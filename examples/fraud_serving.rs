//! End-to-end serving driver (the paper's motivating deployment: §I fraud
//! detection / streaming decision systems; §III-D PCIe-card offload).
//!
//! Trains a real churn/fraud-style binary model at a Table-II-like
//! topology, compiles it, loads the AOT XLA artifact, and serves a
//! sustained stream of requests through the dynamic-batching coordinator,
//! reporting latency percentiles and throughput for both the XLA hot path
//! and the functional-CAM backend, with the exact CPU baseline measured on
//! the same machine for grounding. Also runs the cycle-level chip
//! simulation of the same program so software-served and silicon-projected
//! numbers appear side by side.
//!
//! This is the repository's required end-to-end validation driver; its
//! output is recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example fraud_serving`

use std::path::Path;
use std::time::Instant;
use xtime::baselines::cpu_measure;
use xtime::compiler::{compile, CompileOptions};
use xtime::coordinator::{Backend, BatchPolicy, FunctionalBackend, Server, XlaBackend};
use xtime::data::by_name;
use xtime::runtime::XlaCamEngine;
use xtime::sim::{simulate, ChipConfig, Workload};
use xtime::trees::{gbdt, metrics, GbdtParams};
use xtime::util::bench::{rate, t, Table};

const N_REQUESTS: usize = 20_000;

fn serve(
    name: &str,
    backend: Box<dyn Backend>,
    program: &xtime::compiler::CamProgram,
    data: &xtime::data::Dataset,
    table: &mut Table,
) {
    let server = Server::start(backend, BatchPolicy { max_wait_us: 200, max_batch: 0 }, program.n_features);
    // Pre-quantize requests so the measured path is submit→reply.
    let bins: Vec<Vec<u16>> =
        (0..N_REQUESTS).map(|i| program.quantizer.bin_row(data.row(i % data.n_rows()))).collect();
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(N_REQUESTS);
    for b in bins {
        pending.push(server.submit(b));
    }
    for rx in pending {
        rx.recv().expect("reply");
    }
    let wall = t0.elapsed().as_secs_f64();
    let lat = server.latency_summary().unwrap();
    let stats = server.stats();
    table.row(&[
        name.to_string(),
        rate(N_REQUESTS as f64 / wall, "req"),
        t(lat.median),
        t(lat.p95),
        format!("{:.1}", stats.mean_batch),
    ]);
    server.shutdown();
}

fn main() -> anyhow::Result<()> {
    println!("=== X-TIME end-to-end serving driver (fraud/churn detection) ===\n");

    // Train at a Table-II-like topology (404 trees in the paper; 128 here
    // keeps the demo quick while staying multi-core on chip).
    let data = by_name("churn").expect("dataset").generate_n(10_000);
    let split = data.split(0.8, 0.1, 42);
    let t_train = Instant::now();
    let model = gbdt::train(
        &split.train,
        &GbdtParams {
            n_rounds: 128,
            max_leaves: 256,
            early_stop_rounds: 10,
            ..Default::default()
        },
        Some(&split.val),
    );
    println!(
        "trained {} trees (≤{} leaves, depth {}) in {:.1}s — test accuracy {:.3}",
        model.n_trees(),
        model.max_leaves(),
        model.max_depth(),
        t_train.elapsed().as_secs_f64(),
        metrics::score(&model, &split.test)
    );

    let program = compile(&model, &CompileOptions::default())?;
    println!(
        "compiled: {} cores, {} CAM rows, task {}\n",
        program.cores_per_replica(),
        program.total_rows(),
        program.task.name()
    );

    // --- serve through the coordinator --------------------------------------
    let mut table = Table::new(&["backend", "throughput", "p50 latency", "p95 latency", "mean batch"]);

    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        let engine = XlaCamEngine::new(&program, &artifacts, 64)?;
        println!("XLA bucket: {} (batch {})", engine.bucket().file, engine.max_batch());
        serve("xla-aot (PJRT)", Box::new(XlaBackend { engine }), &program, &data, &mut table);
    } else {
        println!("artifacts missing — run `make artifacts` for the XLA row");
    }
    serve("cam-functional", Box::new(FunctionalBackend::new(&program)), &program, &data, &mut table);

    // Measured CPU baseline on the same machine (exact tree walk).
    let cpu = cpu_measure(&model, &data, N_REQUESTS);
    table.row(&[
        "cpu tree-walk".into(),
        rate(cpu.throughput_sps, "req"),
        t(cpu.latency_ns.median * 1e-9),
        t(cpu.latency_ns.p95 * 1e-9),
        "1.0".into(),
    ]);
    table.print(&format!("serving {} requests", N_REQUESTS));

    // --- silicon projection ---------------------------------------------------
    let batched = compile(&model, &CompileOptions { replicas: 0, ..Default::default() })?;
    let rep = simulate(&batched, &ChipConfig::default(), &Workload::saturating(1_000_000), 0.05);
    println!(
        "\nX-TIME chip projection: {:.0} ns unloaded latency, {:.0} MS/s ({} replicas, bound {}), {:.2} nJ/dec",
        rep.latency_ns.min, rep.throughput_msps, rep.n_replicas, rep.bottleneck, rep.energy_nj_per_decision
    );
    Ok(())
}
