//! End-to-end serving driver (the paper's motivating deployment: §I fraud
//! detection / streaming decision systems; §III-D PCIe-card offload).
//!
//! Trains a real churn/fraud-style binary model at a Table-II-like
//! topology, compiles it, loads the AOT XLA artifact, and serves a
//! sustained stream of requests through the dynamic-batching coordinator,
//! reporting latency percentiles and throughput for both the XLA hot path
//! and the functional-CAM backend, with the exact CPU baseline measured on
//! the same machine for grounding. Also runs the cycle-level chip
//! simulation of the same program so software-served and silicon-projected
//! numbers appear side by side.
//!
//! This is the repository's required end-to-end validation driver; its
//! output is recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example fraud_serving`
//!
//! `--shards N` (default 4) sizes the sharded multi-card demo: a
//! 1024-tree ensemble is partitioned into N shard programs served by a
//! pool of per-shard workers, and throughput is compared against the same
//! ensemble on a single worker (§III-D scale-out; ADR-001).
//!
//! `--threads N` (default 0 = one per CPU) sets the planned-execution
//! worker count inside each CamEngine-backed backend (ADR-002). Results
//! are bit-identical for every value — it is purely a throughput knob.

use std::path::Path;
use std::time::Instant;
use xtime::baselines::cpu_measure;
use xtime::bench_support::{random_ensemble, sharded_functional_pool};
use xtime::compiler::{compile, partition, CamEngine, CompileOptions, PartitionOptions};
use xtime::coordinator::{Backend, BatchPolicy, FunctionalBackend, Server, XlaBackend};
use xtime::data::{by_name, Task};
use xtime::runtime::XlaCamEngine;
use xtime::sim::{simulate, CardConfig, ChipConfig, SimCardBackend, Workload};
use xtime::trees::{gbdt, metrics, GbdtParams};
use xtime::util::bench::{rate, t, times, Table};
use xtime::util::{Args, Rng};

const N_REQUESTS: usize = 20_000;
/// Requests for the sharded demo (functional backend is ~1 ms/req on the
/// 1024-tree model, so this keeps the demo under a minute).
const N_SHARD_REQUESTS: usize = 2_000;

fn serve(
    name: &str,
    backend: Box<dyn Backend>,
    threads: Option<usize>,
    program: &xtime::compiler::CamProgram,
    data: &xtime::data::Dataset,
    table: &mut Table,
) {
    let server = Server::start(
        backend,
        BatchPolicy { max_wait_us: 200, max_batch: 0, threads },
        program.n_features,
    );
    // Pre-quantize requests so the measured path is submit→reply.
    let bins: Vec<Vec<u16>> =
        (0..N_REQUESTS).map(|i| program.quantizer.bin_row(data.row(i % data.n_rows()))).collect();
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(N_REQUESTS);
    for b in bins {
        pending.push(server.submit(b));
    }
    for rx in pending {
        rx.recv().expect("reply");
    }
    let wall = t0.elapsed().as_secs_f64();
    let lat = server.latency_summary().unwrap();
    let stats = server.stats();
    table.row(&[
        name.to_string(),
        rate(N_REQUESTS as f64 / wall, "req"),
        t(lat.median),
        t(lat.p95),
        format!("{:.1}", stats.mean_batch),
    ]);
    server.shutdown();
}

/// Serve the same request stream through a 1-shard and an N-shard pool of
/// functional backends and report the scaling, then print the simulated
/// N-card projection.
fn shard_demo(n_shards: usize, threads: Option<usize>) -> anyhow::Result<()> {
    println!("\n=== sharded multi-card serving (1024-tree ensemble, {n_shards} shards) ===");
    // Exact-topology synthetic ensemble: serving scalability depends only
    // on topology, and 1024 trees is the paper-scale regime (Table II).
    let model = random_ensemble(1024, 4, 32, Task::Binary, 99);
    let program = compile(&model, &CompileOptions::default())?;
    println!(
        "compiled: {} trees, {} rows, {} cores",
        program.n_trees,
        program.total_rows(),
        program.cores_per_replica()
    );

    // Pre-generate the request stream once so both pools see equal work.
    let mut rng = Rng::new(4242);
    let rows: Vec<Vec<f32>> = (0..N_SHARD_REQUESTS)
        .map(|_| (0..program.n_features).map(|_| rng.f32()).collect())
        .collect();
    let bins: Vec<Vec<u16>> = rows.iter().map(|r| program.quantizer.bin_row(r)).collect();

    // Correctness spot check: sharded logits must be bit-identical to the
    // unsharded functional engine (full test in rust/tests/sharding.rs).
    let reference = CamEngine::new(&program);

    let mut table = Table::new(&["shards", "throughput", "p50 latency", "speedup", "shard rows"]);
    let mut base_tput = 0.0f64;
    let mut sharded_plan = None;
    for &n in &[1usize, n_shards] {
        let plan = partition(&program, n, &PartitionOptions::default())?;
        let server = sharded_functional_pool(
            &plan,
            BatchPolicy { max_wait_us: 200, max_batch: 64, threads },
        );
        for (b, r) in bins.iter().take(50).zip(&rows) {
            let reply = server.infer_blocking(b.clone());
            assert_eq!(reply.logits, reference.infer_row(&program, r), "shard aggregation drifted");
        }
        let t0 = Instant::now();
        let pending: Vec<_> = bins.iter().map(|b| server.submit(b.clone())).collect();
        for rx in pending {
            rx.recv().expect("reply");
        }
        let wall = t0.elapsed().as_secs_f64();
        let tput = N_SHARD_REQUESTS as f64 / wall;
        if n == 1 {
            base_tput = tput;
        }
        let lat = server.latency_summary().unwrap();
        let stats = server.stats();
        let rows_per_shard: Vec<String> =
            plan.shards.iter().map(|s| format!("{}", s.total_rows())).collect();
        table.row(&[
            format!("{n}"),
            rate(tput, "req"),
            t(lat.median),
            times(tput / base_tput),
            rows_per_shard.join("/"),
        ]);
        assert_eq!(stats.errors, 0);
        server.shutdown();
        if n == n_shards {
            sharded_plan = Some(plan);
        }
    }
    table.print(&format!("sharded serving, {N_SHARD_REQUESTS} requests (+50 verified)"));
    println!("logits bit-identical to the unsharded engine on all verified rows ✓");

    // Silicon projection: N independent simulated cards, one per shard
    // (reusing the N-shard plan from the loop above).
    let plan = sharded_plan.expect("loop always builds the n_shards plan");
    let cards: Vec<SimCardBackend> = plan
        .shards
        .iter()
        .map(|s| SimCardBackend::new(s, &ChipConfig::default(), &CardConfig::default()))
        .collect();
    // Every request visits every card (partial-sum sharding), so the pool
    // runs at the slowest card's rate — which rises with N because each
    // card holds ~1/N of the rows.
    let pool = cards
        .iter()
        .map(|c| c.projected_throughput_sps())
        .fold(f64::INFINITY, f64::min);
    println!(
        "simulated {}-card projection: {} (slowest card bounds the lock-step pool)",
        n_shards,
        rate(pool, "req"),
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::new("fraud_serving", "end-to-end serving driver")
        .opt("shards", Some("4"), "shard count for the multi-card demo (≥ 2)")
        .opt("threads", Some("0"), "planned-execution workers per backend (0 = one per CPU)")
        .parse(&argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let n_shards = args.get_usize("shards");
    if n_shards < 2 {
        return Err(anyhow::anyhow!(
            "--shards must be ≥ 2 (got {n_shards}); the demo compares N shards against 1"
        ));
    }
    // 0 = auto (one planned worker per CPU); bit-identical either way.
    let n_threads = args.get_usize("threads");
    let threads = Some(n_threads);
    println!(
        "planned-execution workers per backend: {}",
        match n_threads {
            0 => "auto (one per CPU)".to_string(),
            n => n.to_string(),
        }
    );

    println!("=== X-TIME end-to-end serving driver (fraud/churn detection) ===\n");

    // Train at a Table-II-like topology (404 trees in the paper; 128 here
    // keeps the demo quick while staying multi-core on chip).
    let data = by_name("churn").expect("dataset").generate_n(10_000);
    let split = data.split(0.8, 0.1, 42);
    let t_train = Instant::now();
    let model = gbdt::train(
        &split.train,
        &GbdtParams {
            n_rounds: 128,
            max_leaves: 256,
            early_stop_rounds: 10,
            ..Default::default()
        },
        Some(&split.val),
    );
    println!(
        "trained {} trees (≤{} leaves, depth {}) in {:.1}s — test accuracy {:.3}",
        model.n_trees(),
        model.max_leaves(),
        model.max_depth(),
        t_train.elapsed().as_secs_f64(),
        metrics::score(&model, &split.test)
    );

    let program = compile(&model, &CompileOptions::default())?;
    println!(
        "compiled: {} cores, {} CAM rows, task {}\n",
        program.cores_per_replica(),
        program.total_rows(),
        program.task.name()
    );

    // --- serve through the coordinator --------------------------------------
    let mut table = Table::new(&["backend", "throughput", "p50 latency", "p95 latency", "mean batch"]);

    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        let engine = XlaCamEngine::new(&program, &artifacts, 64)?;
        println!("XLA bucket: {} (batch {})", engine.bucket().file, engine.max_batch());
        let backend = Box::new(XlaBackend { engine });
        serve("xla-aot (PJRT)", backend, threads, &program, &data, &mut table);
    } else {
        println!("artifacts missing — run `make artifacts` for the XLA row");
    }
    serve(
        "cam-functional (planned)",
        Box::new(FunctionalBackend::new(&program)),
        threads,
        &program,
        &data,
        &mut table,
    );

    // Measured CPU baseline on the same machine (exact tree walk).
    let cpu = cpu_measure(&model, &data, N_REQUESTS);
    table.row(&[
        "cpu tree-walk".into(),
        rate(cpu.throughput_sps, "req"),
        t(cpu.latency_ns.median * 1e-9),
        t(cpu.latency_ns.p95 * 1e-9),
        "1.0".into(),
    ]);
    table.print(&format!("serving {} requests", N_REQUESTS));

    // --- silicon projection ---------------------------------------------------
    let batched = compile(&model, &CompileOptions { replicas: 0, ..Default::default() })?;
    let rep = simulate(&batched, &ChipConfig::default(), &Workload::saturating(1_000_000), 0.05);
    println!(
        "\nX-TIME chip projection: {:.0} ns unloaded latency, {:.0} MS/s ({} replicas, bound {}), {:.2} nJ/dec",
        rep.latency_ns.min, rep.throughput_msps, rep.n_replicas, rep.bottleneck, rep.energy_nj_per_decision
    );

    // --- sharded multi-card scale-out ----------------------------------------
    shard_demo(n_shards, threads)?;
    Ok(())
}
