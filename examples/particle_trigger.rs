//! Real-time trigger scenario (§I: "for use in real-time processing,
//! model latency must be ~100 ns" — the particle-physics FPGA use case of
//! ref. [61]).
//!
//! A binary classifier screens a stream of events; the question is whether
//! X-TIME's single-sample decision latency fits a 100-ns-class trigger
//! budget where GPUs (µs–ms) cannot. The example sweeps tree count and
//! depth, reporting simulated chip latency against the GPU model and the
//! measured CPU baseline.
//!
//! Run: `cargo run --release --example particle_trigger`

use xtime::baselines::{cpu_measure, GpuModel, GpuWorkload};
use xtime::compiler::{compile, CompileOptions};
use xtime::data::by_name;
use xtime::sim::{ideal_latency_cycles, ChipConfig};
use xtime::trees::{gbdt, GbdtParams};
use xtime::util::bench::{t, Table};

fn main() -> anyhow::Result<()> {
    println!("=== 100 ns trigger budget study ===");
    println!("(paper §I: real-time in-the-loop decisions need ~100 ns inference)\n");

    // Physics-trigger-like data: the gesture stand-in has 32 continuous
    // features, about the width of a calorimeter feature vector.
    let data = by_name("gesture").expect("dataset").generate_n(4000);
    let cfg = ChipConfig::default();
    let gpu = GpuModel::default();

    let mut table = Table::new(&[
        "N_trees", "depth", "X-TIME latency", "GPU latency", "CPU latency", "in budget?",
    ]);

    for (rounds, depth) in [(8usize, 4usize), (32, 6), (64, 8), (128, 8)] {
        let model = gbdt::train(
            &data,
            &GbdtParams {
                n_rounds: rounds,
                max_depth: depth,
                max_leaves: 1 << depth.min(8),
                ..Default::default()
            },
            None,
        );
        let program = compile(&model, &CompileOptions::default())?;
        let xtime_ns = ideal_latency_cycles(&program, &cfg) as f64 * cfg.cycle_ns();

        let gpu_lat = gpu.batch_latency_s(
            &GpuWorkload {
                n_trees: model.n_trees() * data.task.n_outputs(),
                mean_depth: model.max_depth() as f64 * 0.8,
                max_depth: model.max_depth() as f64,
                n_features: data.n_features,
            },
            1, // single event — the trigger regime
        );
        let cpu = cpu_measure(&model, &data, 2000);

        table.row(&[
            format!("{}", model.n_trees()),
            format!("{}", model.max_depth()),
            t(xtime_ns * 1e-9),
            t(gpu_lat),
            t(cpu.latency_ns.median * 1e-9),
            if xtime_ns <= 150.0 { "yes".into() } else { "NO".into() },
        ]);
    }
    table.print("single-event decision latency vs trigger budget");

    println!(
        "\nX-TIME stays flat (~tens of ns) as the ensemble grows — the whole\n\
         forest evaluates in one CAM search — while GPU latency is dominated\n\
         by kernel launch (~10 µs) and CPU latency grows with N_trees × depth."
    );
    Ok(())
}
