//! Self-healing fleet demo (ISSUE 9): closed-loop defect-drift
//! detection → background retrain → hot swap, under live traffic.
//!
//! A HAT-trained churn model serves through a [`SimCardBackend`] whose
//! [`DefectInjector`] lets the demo strike the card with a deterministic
//! memristor-defect draw mid-serve (paper §V-A). Each autonomous cycle:
//!
//! 1. **strike** — the card switches to the tracked defective engine on
//!    its next batch; client traffic keeps flowing;
//! 2. **detect** — a [`HealthMonitor`] shadow-scores pinned canary rows;
//!    consecutive agreement breaches trip its hysteretic detector;
//! 3. **heal** — [`SelfHealer::heal`] flags the route degraded (replies
//!    carry `degraded = true` + soft-boundary confidence, so callers can
//!    abstain), retrains against the live card's exact defect draw on a
//!    background thread, verifies the repaired program (contract 8), and
//!    hot-swaps it under epoch CAS — the old server drains, zero replies
//!    dropped (contract 6), and post-swap replies are proven
//!    bit-identical to the retrained program (contract 10);
//! 4. **re-arm** — the monitor re-pins its canaries against the repaired
//!    deployment and the next cycle begins.
//!
//! Sustained load runs through every cycle; the demo asserts that every
//! admitted request received its reply (zero dropped) and that recovery
//! actually recovered (post-heal canary agreement back to 1.0).
//!
//! Run: `cargo run --release --example self_healing`
//! Flags: `--cycles N` (default 2) autonomous heal cycles,
//! `--canaries N` (default 48) canary rows. `XTIME_FAST=1` shrinks the
//! model for CI smoke runs.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use xtime::bench_support::fast_mode;
use xtime::cam::DefectSpec;
use xtime::compiler::{compile, CamEngine, CamProgram, CompileOptions};
use xtime::coordinator::{
    Admission, Backend, BatchPolicy, CanarySet, DriftConfig, DriftVerdict, Fleet, HealContext,
    HealthMonitor, ModelConfig, SelfHealer, VerifyPolicy, DEFAULT_QUEUE_CAP,
};
use xtime::data::by_name;
use xtime::sim::{CardConfig, ChipConfig, DefectInjector, SimCardBackend};
use xtime::trees::hat::{self, HatParams};
use xtime::trees::{metrics, GbdtParams};
use xtime::util::Args;

const MODEL: &str = "churn";

/// Find a deterministic defect draw that provably drags canary agreement
/// below `trigger` against the *live* route's current answers: candidate
/// draws are replayed offline through `CamEngine::with_defects` — the
/// exact engine the struck card will switch to — so a cycle can never
/// stall on a lucky draw that happens to preserve the canaries.
fn drifting_draw(
    fleet: &Fleet,
    program: &CamProgram,
    canaries: &[Vec<f32>],
    pct: f64,
    seed_base: u64,
    trigger: f64,
) -> (DefectSpec, u64) {
    let reference: Vec<f32> = fleet
        .infer_batch(MODEL, canaries)
        .expect("canary batch")
        .into_iter()
        .map(|r| r.expect("canary reply").prediction)
        .collect();
    let spec = DefectSpec::memristor(pct);
    for seed in seed_base..seed_base + 64 {
        let defective = CamEngine::with_defects(program, spec, seed);
        let agree = canaries
            .iter()
            .zip(&reference)
            .filter(|(row, want)| defective.predict(program, row) == **want)
            .count();
        if (agree as f64) < trigger * canaries.len() as f64 {
            return (spec, seed);
        }
    }
    panic!("no defect draw at {pct} disturbs the canaries (model too defect-tolerant?)");
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::new("self_healing", "closed-loop defect detect → retrain → swap demo")
        .opt("cycles", Some("2"), "autonomous heal cycles to run")
        .opt("canaries", Some("48"), "canary rows shadow-scored per probe")
        .parse(&argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let cycles = args.get_usize("cycles").max(1);
    let n_canaries = args.get_usize("canaries").max(8);

    println!("=== X-TIME self-healing fleet demo ({cycles} cycle(s)) ===\n");

    // --- train + deploy on a (pristine) simulated card --------------------
    let n_rows = if fast_mode() { 1_500 } else { 4_000 };
    let data = by_name(MODEL).expect("catalog dataset").generate_n(n_rows);
    let split = data.split(0.8, 0.0, 97);
    let params = HatParams {
        deploy_bits: 4,
        gbdt: GbdtParams {
            n_rounds: if fast_mode() { 10 } else { 24 },
            max_leaves: 16,
            ..Default::default()
        },
        retrain_passes: 2,
        ..Default::default()
    };
    let mut model = hat::train(&split.train, &params, None);
    let mut program = compile(&model, &CompileOptions::default())?;
    println!(
        "trained {MODEL}: {} trees, {} CAM rows, clean accuracy {:.3}",
        program.n_trees,
        program.total_rows(),
        metrics::score(&model, &split.test)
    );

    let fleet = Arc::new(Fleet::new());
    let mut injector = DefectInjector::new();
    let backend = SimCardBackend::new(&program, &ChipConfig::default(), &CardConfig::default())
        .with_injector(injector.clone());
    fleet
        .register_backends(
            MODEL,
            vec![Box::new(backend) as Box<dyn Backend>],
            Vec::new(),
            ModelConfig::for_program(&program),
        )
        .map_err(|e| anyhow::anyhow!(e))?;

    // --- monitor: canaries pinned against the pristine deployment ---------
    let canary_rows: Vec<Vec<f32>> =
        (0..n_canaries).map(|i| split.test.row(i % split.test.n_rows()).to_vec()).collect();
    let drift_cfg = DriftConfig {
        trigger_below: 0.90,
        clear_above: 0.97,
        breaches_to_trip: 2,
        grace_probes: 0,
    };
    let canary =
        CanarySet::pin(&fleet, MODEL, canary_rows.clone()).map_err(|e| anyhow::anyhow!(e))?;
    let mut monitor = HealthMonitor::new(canary, drift_cfg);

    let mut healer = SelfHealer::new(HealContext {
        fleet: fleet.clone(),
        model: MODEL.to_string(),
        train: split.train.clone(),
        eval: split.test.clone(),
        params,
        options: CompileOptions::default(),
        chip: ChipConfig::default(),
        card: CardConfig::default(),
        batch_policy: BatchPolicy::default(),
        queue_cap: DEFAULT_QUEUE_CAP,
        verify: VerifyPolicy::default(),
        store: None,
    });

    // --- sustained load + autonomous heal cycles --------------------------
    let stop = AtomicBool::new(false);
    let answered = AtomicU64::new(0);
    let dropped = AtomicU64::new(0);
    let low_confidence_degraded = AtomicU64::new(0);

    std::thread::scope(|scope| {
        // Two sustained-load clients: every Accepted admission MUST get
        // its reply (contract 6 across every swap) — a recv failure is a
        // dropped reply and fails the demo.
        for client in 0..2u64 {
            let fleet = Arc::clone(&fleet);
            let rows = &split.test;
            let (stop, answered, dropped, lowconf) =
                (&stop, &answered, &dropped, &low_confidence_degraded);
            scope.spawn(move || {
                let mut i = client as usize;
                while !stop.load(Ordering::Relaxed) {
                    let row = rows.row(i % rows.n_rows());
                    i += 2;
                    match fleet.submit(MODEL, row) {
                        Ok(Admission::Accepted(rx)) => match rx.recv() {
                            Ok(reply) => {
                                answered.fetch_add(1, Ordering::Relaxed);
                                if reply.degraded && reply.confidence < 0.75 {
                                    // A caller abstaining on low-confidence
                                    // degraded rows would skip this one.
                                    lowconf.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            Err(_) => {
                                dropped.fetch_add(1, Ordering::Relaxed);
                            }
                        },
                        Ok(Admission::Shed { .. }) => std::thread::yield_now(),
                        Err(_) => break, // route gone: demo is over
                    }
                }
            });
        }

        for cycle in 0..cycles {
            println!("\n--- cycle {} ---", cycle + 1);

            // 1. strike: escalating defect rate, fresh deterministic draw.
            let pct = 0.15 + 0.05 * cycle as f64;
            let (spec, seed) = drifting_draw(
                &fleet,
                &program,
                &canary_rows,
                pct,
                0xC0FE + 0x100 * cycle as u64,
                drift_cfg.trigger_below,
            );
            injector.strike(spec, seed);
            println!("struck card: {:.0}% memristor defects, seed {seed:#x}", pct * 100.0);

            // 2. detect: probe until the detector trips.
            let mut probes = 0usize;
            loop {
                let reading = monitor.probe(&fleet, MODEL).expect("probe");
                probes += 1;
                println!(
                    "  probe {probes}: agreement {:.3} (effective {:.3}, +{} errors) → {:?}",
                    reading.agreement,
                    reading.effective_agreement,
                    reading.error_delta,
                    reading.verdict
                );
                match reading.verdict {
                    DriftVerdict::Drift => break,
                    _ => assert!(probes < 32, "detector failed to trip"),
                }
            }

            // 3. heal: retrain against the live draw → verify → swap →
            //    contract-10 bit-identity proof, all under load.
            let (repaired, new_injector, report) =
                healer.heal(model, &injector).expect("heal cycle");
            println!(
                "  healed: {} retrain pass(es), affected trees {} → {}, \
                 deployed score {:.3} → {:.3}",
                report.retrain.passes,
                report.retrain.initial_affected,
                report.retrain.final_affected,
                report.retrain.initial_score,
                report.retrain.final_score
            );
            println!(
                "  swap epoch {} → {}, {} rows proven bit-identical to the \
                 retrained program (contract 10), wall {:.2}s",
                report.old_epoch, report.new_epoch, report.bit_identity_rows, report.wall_s
            );

            // 4. re-arm against the repaired deployment.
            model = repaired;
            program = compile(&model, &CompileOptions::default()).expect("repaired compiles");
            injector = new_injector;
            monitor.rearm_with(&fleet, MODEL).expect("rearm");
            let reading = monitor.probe(&fleet, MODEL).expect("post-heal probe");
            assert_eq!(reading.agreement, 1.0, "repaired route must agree with itself");
            println!("  re-armed: post-heal canary agreement {:.3}", reading.agreement);
        }

        stop.store(true, Ordering::Relaxed);
    });

    // --- verdict ----------------------------------------------------------
    let answered = answered.load(Ordering::Relaxed);
    let dropped = dropped.load(Ordering::Relaxed);
    let lowconf = low_confidence_degraded.load(Ordering::Relaxed);
    let stats = fleet.model_stats(MODEL).expect("stats");
    println!(
        "\nload summary: {answered} replies across {cycles} heal cycle(s), \
         {dropped} dropped, {lowconf} low-confidence degraded replies flagged \
         (route epoch {}, degraded={})",
        stats.epoch, stats.degraded
    );
    assert_eq!(dropped, 0, "contract 6: zero dropped replies across all swaps");
    assert!(!stats.degraded, "degraded flag must clear after the last heal");
    assert_eq!(healer.history().len(), cycles);

    drop(healer);
    Arc::try_unwrap(fleet).ok().expect("fleet refs").shutdown();
    println!("self-healing demo complete: {cycles} autonomous cycle(s), zero dropped replies.");
    Ok(())
}
