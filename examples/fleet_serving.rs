//! Multi-tenant fleet serving demo (§III-D: "multiple unique models can
//! be mapped to the accelerator, by assigning a different batch to each
//! model").
//!
//! Trains three Table-II-style tenants (churn, telco, gas), registers
//! each as a sharded route with a bounded admission queue, then:
//!
//! 1. drives a **skewed load mix** (70/20/10) through the fleet with
//!    batched clients and prints the per-model fleet table;
//! 2. **hot-swaps** the hot tenant to a retrained model while client
//!    traffic keeps flowing — the drain contract (DESIGN.md §5
//!    contract 6) guarantees every admitted request is answered by the
//!    program it was admitted to, so the retrain→redeploy loop (PR 3)
//!    runs against live traffic;
//! 3. **bursts** the cold tenant far past its queue cap to show
//!    deterministic degradation: overload sheds at admission with exact
//!    accounting instead of growing an unbounded queue.
//!
//! Run: `cargo run --release --example fleet_serving`
//! Flags: `--shards N` (default 2) shard programs per tenant,
//! `--requests N` (default 6000) mixed-phase requests.

use std::sync::Arc;
use xtime::bench_support::{drive_skewed_mix, fleet_table, MixTenant};
use xtime::compiler::{compile, CompileOptions};
use xtime::coordinator::{Admission, BatchPolicy, Fleet, ModelConfig};
use xtime::data::{by_name, Dataset};
use xtime::trees::{gbdt, metrics, Ensemble, GbdtParams};
use xtime::util::stats::{fmt_si_rate, fmt_si_time};
use xtime::util::Args;

fn train(dataset: &Dataset, n_rounds: usize) -> Ensemble {
    gbdt::train(
        dataset,
        &GbdtParams { n_rounds, max_leaves: 16, ..Default::default() },
        None,
    )
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::new("fleet_serving", "multi-tenant fleet serving demo")
        .opt("shards", Some("2"), "shard programs (virtual cards) per tenant")
        .opt("requests", Some("6000"), "requests in the mixed-load phase")
        .parse(&argv)
        .map_err(|e| anyhow::anyhow!(e))?;
    let shards = args.get_usize("shards").max(1);
    let n_requests = args.get_usize("requests");

    println!("=== X-TIME multi-tenant fleet serving demo ===\n");

    // --- tenants: three Table-II datasets, hot → cold ---------------------
    let names = ["churn", "telco", "gas"];
    let weights = [7usize, 2, 1]; // 70/20/10 skew
    let queue_caps = [2048usize, 1024, 64]; // cold tenant gets a small queue
    let fleet = Arc::new(Fleet::new());
    let mut datasets = Vec::new();
    for (name, &cap) in names.iter().zip(&queue_caps) {
        let data = by_name(name).expect("catalog dataset").generate_n(3_000);
        let model = train(&data, 24);
        let program = compile(&model, &CompileOptions::default())?;
        let cfg = ModelConfig::for_program(&program)
            .with_shards(shards)
            .with_policy(BatchPolicy { max_wait_us: 200, max_batch: 0, threads: None })
            .with_queue_cap(cap);
        fleet
            .register_program(name, &program, cfg)
            .map_err(|e| anyhow::anyhow!(e))?;
        println!(
            "registered {name}: {} trees, {} CAM rows, {shards} shard(s), cap {cap}, acc {:.3}",
            program.n_trees,
            program.total_rows(),
            metrics::score(&model, &data)
        );
        datasets.push(data);
    }

    // --- phase 1: skewed multi-tenant mix ---------------------------------
    println!("\n--- phase 1: skewed load mix ({n_requests} requests, 70/20/10) ---");
    let tenants: Vec<MixTenant> = names
        .iter()
        .zip(&datasets)
        .zip(&weights)
        .map(|((&name, data), &weight)| MixTenant { name, data, weight })
        .collect();
    let mix =
        drive_skewed_mix(&fleet, &tenants, n_requests, 42).map_err(anyhow::Error::msg)?;
    fleet_table(&fleet.stats()).print(&format!(
        "fleet after mixed load — {n_requests} in {}",
        fmt_si_time(mix.wall_s)
    ));
    println!(
        "throughput {} · {} served, {} shed",
        fmt_si_rate(mix.served as f64 / mix.wall_s, "req"),
        mix.served,
        mix.shed
    );

    // --- phase 2: hot swap under live traffic -----------------------------
    println!("\n--- phase 2: retrain + hot-swap `churn` under live traffic ---");
    let retrained = train(&datasets[0], 48); // the HAT→retrain→redeploy loop
    let new_program = compile(&retrained, &CompileOptions::default())?;
    let swap_cfg = ModelConfig::for_program(&new_program)
        .with_shards(shards)
        .with_queue_cap(queue_caps[0]);
    std::thread::scope(|scope| {
        let fleet2 = Arc::clone(&fleet);
        let d = &datasets[0];
        let client = scope.spawn(move || {
            let mut ok = 0usize;
            for i in 0..600 {
                if fleet2.infer("churn", d.row(i % d.n_rows())).is_ok() {
                    ok += 1;
                }
            }
            ok
        });
        std::thread::sleep(std::time::Duration::from_millis(2));
        fleet.swap_program("churn", &new_program, swap_cfg).expect("swap");
        let ok = client.join().expect("client thread");
        println!(
            "swap completed mid-traffic: client saw {ok}/600 successful replies \
             (drain contract: none dropped)"
        );
        assert_eq!(ok, 600);
    });
    let churn = fleet.model_stats("churn").expect("churn stats");
    println!(
        "churn route restarted on the retrained program ({} trees): \
         {} requests on the new server, {} errors",
        new_program.n_trees, churn.admitted, churn.errors
    );

    // --- phase 3: overload the cold tenant --------------------------------
    println!("\n--- phase 3: burst the cold tenant past its queue cap ---");
    let d = &datasets[2];
    let burst = 2_000usize;
    let rows: Vec<Vec<f32>> = (0..burst).map(|i| d.row(i % d.n_rows()).to_vec()).collect();
    let admissions = fleet.submit_batch("gas", &rows).map_err(anyhow::Error::msg)?;
    let (mut ok, mut dropped) = (0usize, 0usize);
    for adm in admissions {
        match adm {
            Admission::Accepted(rx) => {
                rx.recv().expect("admitted request must be answered");
                ok += 1;
            }
            Admission::Shed { .. } => dropped += 1,
        }
    }
    let gas = fleet.model_stats("gas").expect("gas stats");
    println!(
        "burst of {burst}: {ok} served, {dropped} shed at the {} cap \
         (model shed counter: {}) — overload degrades deterministically",
        gas.queue_cap, gas.shed
    );
    assert_eq!(ok + dropped, burst, "every burst request accounted");

    fleet_table(&fleet.stats()).print("final fleet state");
    let totals = fleet.stats();
    println!(
        "fleet lifetime: {} admitted, {} shed (counters survive swaps)",
        totals.admitted, totals.shed
    );
    Ok(())
}
