//! Analog-defect robustness study (paper §V-A, Fig. 9b).
//!
//! Sweeps memristor-conductance and DAC defect rates on a trained model
//! and reports mean relative accuracy over independent defect draws —
//! including the paper's operating point (~0.2% flip probability from a
//! 1 µS conductance σ), where the accuracy drop should stay < 0.5%.
//!
//! Run: `cargo run --release --example defect_study`

use xtime::cam::DefectSpec;
use xtime::compiler::{compile, CamEngine, CompileOptions};
use xtime::data::by_name;
use xtime::trees::{gbdt, GbdtParams};
use xtime::util::bench::Table;

fn main() -> anyhow::Result<()> {
    println!("=== analog defect injection study (Fig. 9b protocol) ===\n");
    let data = by_name("churn").expect("dataset").generate_n(6000);
    let split = data.split(0.8, 0.0, 3);
    let model = gbdt::train(
        &split.train,
        &GbdtParams { n_rounds: 60, max_leaves: 64, ..Default::default() },
        None,
    );
    let program = compile(&model, &CompileOptions::default())?;

    let test_rows = 600.min(split.test.n_rows());
    let ideal = {
        let engine = CamEngine::new(&program);
        let mut hits = 0;
        for i in 0..test_rows {
            hits += (engine.predict(&program, split.test.row(i)) == split.test.y[i]) as usize;
        }
        hits as f64 / test_rows as f64
    };
    println!("ideal (defect-free) accuracy: {ideal:.4}  ({} trees)", model.n_trees());

    let runs = 20; // paper: 100 runs; 20 keeps the example snappy
    let mut table = Table::new(&["defect %", "memristor rel.acc", "DAC rel.acc"]);
    for pct in [0.002, 0.01, 0.05, 0.10, 0.20] {
        let mut rel = [0.0f64; 2];
        for (which, spec) in
            [DefectSpec::memristor(pct), DefectSpec::dac(pct)].into_iter().enumerate()
        {
            let mut acc_sum = 0.0;
            for run in 0..runs {
                let engine = CamEngine::with_defects(&program, spec, 1000 + run as u64);
                let mut hits = 0;
                for i in 0..test_rows {
                    hits +=
                        (engine.predict(&program, split.test.row(i)) == split.test.y[i]) as usize;
                }
                acc_sum += hits as f64 / test_rows as f64;
            }
            rel[which] = (acc_sum / runs as f64) / ideal;
        }
        table.row(&[
            format!("{:.1}", pct * 100.0),
            format!("{:.4}", rel[0]),
            format!("{:.4}", rel[1]),
        ]);
    }
    table.print(&format!("mean relative accuracy over {runs} defect draws"));

    println!(
        "\npaper operating point: ~0.2% flip probability (1 µS σ on a 1–100 µS\n\
         window) → expect < 0.5% accuracy drop; ensembles average out\n\
         individual bound perturbations, so degradation stays graceful until\n\
         defect rates reach several percent."
    );
    Ok(())
}
