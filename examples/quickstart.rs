//! Quickstart: the whole X-TIME flow in ~60 lines.
//!
//! 1. synthesize a tabular dataset (Table II "churn" stand-in);
//! 2. train a gradient-boosted ensemble (XGBoost-style, from scratch);
//! 3. compile it to analog-CAM threshold maps + NoC config;
//! 4. run inference three ways — CPU reference, functional CAM model,
//!    and the AOT XLA artifact on PJRT — and check they agree;
//! 5. simulate the chip to get latency / throughput / energy.
//!
//! Run: `cargo run --release --example quickstart`

use std::path::Path;
use xtime::compiler::{compile, CamEngine, CompileOptions};
use xtime::data::by_name;
use xtime::runtime::XlaCamEngine;
use xtime::sim::{simulate, ChipConfig, Workload};
use xtime::trees::{gbdt, metrics, GbdtParams};

fn main() -> anyhow::Result<()> {
    // 1. Data ---------------------------------------------------------------
    let data = by_name("churn").expect("catalog dataset").generate_n(4000);
    let split = data.split(0.8, 0.0, 7);
    println!("dataset: churn  ({} rows × {} features)", data.n_rows(), data.n_features);

    // 2. Train --------------------------------------------------------------
    let model = gbdt::train(
        &split.train,
        &GbdtParams { n_rounds: 40, max_leaves: 32, ..Default::default() },
        None,
    );
    println!(
        "model  : {} trees, ≤{} leaves, accuracy {:.3}",
        model.n_trees(),
        model.max_leaves(),
        metrics::score(&model, &split.test)
    );

    // 3. Compile ------------------------------------------------------------
    let program = compile(&model, &CompileOptions::default())?;
    println!(
        "compile: {} core(s), {} CAM rows, {} NoC routers ({} accumulating)",
        program.cores_per_replica(),
        program.total_rows(),
        program.noc.n_routers(),
        program.noc.n_accumulating()
    );

    // 4. Run all three engines ----------------------------------------------
    let cam = CamEngine::new(&program);
    let rows: Vec<&[f32]> = (0..200).map(|i| split.test.row(i)).collect();
    let mut agree_cam = 0;
    for row in &rows {
        agree_cam += (cam.predict(&program, row) == model.predict(row)) as usize;
    }
    println!("functional CAM engine agrees with CPU on {agree_cam}/200 samples");

    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        let xla = XlaCamEngine::new(&program, &artifacts, 64)?;
        let preds = xla.predict_rows(&program, &rows)?;
        let agree = rows
            .iter()
            .zip(&preds)
            .filter(|(row, p)| **p == model.predict(row))
            .count();
        println!(
            "XLA artifact ({}) agrees with CPU on {agree}/200 samples",
            xla.bucket().file
        );
    } else {
        println!("(run `make artifacts` to exercise the XLA path)");
    }

    // 5. Simulate the chip ----------------------------------------------------
    let rep = simulate(&program, &ChipConfig::default(), &Workload::saturating(100_000), 0.05);
    println!(
        "chip   : latency {:.0} ns, throughput {:.0} MS/s, {:.3} nJ/decision (bound: {})",
        rep.latency_ns.min, rep.throughput_msps, rep.energy_nj_per_decision, rep.bottleneck
    );
    Ok(())
}
