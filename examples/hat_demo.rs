//! Hardware-aware training (HAT) demo: the Fig. 9a recovery story on one
//! dataset, end to end.
//!
//! 1. Train an unconstrained (11-bit ≈ float-threshold) GBDT and deploy
//!    it naively at 4 bits — post-training quantization (PTQ). The
//!    `HatReport` shows how far the thresholds had to move.
//! 2. Train the same architecture hardware-aware at 4 bits: thresholds
//!    restricted to the exact CAM grid, splits scored under the analog
//!    ±1-bin drift model. Deployment is lossless *by construction*
//!    (contract 5, asserted).
//! 3. Given a chip's known defect map, run the defect-aware retrain loop:
//!    trees whose CAM rows land on defective cells are re-fit and the
//!    best-scoring pass deployed.
//!
//! Run: `cargo run --release --example hat_demo`

use xtime::cam::DefectSpec;
use xtime::compiler::{
    compile_for_deploy, defective_score, hat_defect_retrain, requantize, CamEngine,
    CompileOptions,
};
use xtime::data::by_name;
use xtime::trees::hat::{self, HatParams};
use xtime::trees::{gbdt, metrics, GbdtParams};

fn main() {
    let data = by_name("churn").unwrap().generate_n(4000);
    let split = data.split(0.8, 0.0, 97);
    println!(
        "dataset: churn ({} train / {} test rows)\n",
        split.train.n_rows(),
        split.test.n_rows()
    );

    // ---- 1. Unconstrained training + naive 4-bit deployment (PTQ) ----
    let uncon = gbdt::train(
        &split.train,
        &GbdtParams { n_rounds: 60, max_leaves: 64, n_bits: 11, ..Default::default() },
        None,
    );
    let s_uncon = metrics::score(&uncon, &split.test);
    let (ptq4, ptq_report) = requantize(&uncon, 4);
    let s_ptq4 = metrics::score(&ptq4, &split.test);
    println!("unconstrained (11-bit):            accuracy {s_uncon:.3}");
    println!(
        "post-training quantized to 4 bits: accuracy {s_ptq4:.3}  \
         ({} of {} thresholds off-grid, mean snap error {:.4}, max {:.4})",
        ptq_report.n_thresholds - ptq_report.n_exact,
        ptq_report.n_thresholds,
        ptq_report.mean_snap_err(),
        ptq_report.max_snap_err
    );

    // ---- 2. Hardware-aware training at 4 bits ------------------------
    let params = HatParams {
        deploy_bits: 4,
        gbdt: GbdtParams { n_rounds: 60, max_leaves: 64, ..Default::default() },
        retrain_passes: 3,
        ..Default::default()
    };
    let hat4 = hat::train(&split.train, &params, None);
    let s_hat4 = metrics::score(&hat4, &split.test);
    let (program, hat_report) =
        compile_for_deploy(&hat4, 4, &CompileOptions::default()).expect("HAT model compiles");
    hat_report.assert_lossless("hat_demo 4-bit model");
    println!(
        "hardware-aware trained at 4 bits:  accuracy {s_hat4:.3}  \
         (all {} thresholds exactly on the CAM grid — contract 5 holds)",
        hat_report.n_thresholds
    );
    println!(
        "  → HAT recovers {:+.3} accuracy over naive PTQ at the same precision\n",
        s_hat4 - s_ptq4
    );

    // Bit-accurate deployment check on a few rows.
    let engine = CamEngine::new(&program);
    let agree = (0..200)
        .filter(|&i| engine.predict(&program, split.test.row(i)) == hat4.predict(split.test.row(i)))
        .count();
    println!("functional CAM engine agreement on 200 held-out rows: {agree}/200");

    // ---- 3. Defect-aware retraining for a known defect map -----------
    let defects = DefectSpec::memristor(0.05);
    let seed = 7u64;
    let deployed_before = defective_score(&program, defects, seed, &split.test);
    println!(
        "\nchip with 5% memristor defects (seed {seed}): deployed accuracy {deployed_before:.3}"
    );
    let (retrained, report) = hat_defect_retrain(
        &split.train,
        &split.test,
        hat4,
        &params,
        &CompileOptions::default(),
        defects,
        seed,
    )
    .expect("retrain loop runs");
    println!(
        "defect-aware retrain: {} pass(es), {} → {} affected trees, \
         deployed accuracy {:.3} → {:.3}",
        report.passes,
        report.initial_affected,
        report.final_affected,
        report.initial_score,
        report.final_score
    );
    let (_, final_report) = compile_for_deploy(&retrained, 4, &CompileOptions::default())
        .expect("retrained model compiles");
    final_report.assert_lossless("retrained model");
    println!("retrained model still deploys losslessly (contract 5).");
}
