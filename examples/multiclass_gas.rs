//! Multi-class + wide-feature scenario: the gas-sensor drift dataset
//! (Table II id 4: 129 features, 6 classes, random forest).
//!
//! Exercises the paper's hardest mapping cases simultaneously:
//!  * 129 features → two queued CAM arrays per core with selective
//!    pre-charge (input vector segmentation, §III-C);
//!  * 6 classes → class-uniform cores, passthrough routers and CP argmax
//!    (Fig. 7b), which caps throughput at 1/N_classes per clock;
//!  * random forest → probability-vote leaves (majority voting).
//!
//! Run: `cargo run --release --example multiclass_gas`

use std::path::Path;
use xtime::compiler::{compile, CamEngine, CompileOptions};
use xtime::data::by_name;
use xtime::runtime::XlaCamEngine;
use xtime::sim::{simulate, ChipConfig, Workload};
use xtime::trees::{metrics, rf, RfParams};
use xtime::util::bench::rate;

fn main() -> anyhow::Result<()> {
    println!("=== gas-sensor multiclass study (129 features, 6 classes, RF) ===\n");
    let data = by_name("gas").expect("dataset").generate_n(8000);
    let split = data.split(0.8, 0.0, 11);

    // 20 estimators × 6 one-vs-rest trees × ≤128 leaves = ≤15360 CAM rows,
    // inside the largest AOT bucket (16384 rows).
    let model = rf::train(
        &split.train,
        &RfParams { n_estimators: 20, max_leaves: 128, ..Default::default() },
    );
    println!(
        "random forest: {} trees ({} estimators × 6 classes), accuracy {:.3}",
        model.n_trees(),
        model.n_trees() / 6,
        metrics::score(&model, &split.test)
    );

    let program = compile(&model, &CompileOptions { replicas: 0, ..Default::default() })?;
    println!(
        "mapping: {} cores/replica × {} replicas; every core class-uniform: {}",
        program.cores_per_replica(),
        program.n_replicas,
        program.cores.iter().all(|c| c.rows.iter().all(|r| r.class == c.class))
    );
    let acc_routers = program.noc.n_accumulating();
    println!(
        "NoC: {} routers, {} accumulate in-subtree (class/replica-uniform), rest passthrough",
        program.noc.n_routers(),
        acc_routers
    );

    // Functional check incl. the queued-array selective pre-charge stats.
    let engine = CamEngine::new(&program);
    let bins = program.quantizer.bin_row(split.test.row(0));
    let (logits, stats) = engine.infer_bins_stats(&bins);
    println!(
        "\nsample 0: logits {:?} → class {}",
        logits.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>(),
        program.task.decide(&logits) as usize
    );
    println!(
        "selective pre-charge: {} charged rows across both queued segments (total rows {})",
        stats.charged_rows,
        program.total_rows()
    );
    let mut agree = 0;
    for i in 0..300 {
        agree += (engine.predict(&program, split.test.row(i)) == model.predict(split.test.row(i)))
            as usize;
    }
    println!("functional CAM vs CPU agreement: {agree}/300");

    // XLA path on the F=130 bucket.
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        match XlaCamEngine::new(&program, &artifacts, 64) {
            Ok(xla) => {
                let rows: Vec<&[f32]> = (0..128).map(|i| split.test.row(i)).collect();
                let preds = xla.predict_rows(&program, &rows)?;
                let ok =
                    rows.iter().zip(&preds).filter(|(r, p)| **p == model.predict(r)).count();
                println!("XLA bucket {}: agreement {ok}/128", xla.bucket().file);
            }
            Err(e) => println!("XLA path skipped: {e}"),
        }
    }

    // Chip projection: the two §III-C/§III-D levers visible at once.
    let cfg = ChipConfig::default();
    let rep = simulate(&program, &cfg, &Workload::saturating(200_000), 0.05);
    println!(
        "\nchip: latency {:.0} ns, throughput {} (bound: {})",
        rep.latency_ns.min,
        rate(rep.throughput_msps * 1e6, "Samples"),
        rep.bottleneck
    );
    println!(
        "  input broadcast: {} flits/sample (129 features × 8 b / 64 b flits)",
        cfg.input_flits(program.n_features)
    );
    println!("  output: 6 class flits/sample on the root link (Fig. 7b ceiling: 1/6 per clock)");
    Ok(())
}
