//! Self-healing loop coverage (ISSUE 9): the drift detector's state
//! machine (threshold crossing, hysteresis no-flap, cold-start grace),
//! no-false-positive on a defect-free card, and one full
//! detect → retrain → verify → swap cycle through [`SelfHealer`].
//!
//! The detector tests are pure (no fleet, no clocks): `observe` is fed
//! agreement fractions directly and every transition is asserted. The
//! integration tests drive a real [`SimCardBackend`] route, with
//! mid-serve defects injected through [`DefectInjector`] — the same
//! deterministic `(DefectSpec, seed)` draw the retrain loop repairs
//! against, which is what makes the post-heal assertions exact.

use std::sync::Arc;
use xtime::cam::DefectSpec;
use xtime::compiler::{compile, CamEngine, CompileOptions};
use xtime::coordinator::{
    DriftConfig, DriftDetector, DriftVerdict, Fleet, HealContext, HealthMonitor, ModelConfig,
    SelfHealer, VerifyPolicy,
};
use xtime::coordinator::{Backend, BatchPolicy, CanarySet, DEFAULT_QUEUE_CAP};
use xtime::data::{by_name, Dataset};
use xtime::sim::{CardConfig, ChipConfig, DefectInjector, SimCardBackend};
use xtime::trees::hat::{self, HatParams};
use xtime::trees::{Ensemble, GbdtParams};

// ---------------------------------------------------------------- unit:
// DriftDetector is a pure state machine — feed agreements, pin verdicts.

fn cfg(trigger: f64, clear: f64, breaches: usize, grace: usize) -> DriftConfig {
    DriftConfig {
        trigger_below: trigger,
        clear_above: clear,
        breaches_to_trip: breaches,
        grace_probes: grace,
    }
}

/// Threshold crossing: K consecutive breaches trip; `Drift` is emitted
/// exactly once, then `Tripped` until rearm.
#[test]
fn detector_trips_after_consecutive_breaches_and_emits_drift_once() {
    let mut d = DriftDetector::new(cfg(0.90, 0.97, 3, 0));
    assert_eq!(d.observe(0.99), DriftVerdict::Healthy);
    assert_eq!(d.observe(0.50), DriftVerdict::Suspect { breaches: 1 });
    assert_eq!(d.observe(0.50), DriftVerdict::Suspect { breaches: 2 });
    assert!(!d.is_tripped());
    assert_eq!(d.observe(0.50), DriftVerdict::Drift);
    assert!(d.is_tripped());
    // Once tripped, stays tripped — even a perfect probe does not clear
    // it (only the healer's rearm does).
    assert_eq!(d.observe(0.50), DriftVerdict::Tripped);
    assert_eq!(d.observe(1.00), DriftVerdict::Tripped);

    d.rearm();
    assert!(!d.is_tripped());
    assert_eq!(d.observe(1.00), DriftVerdict::Healthy);
}

/// A clear probe (≥ `clear_above`) resets the streak: breaches must be
/// *consecutive* to trip.
#[test]
fn clear_probe_resets_the_breach_streak() {
    let mut d = DriftDetector::new(cfg(0.90, 0.97, 2, 0));
    assert_eq!(d.observe(0.80), DriftVerdict::Suspect { breaches: 1 });
    assert_eq!(d.observe(0.99), DriftVerdict::Healthy);
    // Streak restarted: one more breach is Suspect{1} again, not a trip.
    assert_eq!(d.observe(0.80), DriftVerdict::Suspect { breaches: 1 });
    assert_eq!(d.observe(0.80), DriftVerdict::Drift);
}

/// Hysteresis: probes in `[trigger_below, clear_above)` neither extend
/// nor reset an in-progress streak — a route hovering at the boundary
/// holds `Suspect` indefinitely instead of flapping, and trips only if
/// it breaches again.
#[test]
fn hysteresis_band_holds_streak_without_flapping() {
    let mut d = DriftDetector::new(cfg(0.90, 0.97, 2, 0));
    assert_eq!(d.observe(0.85), DriftVerdict::Suspect { breaches: 1 });
    // Borderline probes: inside the band, streak held at 1 — not
    // cleared (would allow flapping), not extended (not a breach).
    for _ in 0..10 {
        assert_eq!(d.observe(0.93), DriftVerdict::Suspect { breaches: 1 });
    }
    assert!(!d.is_tripped(), "band probes must never trip");
    // A second genuine breach after the hover trips it.
    assert_eq!(d.observe(0.85), DriftVerdict::Drift);

    // With no streak in progress, band probes are plain Healthy.
    let mut d = DriftDetector::new(cfg(0.90, 0.97, 2, 0));
    assert_eq!(d.observe(0.93), DriftVerdict::Healthy);
    assert_eq!(d.observe(0.93), DriftVerdict::Healthy);
}

/// Cold-start grace: the first `grace_probes` observations are never
/// counted as breaches, and `rearm` restarts the window for the
/// repaired deployment.
#[test]
fn cold_start_grace_ignores_initial_breaches_and_rearm_restarts_it() {
    let mut d = DriftDetector::new(cfg(0.90, 0.97, 1, 2));
    // Terrible agreement during warmup: observed, never counted.
    assert_eq!(d.observe(0.0), DriftVerdict::Grace);
    assert_eq!(d.observe(0.0), DriftVerdict::Grace);
    assert!(!d.is_tripped());
    // First counted probe is healthy — the grace breaches left no streak.
    assert_eq!(d.observe(0.99), DriftVerdict::Healthy);
    // Now a real breach trips (breaches_to_trip = 1).
    assert_eq!(d.observe(0.0), DriftVerdict::Drift);

    d.rearm();
    // Fresh grace window after the repair.
    assert_eq!(d.observe(0.0), DriftVerdict::Grace);
    assert_eq!(d.observe(0.0), DriftVerdict::Grace);
    assert_eq!(d.observe(0.99), DriftVerdict::Healthy);
}

// --------------------------------------------------------- integration:
// real SimCard routes, deterministic defect draws.

fn trained(n_rows: usize) -> (Dataset, Dataset, Ensemble, HatParams) {
    let data = by_name("churn").unwrap().generate_n(n_rows);
    let split = data.split(0.8, 0.0, 97);
    let params = HatParams {
        deploy_bits: 4,
        gbdt: GbdtParams { n_rounds: 10, max_leaves: 8, ..Default::default() },
        retrain_passes: 2,
        ..Default::default()
    };
    let model = hat::train(&split.train, &params, None);
    (split.train, split.test, model, params)
}

/// A defect-free card must never trip the monitor: canary agreement is
/// 1.0 by determinism (the route serves the same engine the references
/// were pinned from), so every post-grace probe is `Healthy`.
#[test]
fn defect_free_card_never_false_positives() {
    let (_, eval, model, _) = trained(800);
    let program = compile(&model, &CompileOptions::default()).unwrap();

    let fleet = Fleet::new();
    let injector = DefectInjector::new();
    let backend = SimCardBackend::new(&program, &ChipConfig::default(), &CardConfig::default())
        .with_injector(injector.clone());
    fleet
        .register_backends(
            "churn",
            vec![Box::new(backend) as Box<dyn Backend>],
            Vec::new(),
            ModelConfig::for_program(&program),
        )
        .unwrap();

    let canary_rows: Vec<Vec<f32>> = (0..48).map(|i| eval.row(i).to_vec()).collect();
    let canary = CanarySet::pin(&fleet, "churn", canary_rows).unwrap();
    let mut monitor = HealthMonitor::new(canary, DriftConfig::default());

    for probe in 0..10 {
        let reading = monitor.probe(&fleet, "churn").unwrap();
        assert_eq!(reading.agreement, 1.0, "probe {probe}");
        assert_eq!(reading.effective_agreement, 1.0, "probe {probe}");
        assert_eq!(reading.error_delta, 0, "probe {probe}");
        let want = if probe < DriftConfig::default().grace_probes {
            DriftVerdict::Grace
        } else {
            DriftVerdict::Healthy
        };
        assert_eq!(reading.verdict, want, "probe {probe}");
    }
    assert!(!monitor.is_tripped());
    assert_eq!(injector.strikes_applied(), 0);
    fleet.shutdown();
}

/// Deterministic defect draw that provably drags canary agreement below
/// `trigger`: replayed offline through `CamEngine::with_defects` — the
/// exact engine the struck card switches to — so the integration test
/// cannot flake on a lucky draw.
fn drifting_draw(
    program: &xtime::compiler::CamProgram,
    canaries: &[Vec<f32>],
    trigger: f64,
) -> (DefectSpec, u64) {
    let clean = CamEngine::new(program);
    let reference: Vec<f32> =
        canaries.iter().map(|r| clean.predict(program, r)).collect();
    let spec = DefectSpec::memristor(0.25);
    for seed in 0xC0FE..0xC0FE + 32u64 {
        let defective = CamEngine::with_defects(program, spec, seed);
        let agree = canaries
            .iter()
            .zip(&reference)
            .filter(|(r, want)| defective.predict(program, r) == **want)
            .count();
        if (agree as f64) < trigger * canaries.len() as f64 {
            return (spec, seed);
        }
    }
    panic!("no defect draw in the candidate range disturbs the canaries");
}

/// One full autonomous cycle: healthy serving (confident, undegraded
/// replies) → mid-serve defect strike → monitor breaches and trips →
/// [`SelfHealer::heal`] retrains against the live draw, verifies, swaps
/// under epoch CAS, proves contract-10 bit-identity — and the re-armed
/// monitor sees the repaired route healthy again.
#[test]
fn struck_card_trips_monitor_and_heal_restores_agreement() {
    let (train, eval, model, params) = trained(1200);
    let options = CompileOptions::default();
    let program = compile(&model, &options).unwrap();
    let chip = ChipConfig::default();
    let card = CardConfig::default();

    let fleet = Arc::new(Fleet::new());
    let injector = DefectInjector::new();
    let backend =
        SimCardBackend::new(&program, &chip, &card).with_injector(injector.clone());
    fleet
        .register_backends(
            "churn",
            vec![Box::new(backend) as Box<dyn Backend>],
            Vec::new(),
            ModelConfig::for_program(&program),
        )
        .unwrap();
    let epoch0 = fleet.route_epoch("churn").unwrap();

    // Healthy serving: confident (binary task ⇒ σ(β·|logit|) ≥ 0.5),
    // undegraded replies; degraded flag is observable when set.
    let reply = fleet.infer("churn", eval.row(0)).unwrap();
    assert!(reply.is_ok());
    assert!((0.5..=1.0).contains(&reply.confidence), "got {}", reply.confidence);
    assert!(!reply.degraded);
    fleet.set_degraded("churn", true).unwrap();
    assert!(fleet.infer("churn", eval.row(0)).unwrap().degraded);
    fleet.set_degraded("churn", false).unwrap();

    let canary_rows: Vec<Vec<f32>> = (0..48).map(|i| eval.row(i).to_vec()).collect();
    let drift_cfg = cfg(0.90, 0.97, 2, 0);
    let canary = CanarySet::pin(&fleet, "churn", canary_rows.clone()).unwrap();
    let mut monitor = HealthMonitor::new(canary, drift_cfg);
    assert_eq!(monitor.probe(&fleet, "churn").unwrap().verdict, DriftVerdict::Healthy);

    // Mid-serve defect strike: the card switches to the tracked
    // defective engine on its next batch.
    let (spec, seed) = drifting_draw(&program, &canary_rows, drift_cfg.trigger_below);
    injector.strike(spec, seed);

    let r1 = monitor.probe(&fleet, "churn").unwrap();
    assert!(r1.agreement < drift_cfg.trigger_below, "got {}", r1.agreement);
    assert_eq!(r1.verdict, DriftVerdict::Suspect { breaches: 1 });
    let r2 = monitor.probe(&fleet, "churn").unwrap();
    assert_eq!(r2.verdict, DriftVerdict::Drift);
    assert!(monitor.is_tripped());
    assert_eq!(monitor.probe(&fleet, "churn").unwrap().verdict, DriftVerdict::Tripped);
    assert_eq!(injector.live_draw(), Some((spec, seed)));

    // Repair: background retrain against the live draw, verify gate,
    // epoch-CAS swap, contract-10 bit-identity probe.
    let mut healer = SelfHealer::new(HealContext {
        fleet: fleet.clone(),
        model: "churn".to_string(),
        train,
        eval: eval.clone(),
        params,
        options,
        chip,
        card,
        batch_policy: BatchPolicy::default(),
        queue_cap: DEFAULT_QUEUE_CAP,
        verify: VerifyPolicy::default(),
        store: None,
    });
    let (_repaired, new_injector, report) = healer.heal(model, &injector).unwrap();

    assert_eq!(report.defects, spec);
    assert_eq!(report.seed, seed);
    assert_eq!(report.old_epoch, epoch0);
    assert!(report.new_epoch > report.old_epoch, "swap must mint a fresh epoch");
    assert_eq!(fleet.route_epoch("churn"), Some(report.new_epoch));
    assert_eq!(report.bit_identity_rows, 64.min(eval.n_rows()));
    assert!(
        report.retrain.final_score >= report.retrain.initial_score,
        "retrain keeps the best pass: {} -> {}",
        report.retrain.initial_score,
        report.retrain.final_score
    );
    // The repaired card serves the same diagnosed draw (that is the
    // deployment the retrain optimized), with the degraded flag cleared.
    assert_eq!(new_injector.live_draw(), Some((spec, seed)));
    assert!(!fleet.infer("churn", eval.row(0)).unwrap().degraded);
    assert_eq!(healer.history().len(), 1);

    // Re-armed against the repaired deployment, the monitor is healthy:
    // references re-pinned, agreement 1.0 by determinism.
    monitor.rearm_with(&fleet, "churn").unwrap();
    assert!(!monitor.is_tripped());
    let reading = monitor.probe(&fleet, "churn").unwrap();
    assert_eq!(reading.agreement, 1.0);
    assert_eq!(reading.verdict, DriftVerdict::Healthy);

    drop(healer);
    Arc::try_unwrap(fleet).ok().unwrap().shutdown();
}
