//! Static-verifier contract (ISSUE 7): the property suite proves every
//! real compile path produces verifier-clean programs (zero deny-level
//! findings — the verifier is a standing oracle over the compiler
//! surface), and the mutation suite proves each rule V1–V7 actually
//! fires, on exactly its own `RuleId`, under a deliberate corruption.
//! The fleet tests pin contract 8: `register_program`/`swap_program`
//! refuse a blocked program with a diagnostic and leave live routes
//! untouched.

use xtime::analysis::{self, RuleId, Severity, VerifyPolicy};
use xtime::bench_support::random_ensemble;
use xtime::cam::DefectSpec;
use xtime::compiler::{
    compile, compile_for_deploy, partition, CamEngine, CamProgram, CompileOptions,
    PartitionOptions,
};
use xtime::coordinator::{Fleet, ModelConfig};
use xtime::data::{by_name, Dataset, Task};
use xtime::trees::{gbdt, hat, rf, GbdtParams, HatParams, RfParams};

fn churn(n: usize) -> Dataset {
    by_name("churn").unwrap().generate_n(n)
}

fn gbdt_program(n_bits: u8) -> CamProgram {
    let d = churn(400);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 8, max_leaves: 16, n_bits, ..Default::default() },
        None,
    );
    compile(&m, &CompileOptions::default()).unwrap()
}

/// Zero deny findings at 1 and 2 shards, and the census is present.
fn assert_clean(p: &CamProgram, what: &str) {
    for shards in [1usize, 2] {
        let r = analysis::verify(p, shards);
        assert_eq!(
            r.deny_count(),
            0,
            "{what} ({shards} shard(s)) must verify clean, got: {:?}",
            r.findings
        );
        let census = r.census.as_ref().expect("census always emitted");
        assert_eq!(census.n_cores, p.cores.len());
        assert!(!r.findings_for(RuleId::V6SparsityCensus).is_empty());
    }
}

/// Every deny finding carries `rule` — the corruption fired exactly the
/// rule under test, not a neighbor.
fn assert_denies_only(r: &analysis::AnalysisReport, rule: RuleId, what: &str) {
    assert!(r.deny_count() > 0, "{what}: corruption must produce deny findings");
    for f in &r.findings {
        if f.severity == Severity::Deny {
            assert_eq!(f.rule, rule, "{what}: unexpected rule fired: {f}");
        }
    }
}

// ---------------------------------------------------------------- property

/// The verifier-clean oracle over the compile surface: GBDT and RF,
/// direct compile and PTQ requantization (4/6/8-bit), hardware-aware
/// training, multiclass, sharded and unsharded.
#[test]
fn all_compile_paths_verify_clean() {
    let d = churn(400);
    let m8 = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 8, max_leaves: 16, ..Default::default() },
        None,
    );
    assert_clean(&compile(&m8, &CompileOptions::default()).unwrap(), "gbdt 8-bit");
    for bits in [4u8, 6] {
        let (p, _) = compile_for_deploy(&m8, bits, &CompileOptions::default()).unwrap();
        assert_clean(&p, &format!("gbdt PTQ {bits}-bit"));
    }

    let mrf = rf::train(&d, &RfParams { n_estimators: 8, max_leaves: 16, ..Default::default() });
    assert_clean(&compile(&mrf, &CompileOptions::default()).unwrap(), "rf 8-bit");
    let (prf4, _) = compile_for_deploy(&mrf, 4, &CompileOptions::default()).unwrap();
    assert_clean(&prf4, "rf PTQ 4-bit");

    let mhat = hat::train(
        &d,
        &HatParams {
            deploy_bits: 4,
            gbdt: GbdtParams { n_rounds: 6, max_leaves: 16, ..Default::default() },
            ..Default::default()
        },
        None,
    );
    let (phat, rep) = compile_for_deploy(&mhat, 4, &CompileOptions::default()).unwrap();
    rep.assert_lossless("hat 4-bit deploy");
    assert_clean(&phat, "hat 4-bit");

    let msyn = random_ensemble(12, 4, 10, Task::MultiClass(3), 5);
    assert_clean(&compile(&msyn, &CompileOptions::default()).unwrap(), "synthetic multiclass");
}

/// Defect draws may kill rows (V5 warnings) but never produce deny
/// findings: the perturbed plan is rebuilt from the perturbed cells, so
/// it stays self-consistent under V1/V2.
#[test]
fn defect_draws_warn_but_never_deny() {
    let p = gbdt_program(8);
    for seed in 0..4 {
        let r = analysis::verify_with_defects(&p, DefectSpec::memristor(2.0), seed);
        assert_eq!(r.deny_count(), 0, "defect draw {seed}: {:?}", r.findings);
        for f in &r.findings {
            if f.severity == Severity::Warn {
                assert_eq!(f.rule, RuleId::V5DeadLeaf);
            }
        }
    }
}

// ---------------------------------------------------------------- mutations

/// V1: one corrupted LUT entry — level→interval resolution disagrees
/// with the interval bounds at exactly that (core, feature, level).
#[test]
fn mutation_corrupt_lut_entry_fires_v1() {
    let p = gbdt_program(8);
    let mut engine = CamEngine::new(&p);
    engine.corrupt_lut_entry(0, 0, 100);
    let r = analysis::verify_engine(&p, &engine, None);
    assert_denies_only(&r, RuleId::V1IntervalPartition, "lut corruption");
    let f = r.findings_for(RuleId::V1IntervalPartition)[0];
    assert_eq!(f.location.core, Some(0));
    assert_eq!(f.location.feature, Some(0));
    assert_eq!(f.location.interval, Some(100));
}

/// V2: one arena offset pointing past the arena — bounds violation on
/// exactly that feature, no other rule disturbed (bounds and LUT are
/// untouched by the corruption).
#[test]
fn mutation_corrupt_arena_offset_fires_v2() {
    let p = gbdt_program(8);
    let mut engine = CamEngine::new(&p);
    engine.corrupt_arena_offset(0, 0);
    let r = analysis::verify_engine(&p, &engine, None);
    assert_denies_only(&r, RuleId::V2ArenaBounds, "arena offset corruption");
    assert!(r
        .findings_for(RuleId::V2ArenaBounds)
        .iter()
        .all(|f| f.location.core == Some(0) && f.location.feature == Some(0)));
}

/// V2 padding: a single stray bit above `n_rows` in an interval bitset
/// (a phantom row on the planned path) is caught.
#[test]
fn mutation_padding_bit_fires_v2() {
    // A core only has padding bits when its row count is not a multiple
    // of 64, so scan a few ensemble sizes rather than betting one
    // trainer's exact leaf count never lands on 64/128/192.
    let d = churn(400);
    let (p, engine, ci) = (5..12)
        .find_map(|rounds| {
            let m = gbdt::train(
                &d,
                &GbdtParams { n_rounds: rounds, max_leaves: 16, ..Default::default() },
                None,
            );
            let p = compile(&m, &CompileOptions::default()).unwrap();
            let mut engine = CamEngine::new(&p);
            let ci = (0..engine.n_cores()).find(|&ci| engine.set_arena_padding_bit(ci))?;
            Some((p, engine, ci))
        })
        .expect("some ensemble size yields a core with padding bits");
    let r = analysis::verify_engine(&p, &engine, None);
    assert_denies_only(&r, RuleId::V2ArenaBounds, "padding bit");
    let f = r.findings_for(RuleId::V2ArenaBounds)[0];
    assert_eq!(f.location.core, Some(ci));
    assert_eq!(f.location.interval, Some(0));
}

/// V3: a lost tree, a duplicated tree, and a dropped shard program each
/// break the exact-partition contract — and nothing else.
#[test]
fn mutation_shard_tampering_fires_v3() {
    let p = gbdt_program(8);
    let plan = partition(&p, 2, &PartitionOptions::default()).unwrap();
    assert_eq!(analysis::verify_shard_plan(&p, &plan).deny_count(), 0);

    let mut lost = plan.clone();
    let dropped = lost.assignment[0].pop().expect("shard 0 owns trees");
    let r = analysis::verify_shard_plan(&p, &lost);
    assert_denies_only(&r, RuleId::V3ShardPartition, "lost tree");
    assert!(r.findings.iter().any(|f| f.location.tree == Some(dropped)));

    let mut dup = plan.clone();
    let stolen = dup.assignment[0][0];
    dup.assignment[1].push(stolen);
    let r = analysis::verify_shard_plan(&p, &dup);
    assert_denies_only(&r, RuleId::V3ShardPartition, "duplicated tree");

    let mut short = plan.clone();
    short.shards.pop();
    let r = analysis::verify_shard_plan(&p, &short);
    assert_denies_only(&r, RuleId::V3ShardPartition, "dropped shard");
}

/// V4: a desynced (duplicated) quantizer cut and an off-grid window
/// bound each fire the grid rule alone — the engine rebuilt from the
/// tampered program stays V1/V2-consistent, so only V4 sees the lie.
#[test]
fn mutation_desynced_cut_fires_v4() {
    let mut p = gbdt_program(8);
    let f = (0..p.n_features)
        .find(|&f| p.quantizer.edges[f].len() >= 2)
        .expect("some feature has >= 2 cuts");
    p.quantizer.edges[f][1] = p.quantizer.edges[f][0];
    let r = analysis::verify_program(&p);
    assert_denies_only(&r, RuleId::V4QuantizerGrid, "duplicated cut");
    assert!(r
        .findings_for(RuleId::V4QuantizerGrid)
        .iter()
        .any(|fi| fi.location.feature == Some(f)));

    let mut p = gbdt_program(8);
    let cuts = p.quantizer.edges[0].len() as u16;
    assert!(cuts + 1 < p.n_bins, "off-grid bound must stay constrained");
    p.cores[0].rows[0].lo[0] = 0;
    p.cores[0].rows[0].hi[0] = cuts + 1; // one past the last grid index
    let r = analysis::verify_program(&p);
    assert_denies_only(&r, RuleId::V4QuantizerGrid, "off-grid bound");
}

/// V5: a heavy memristor draw kills at least one row on some seed; the
/// dead row is a warning (with row/tree location), never a deny.
#[test]
fn mutation_defect_draw_fires_v5() {
    let p = gbdt_program(8);
    let spec = DefectSpec::memristor(25.0);
    let fired = (0..50).find_map(|seed| {
        let r = analysis::verify_with_defects(&p, spec, seed);
        (r.warn_count() > 0).then_some(r)
    });
    let r = fired.expect("25% defects must kill a row on some seed");
    assert_eq!(r.deny_count(), 0);
    let warns = r.findings_for(RuleId::V5DeadLeaf);
    assert!(!warns.is_empty());
    assert!(warns.iter().all(|f| f.location.row.is_some() && f.location.core.is_some()));
    assert!(warns[0].message.contains("defect draw"));
    assert_eq!(r.census.as_ref().unwrap().never_match_rows, warns.len());
}

/// V6: wildcarding a previously-constrained row moves the census — the
/// sparsity numbers measure the cells, not a cached summary.
#[test]
fn mutation_wildcarded_row_moves_v6_census() {
    let p = gbdt_program(8);
    let before = analysis::verify_program(&p).census.unwrap();
    let mut open = p.clone();
    let row = &mut open.cores[0].rows[0];
    for f in 0..open.n_features {
        row.lo[f] = 0;
        row.hi[f] = open.n_bins;
    }
    let after = analysis::verify_program(&open).census.unwrap();
    assert!(
        after.wildcard_cells > before.wildcard_cells,
        "census must register the opened row ({} -> {})",
        before.wildcard_cells,
        after.wildcard_cells
    );
    assert_eq!(after.n_cells, before.n_cells);
}

// ------------------------------------------------------------- V7 mutations

fn compressed_gbdt_program(n_bits: u8) -> CamProgram {
    let d = churn(400);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 8, max_leaves: 16, n_bits, ..Default::default() },
        None,
    );
    compile(&m, &CompileOptions { compress: true, ..Default::default() }).unwrap()
}

/// The verifier-clean oracle extends to compression (ISSUE 10): every
/// compile path that verifies clean uncompressed also verifies clean
/// with the capacity-compression pass on — V7 runs and finds nothing.
#[test]
fn compressed_compile_paths_verify_clean() {
    let opts = CompileOptions { compress: true, ..Default::default() };
    let d = churn(400);
    let m8 = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 8, max_leaves: 16, ..Default::default() },
        None,
    );
    assert_clean(&compile(&m8, &opts).unwrap(), "compressed gbdt 8-bit");
    for bits in [4u8, 6] {
        let (p, _) = compile_for_deploy(&m8, bits, &opts).unwrap();
        assert_clean(&p, &format!("compressed gbdt PTQ {bits}-bit"));
    }
    let mrf = rf::train(&d, &RfParams { n_estimators: 8, max_leaves: 16, ..Default::default() });
    assert_clean(&compile(&mrf, &opts).unwrap(), "compressed rf 8-bit");
    let msyn = random_ensemble(12, 4, 10, Task::MultiClass(3), 5);
    assert_clean(&compile(&msyn, &opts).unwrap(), "compressed synthetic multiclass");
    let mone = random_ensemble(6, 0, 8, Task::Binary, 3);
    assert_clean(&compile(&mone, &opts).unwrap(), "compressed single-leaf ensemble");
}

/// Defect draws on a compressed program still never deny: V7's dedup
/// check recomputes interval membership from the *perturbed* cells, so
/// the perturbed plan stays self-consistent (same contract as V1/V2).
#[test]
fn compressed_defect_draws_never_deny() {
    let p = compressed_gbdt_program(8);
    for seed in 0..4 {
        let r = analysis::verify_with_defects(&p, DefectSpec::memristor(2.0), seed);
        assert_eq!(r.deny_count(), 0, "compressed defect draw {seed}: {:?}", r.findings);
    }
}

/// V7 packing disjointness: force two units that constrain the same
/// feature into one physical word — the packed row is corrupt (two
/// owners for one cell) and V7 must say so, at exactly that
/// (core, feature, word), with no other rule disturbed (V1–V6 never
/// read the layout annotation).
#[test]
fn mutation_overlapping_packed_units_fire_v7() {
    let mut p = compressed_gbdt_program(8);
    // Find two units in different words sharing a constrained feature.
    let layouts = p.layouts.as_ref().expect("compressed program carries layouts");
    let (ci, ua, ub, f) = p
        .cores
        .iter()
        .enumerate()
        .find_map(|(ci, core)| {
            let l = &layouts[ci];
            for ua in 0..l.units.len() {
                for ub in ua + 1..l.units.len() {
                    if l.word_of_unit[ua] == l.word_of_unit[ub] {
                        continue;
                    }
                    let ca = l.unit_constrained(ua, &core.rows, p.n_bins);
                    let cb = l.unit_constrained(ub, &core.rows, p.n_bins);
                    if let Some(&f) = ca.iter().find(|f| cb.contains(*f)) {
                        return Some((ci, ua, ub, f));
                    }
                }
            }
            None
        })
        .expect("some pair of units contends for a feature cell");
    let w = layouts[ci].word_of_unit[ua];
    p.layouts.as_mut().unwrap()[ci].word_of_unit[ub] = w;
    let r = analysis::verify_program(&p);
    assert_denies_only(&r, RuleId::V7CompressedEquivalence, "overlapping packed units");
    let overlap = r
        .findings
        .iter()
        .find(|fi| fi.message.contains("overlapping constrained features"))
        .expect("disjointness finding present");
    assert_eq!(overlap.location.core, Some(ci));
    assert_eq!(overlap.location.feature, Some(f));
    assert_eq!(overlap.location.row, Some(w as usize), "word index is the row coordinate");
    assert!(overlap.message.contains(&format!("{ub}")), "{}", overlap.message);
}

/// V7 word-image fidelity: bump one owned cell's union bound in the
/// physical image — the packed row no longer equals the union of its
/// owning logical rows, and V7 reports the exact (core, feature, word)
/// with both the held and the recomputed window.
#[test]
fn mutation_wrong_union_bounds_fires_v7() {
    let mut p = compressed_gbdt_program(8);
    let layouts = p.layouts.as_mut().expect("layouts");
    let (ci, w, f) = layouts
        .iter()
        .enumerate()
        .find_map(|(ci, l)| {
            l.words.iter().enumerate().find_map(|(w, word)| {
                (0..word.owner.len())
                    .find(|&f| word.owner[f] >= 0 && word.hi[f] > word.lo[f])
                    .map(|f| (ci, w, f))
            })
        })
        .expect("some physical word has an owned, non-empty cell");
    layouts[ci].words[w].hi[f] -= 1; // narrower than the owning rows' union
    let r = analysis::verify_program(&p);
    assert_denies_only(&r, RuleId::V7CompressedEquivalence, "wrong union bounds");
    let bad = r
        .findings
        .iter()
        .find(|fi| fi.message.contains("wrong union bounds"))
        .expect("fidelity finding present");
    assert_eq!(bad.location.core, Some(ci));
    assert_eq!(bad.location.feature, Some(f));
    assert_eq!(bad.location.row, Some(w));
}

/// V7 dedup equivalence: remap one slot of the deduplicated arena to a
/// different slice — the slice a query resolves to diverges from the
/// match set recomputed from the programmed cells. This is the only
/// rule that checks arena slice *content*, so exactly V7 fires.
#[test]
fn mutation_corrupt_dedup_slot_fires_v7() {
    let p = compressed_gbdt_program(8);
    let mut engine = CamEngine::new(&p);
    let ci = (0..engine.n_cores())
        .find(|&ci| engine.corrupt_dedup_slot(ci))
        .expect("some core has more than one distinct arena slice");
    let r = analysis::verify_engine(&p, &engine, None);
    assert_denies_only(&r, RuleId::V7CompressedEquivalence, "dedup slot corruption");
    let bad = r
        .findings
        .iter()
        .find(|fi| fi.message.contains("diverges from the match set"))
        .expect("dedup finding present");
    assert_eq!(bad.location.core, Some(ci));
    assert_eq!(bad.location.feature, Some(0), "hook remaps feature 0");
    assert_eq!(bad.location.interval, Some(0), "hook remaps interval 0");
}

/// V7 coverage: orphan a logical row from the unit map — its leaf would
/// vanish from the physical image. Also pins the layout/core count
/// consistency deny when a core's layout is dropped wholesale.
#[test]
fn mutation_dropped_unit_coverage_fires_v7() {
    let mut p = compressed_gbdt_program(8);
    {
        let layouts = p.layouts.as_mut().expect("layouts");
        // Point row 0's unit elsewhere without touching the unit list:
        // unit 0 still claims row 0, so the map and the units disagree.
        let l = &mut layouts[0];
        l.unit_of_row[0] = (l.units.len() as u32).saturating_sub(1).max(1);
    }
    let r = analysis::verify_program(&p);
    assert_denies_only(&r, RuleId::V7CompressedEquivalence, "unit map tampering");

    let mut short = compressed_gbdt_program(8);
    if short.cores.len() > 1 {
        short.layouts.as_mut().unwrap().pop();
        let r = analysis::verify_program(&short);
        assert_denies_only(&r, RuleId::V7CompressedEquivalence, "short layout vector");
        assert!(
            r.findings.iter().any(|f| f.message.contains("compression layouts")),
            "{:?}",
            r.findings
        );
    }
}

// ---------------------------------------------------------------- contract 8

/// `register_program` refuses a corrupted program with the worst
/// finding in the diagnostic; `VerifyPolicy::Skip` trusts the compiler.
#[test]
fn fleet_refuses_corrupted_program() {
    let mut p = gbdt_program(8);
    let f = (0..p.n_features).find(|&f| p.quantizer.edges[f].len() >= 2).unwrap();
    p.quantizer.edges[f][1] = p.quantizer.edges[f][0];

    let fleet = Fleet::new();
    let err = fleet
        .register_program("bad", &p, ModelConfig::for_program(&p))
        .expect_err("deny-level program must be refused");
    assert!(err.contains("static verifier refused"), "diagnostic: {err}");
    assert!(err.contains("V4"), "diagnostic names the rule: {err}");
    assert!(fleet.models().is_empty());

    fleet
        .register_program(
            "trusted",
            &p,
            ModelConfig::for_program(&p).with_verify(VerifyPolicy::Skip),
        )
        .expect("Skip policy bypasses the gate");
    fleet.shutdown();
}

/// A refused swap leaves the live route serving the old program.
#[test]
fn refused_swap_leaves_live_route_serving() {
    let good = gbdt_program(8);
    let mut bad = good.clone();
    let f = (0..bad.n_features).find(|&f| bad.quantizer.edges[f].len() >= 2).unwrap();
    bad.quantizer.edges[f][1] = bad.quantizer.edges[f][0];

    let fleet = Fleet::new();
    fleet.register_program("m", &good, ModelConfig::for_program(&good)).unwrap();
    let err = fleet
        .swap_program("m", &bad, ModelConfig::for_program(&bad))
        .expect_err("corrupted replacement must be refused");
    assert!(err.contains("V4"), "diagnostic: {err}");
    // The old program still serves.
    let row = vec![0.5; good.n_features];
    let reply = fleet.infer("m", &row).unwrap();
    assert!(reply.prediction.is_finite());
    fleet.shutdown();
}

/// Severity policy: a dead row (V5 warning) passes `DenyErrors` but is
/// refused under `DenyWarnings`.
#[test]
fn deny_warnings_policy_blocks_dead_rows() {
    let mut p = gbdt_program(8);
    // Close one window in place (lo = hi, both on-grid): never-match
    // row, structurally valid everywhere else.
    let (ci, ri, f) = p
        .cores
        .iter()
        .enumerate()
        .find_map(|(ci, core)| {
            core.rows.iter().enumerate().find_map(|(ri, row)| {
                (0..p.n_features)
                    .find(|&f| row.hi[f] >= 1 && row.hi[f] < p.n_bins)
                    .map(|f| (ci, ri, f))
            })
        })
        .expect("some row has a constrained upper bound");
    p.cores[ci].rows[ri].lo[f] = p.cores[ci].rows[ri].hi[f];

    let r = analysis::verify_program(&p);
    assert_eq!(r.deny_count(), 0, "{:?}", r.findings);
    assert!(!r.findings_for(RuleId::V5DeadLeaf).is_empty());

    let fleet = Fleet::new();
    fleet
        .register_program("lenient", &p, ModelConfig::for_program(&p))
        .expect("DenyErrors tolerates warnings");
    let err = fleet
        .register_program(
            "strict",
            &p,
            ModelConfig::for_program(&p).with_verify(VerifyPolicy::DenyWarnings),
        )
        .expect_err("DenyWarnings refuses dead rows");
    assert!(err.contains("V5"), "diagnostic: {err}");
    fleet.shutdown();
}

// ---------------------------------------------------------------- degenerate

/// Single-leaf trees compile to fully-wildcard rows: zero interval
/// bounds per feature, LUTs all zero — must verify clean, not trip V1.
#[test]
fn single_leaf_trees_verify_clean() {
    let m = random_ensemble(6, 0, 8, Task::Binary, 3);
    let p = compile(&m, &CompileOptions::default()).unwrap();
    assert_clean(&p, "single-leaf ensemble");
    let census = analysis::verify_program(&p).census.unwrap();
    assert_eq!(census.wildcard_cells, census.n_cells, "every cell is a wildcard");
}

/// A constant feature yields an empty cut list; no tree can split on
/// it, so the empty grid is never referenced — must verify clean, not
/// trip V4.
#[test]
fn constant_feature_verifies_clean() {
    let mut d = churn(300);
    for r in 0..d.n_rows() {
        d.x[r * d.n_features] = 0.5;
    }
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 6, max_leaves: 16, ..Default::default() },
        None,
    );
    let p = compile(&m, &CompileOptions::default()).unwrap();
    assert!(p.quantizer.edges[0].is_empty(), "constant feature has no cuts");
    assert_clean(&p, "constant-feature program");
}

/// The `snap_threshold` empty-grid convention (bin 1 on a feature with
/// no deploy cuts) is on-grid by the satellite-6 allowance — without
/// it this shape would trip V4.
#[test]
fn empty_grid_snap_convention_verifies_clean() {
    let mut p = gbdt_program(8);
    p.quantizer.edges[0] = Vec::new();
    for core in &mut p.cores {
        for row in &mut core.rows {
            row.lo[0] = 0;
            row.hi[0] = p.n_bins;
        }
    }
    // One row carries the snapped degenerate threshold: bin 1.
    p.cores[0].rows[0].lo[0] = 1;
    let r = analysis::verify_program(&p);
    assert_eq!(r.deny_count(), 0, "{:?}", r.findings);
}
