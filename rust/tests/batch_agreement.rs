//! Batched-vs-scalar bit-identity: the indexed batch path
//! (`CamEngine::partials_batch` / `infer_batch`) *and* the planned path
//! (`partials_planned` / `infer_planned`, at thread counts 1/2/8) must
//! reproduce the row-at-a-time scalar engine *exactly* — f64 partials,
//! f32 logits, decisions and `SearchStats` counts — across tasks,
//! program precisions, defect draws and sharded plans. This is the
//! contract every serving backend now rides on (DESIGN.md §5,
//! docs/adr/002-planned-execution.md), so the comparison is
//! `assert_eq!` on raw floats, not a tolerance.

use xtime::bench_support::{random_ensemble, random_query_bins, sharded_functional_pool};
use xtime::cam::DefectSpec;
use xtime::compiler::{compile, partition, CamEngine, CompileOptions, PartitionOptions};
use xtime::coordinator::{Backend, BatchPolicy, CpuExactBackend, FunctionalBackend};
use xtime::data::{by_name, Task};
use xtime::sim::{CardConfig, ChipConfig, SimCardBackend};
use xtime::trees::{gbdt, rf, GbdtParams, RfParams};
use xtime::util::prop;

/// Thread counts the planned path is pinned at everywhere: single
/// worker, a split, and more workers than most test programs have cores
/// (exercising the clamp).
const THREADS: [usize; 3] = [1, 2, 8];

/// Exact agreement of one engine's indexed, planned (all pinned thread
/// counts) and scalar paths on `batch`. Returns an `Err` witness for
/// `prop::check` instead of asserting, so failures report the
/// replayable iteration.
fn batch_agrees(e: &CamEngine, batch: &[Vec<u16>], label: &str) -> prop::PropResult {
    let (partials, stats) = e.partials_batch_stats(batch);
    let logits = e.infer_batch(batch);
    let (mut charged, mut matches) = (0usize, 0usize);
    for (i, bins) in batch.iter().enumerate() {
        prop::require(
            partials[i] == e.partials_bins(bins),
            format!("{label}: row {i} partials diverged"),
        )?;
        let (want, s) = e.infer_bins_stats(bins);
        prop::require(logits[i] == want, format!("{label}: row {i} logits diverged"))?;
        prop::require(
            e.decide(&logits[i]) == e.decide(&want),
            format!("{label}: row {i} decision diverged"),
        )?;
        charged += s.charged_rows;
        matches += s.matches;
    }
    prop::require(
        stats.charged_rows == charged,
        format!("{label}: charged_rows {} vs scalar {charged}", stats.charged_rows),
    )?;
    prop::require(
        stats.matches == matches,
        format!("{label}: matches {} vs scalar {matches}", stats.matches),
    )?;
    // The planned path must agree for every thread count — partials,
    // logits and stats, bit for bit (determinism contract, ADR-002).
    for &threads in &THREADS {
        let (pp, ps) = e.partials_planned_stats(batch, threads);
        prop::require(
            pp == partials,
            format!("{label}: planned({threads}T) partials diverged"),
        )?;
        prop::require(
            e.infer_planned(batch, threads) == logits,
            format!("{label}: planned({threads}T) logits diverged"),
        )?;
        prop::require(
            (ps.charged_rows, ps.matches) == (charged, matches),
            format!(
                "{label}: planned({threads}T) stats ({}, {}) vs scalar ({charged}, {matches})",
                ps.charged_rows, ps.matches
            ),
        )?;
    }
    Ok(())
}

/// Random bin batch straight from the generator — exercises bin-space
/// edges (0 and n_bins−1) more aggressively than data-driven rows.
fn random_bin_batch(
    g: &mut prop::Gen,
    n_features: usize,
    n_bins: usize,
    rows: usize,
) -> Vec<Vec<u16>> {
    (0..rows)
        .map(|_| (0..n_features).map(|_| g.usize_in(0, n_bins) as u16).collect())
        .collect()
}

#[test]
fn batched_equals_scalar_binary_8bit() {
    let d = by_name("churn").unwrap().generate_n(1200);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 12, max_leaves: 16, ..Default::default() },
        None,
    );
    let p = compile(&m, &CompileOptions::default()).unwrap();
    let e = CamEngine::new(&p);
    prop::check(40, 0xBA7C4ED, |g| {
        let batch = random_bin_batch(g, p.n_features, p.n_bins as usize, g.usize_in(1, 17));
        batch_agrees(&e, &batch, "binary-8bit")
    });
}

#[test]
fn batched_equals_scalar_multiclass_multicore() {
    let d = by_name("eye").unwrap().generate_n(1000);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 9, max_leaves: 16, ..Default::default() },
        None,
    );
    // Small cores force multi-core placement and in-network reduction.
    let p = compile(&m, &CompileOptions { core_rows: 48, ..Default::default() }).unwrap();
    assert!(p.cores_per_replica() > 1);
    let e = CamEngine::new(&p);
    prop::check(30, 0xEE7E, |g| {
        let batch = random_bin_batch(g, p.n_features, p.n_bins as usize, g.usize_in(1, 13));
        batch_agrees(&e, &batch, "multiclass")
    });
}

#[test]
fn batched_equals_scalar_regression_rf() {
    let d = by_name("rossmann").unwrap().generate_n(900);
    let m = rf::train(&d, &RfParams { n_estimators: 8, max_leaves: 32, ..Default::default() });
    let p = compile(&m, &CompileOptions::default()).unwrap();
    let e = CamEngine::new(&p);
    prop::check(30, 0x2E62E55, |g| {
        let batch = random_bin_batch(g, p.n_features, p.n_bins as usize, g.usize_in(1, 13));
        batch_agrees(&e, &batch, "regression")
    });
}

#[test]
fn batched_equals_scalar_4bit_program() {
    let d = by_name("telco").unwrap().generate_n(800);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 6, max_leaves: 8, n_bits: 4, ..Default::default() },
        None,
    );
    let p = compile(&m, &CompileOptions::default()).unwrap();
    assert_eq!(p.n_bins, 16);
    let e = CamEngine::new(&p);
    prop::check(40, 0x4B17, |g| {
        let batch = random_bin_batch(g, p.n_features, p.n_bins as usize, g.usize_in(1, 17));
        batch_agrees(&e, &batch, "4bit")
    });
}

#[test]
fn batched_equals_scalar_under_defects() {
    // The interval index is built from the defect-perturbed cells and
    // applies the same per-core DAC offsets, so bit-identity must hold
    // for every defect draw, not just clean engines.
    let d = by_name("churn").unwrap().generate_n(1000);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 10, max_leaves: 16, ..Default::default() },
        None,
    );
    let p = compile(&m, &CompileOptions::default()).unwrap();
    prop::check(12, 0xDEFEC7ED, |g| {
        let spec = DefectSpec {
            memristor_pct: g.f64_unit() * 0.3,
            dac_pct: g.f64_unit() * 0.2,
        };
        let e = CamEngine::with_defects(&p, spec, g.u64());
        let batch = random_bin_batch(g, p.n_features, p.n_bins as usize, 8);
        batch_agrees(&e, &batch, "defects")
    });
}

#[test]
fn batched_shards_reproduce_unsharded_logits() {
    // Shard engines answer batched; summing their f64 partials in shard
    // order and applying the base once must equal the unsharded engine
    // bit for bit (the sharding contract now served by the batched path).
    let model = random_ensemble(256, 4, 16, Task::Binary, 11);
    let program = compile(&model, &CompileOptions::default()).unwrap();
    let reference = CamEngine::new(&program);
    let plan = partition(&program, 3, &PartitionOptions::default()).unwrap();
    let shard_engines: Vec<CamEngine> = plan.shards.iter().map(CamEngine::new).collect();

    let batch = random_query_bins(&program, 32, 0x5AFE);
    // Per-shard batched partials, then the dispatcher's aggregation.
    let per_shard: Vec<Vec<Vec<f64>>> =
        shard_engines.iter().map(|e| e.partials_batch(&batch)).collect();
    // Planned shard workers produce the identical partials (any thread
    // count), so the sharding contract transfers to the planned path.
    for (s, e) in shard_engines.iter().enumerate() {
        assert_eq!(e.partials_planned(&batch, 2), per_shard[s], "shard {s} planned partials");
    }
    for (i, bins) in batch.iter().enumerate() {
        let mut total = vec![0f64; reference.n_outputs];
        for shard in &per_shard {
            for (k, v) in shard[i].iter().enumerate() {
                total[k] += v;
            }
        }
        let logits: Vec<f32> = total
            .iter()
            .zip(plan.base_score.iter().chain(std::iter::repeat(&0.0)))
            .map(|(&t, &b)| t as f32 + b)
            .collect();
        assert_eq!(logits, reference.infer_bins(bins), "row {i}");
    }
    // And each shard engine itself is batched-vs-scalar clean.
    for (s, e) in shard_engines.iter().enumerate() {
        batch_agrees(e, &batch, &format!("shard {s}")).unwrap();
    }
}

/// Regression (ISSUE 4 satellite): query scaling routes through the
/// shared saturating `dac_level` conversion. A raw `b * scale` multiply
/// once wrapped/panicked (u16 overflow) on out-of-range bins; now every
/// path saturates at DAC full scale and they all agree at the
/// boundaries — bin 0, the top in-range bin, the first out-of-range
/// bin, and u16::MAX (which used to overflow the multiply outright on
/// sub-8-bit programs).
#[test]
fn bin_boundaries_agree_across_paths() {
    for n_bits in [4u8, 8] {
        let d = by_name("telco").unwrap().generate_n(700);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 5, max_leaves: 8, n_bits, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let e = CamEngine::new(&p);
        let nf = p.n_features;
        let max_bin = p.n_bins - 1;
        let mut batch: Vec<Vec<u16>> = vec![
            vec![0u16; nf],              // floor
            vec![max_bin; nf],           // top in-range bin
            vec![p.n_bins; nf],          // first out-of-range bin
            vec![u16::MAX; nf],          // saturating_mul territory
        ];
        // Mixed row: one boundary value per feature, cycling.
        batch.push(
            (0..nf)
                .map(|f| [0, max_bin, p.n_bins, u16::MAX][f % 4])
                .collect(),
        );
        batch_agrees(&e, &batch, &format!("{n_bits}-bit boundaries")).unwrap();
        // Out-of-range bins drive the saturated top DAC level and still
        // produce finite logits on every path.
        for (i, bins) in batch.iter().enumerate() {
            for l in e.infer_bins(bins) {
                assert!(l.is_finite(), "{n_bits}-bit row {i}: non-finite logit");
            }
        }
    }
}

#[test]
fn backends_agree_through_the_batched_path() {
    // CPU-exact, functional and sim-card backends (all now serving whole
    // batches) must agree: decisions across all three, and bit-identical
    // logits/partials between the two CamEngine-backed ones.
    let d = by_name("churn").unwrap().generate_n(1000);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 10, max_leaves: 16, ..Default::default() },
        None,
    );
    let p = compile(&m, &CompileOptions::default()).unwrap();
    let bins: Vec<Vec<u16>> = (0..40).map(|i| p.quantizer.bin_row(d.row(i))).collect();

    let mut cpu = CpuExactBackend { model: m };
    let mut cam = FunctionalBackend::new(&p);
    let mut card = SimCardBackend::new(&p, &ChipConfig::default(), &CardConfig::default());

    let cam_logits = cam.infer(&bins).unwrap();
    let card_logits = card.infer(&bins).unwrap();
    assert_eq!(cam_logits, card_logits, "functional vs sim-card logits");
    assert_eq!(
        cam.infer_partials(&bins).unwrap(),
        card.infer_partials(&bins).unwrap(),
        "functional vs sim-card partials"
    );
    assert_eq!(
        cpu.predict(&bins).unwrap(),
        cam.predict(&bins).unwrap(),
        "cpu vs functional decisions"
    );
}

#[test]
fn empty_batch_and_empty_latency_summary_are_guarded() {
    // `Summary::of`/`percentile_sorted` index into their slice; the
    // serving path must never feed them an empty one.
    let d = by_name("telco").unwrap().generate_n(600);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 4, max_leaves: 4, ..Default::default() },
        None,
    );
    let p = compile(&m, &CompileOptions::default()).unwrap();

    // Engine level.
    let e = CamEngine::new(&p);
    let (partials, stats) = e.partials_batch_stats(&[]);
    assert!(partials.is_empty());
    assert_eq!((stats.charged_rows, stats.matches), (0, 0));
    assert!(e.infer_batch(&[]).is_empty());

    // Backend level.
    let mut cam = FunctionalBackend::new(&p);
    assert!(cam.infer(&[]).unwrap().is_empty());
    assert!(cam.infer_partials(&[]).unwrap().is_empty());

    // Server level: a pool that has served nothing reports no latency
    // summary instead of panicking on an empty sample.
    let plan = partition(&p, 2, &PartitionOptions::default()).unwrap();
    let server = sharded_functional_pool(&plan, BatchPolicy::default());
    assert!(server.latency_summary().is_none());
    assert_eq!(server.stats().requests, 0);
    server.shutdown();
}
