//! Integration: the XLA/PJRT artifact path must agree with the functional
//! CAM engine and the exact CPU reference on real trained models.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use std::path::{Path, PathBuf};
use xtime::compiler::{compile, CamEngine, CompileOptions};
use xtime::data::by_name;
use xtime::runtime::XlaCamEngine;
use xtime::trees::{gbdt, rf, GbdtParams, RfParams};

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= 1e-3 * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn xla_matches_functional_and_cpu_binary() {
    let Some(dir) = artifacts() else { return };
    let d = by_name("churn").unwrap().generate_n(1200);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 12, max_leaves: 16, ..Default::default() },
        None,
    );
    let p = compile(&m, &CompileOptions::default()).unwrap();
    let xla = XlaCamEngine::new(&p, &dir, 8).expect("engine");
    let cam = CamEngine::new(&p);

    let rows: Vec<&[f32]> = (0..64).map(|i| d.row(i)).collect();
    let got = xla.infer_rows(&p, &rows).unwrap();
    for (i, row) in rows.iter().enumerate() {
        let want_cpu = m.logits(row);
        let want_cam = cam.infer_row(&p, row);
        assert!(close(got[i][0], want_cpu[0]), "row {i}: xla {} cpu {}", got[i][0], want_cpu[0]);
        assert!(close(got[i][0], want_cam[0]), "row {i}: xla {} cam {}", got[i][0], want_cam[0]);
    }
    let preds = xla.predict_rows(&p, &rows).unwrap();
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(preds[i], m.predict(row), "decision mismatch at {i}");
    }
}

#[test]
fn xla_matches_reference_multiclass_rf() {
    let Some(dir) = artifacts() else { return };
    let d = by_name("eye").unwrap().generate_n(900);
    let m = rf::train(&d, &RfParams { n_estimators: 6, max_leaves: 32, ..Default::default() });
    let p = compile(&m, &CompileOptions::default()).unwrap();
    let xla = XlaCamEngine::new(&p, &dir, 1).expect("engine");

    let rows: Vec<&[f32]> = (0..40).map(|i| d.row(i)).collect();
    let got = xla.infer_rows(&p, &rows).unwrap();
    for (i, row) in rows.iter().enumerate() {
        let want = m.logits(row);
        assert_eq!(got[i].len(), 3);
        for k in 0..3 {
            assert!(close(got[i][k], want[k]), "row {i} class {k}: {} vs {}", got[i][k], want[k]);
        }
    }
}

#[test]
fn xla_handles_max_feature_dataset() {
    let Some(dir) = artifacts() else { return };
    // gas: 129 features — exercises the F=130 bucket.
    let d = by_name("gas").unwrap().generate_n(700);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 3, max_leaves: 8, ..Default::default() },
        None,
    );
    let p = compile(&m, &CompileOptions::default()).unwrap();
    let xla = XlaCamEngine::new(&p, &dir, 64).expect("engine");
    assert!(xla.bucket().features >= 129);

    let rows: Vec<&[f32]> = (0..32).map(|i| d.row(i)).collect();
    let got = xla.infer_rows(&p, &rows).unwrap();
    for (i, row) in rows.iter().enumerate() {
        let want = m.logits(row);
        for k in 0..want.len() {
            assert!(close(got[i][k], want[k]), "row {i} class {k}");
        }
    }
}

#[test]
fn batch_chunking_is_transparent() {
    let Some(dir) = artifacts() else { return };
    let d = by_name("telco").unwrap().generate_n(600);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 5, max_leaves: 4, ..Default::default() },
        None,
    );
    let p = compile(&m, &CompileOptions::default()).unwrap();
    let xla = XlaCamEngine::new(&p, &dir, 8).expect("engine");
    let cap = xla.max_batch();
    // Request more rows than one device batch: results must equal the
    // row-by-row path.
    let rows: Vec<&[f32]> = (0..cap * 2 + 3).map(|i| d.row(i % d.n_rows())).collect();
    let batched = xla.infer_rows(&p, &rows).unwrap();
    for (i, row) in rows.iter().enumerate() {
        let single = xla.infer_rows(&p, &[row]).unwrap();
        assert_eq!(batched[i], single[0], "row {i}");
    }
}
