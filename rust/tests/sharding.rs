//! Integration: sharded serving must reproduce the unsharded path
//! exactly — same logits, same predictions — on paper-scale ensembles
//! (the acceptance bar for the multi-card serving engine).
//!
//! Why `assert_eq!` on f32 logits is sound here: leaf payloads are f32
//! (24-bit significands) of similar magnitude, so every f64 addition in
//! both the unsharded accumulation and the per-shard partial sums is
//! *exact* (a sum of ~2^14 such values needs well under f64's 53 bits).
//! Exact additions make the total independent of grouping, so splitting
//! the sum across shards and re-summing in shard order yields the same
//! f64 value, and the single final rounding (`sum as f32 + base`) is
//! shared by both paths. This holds for the functional/CPU/sim-card
//! backends; the XLA backend reduces in f32 and is only near-exact.

use xtime::bench_support::{random_ensemble, sharded_functional_pool};
use xtime::compiler::{
    compile, partition, CamEngine, CompileOptions, PartitionOptions, ShardStrategy,
};
use xtime::coordinator::{BatchPolicy, Server};
use xtime::data::{by_name, Task};
use xtime::trees::{gbdt, GbdtParams};
use xtime::util::Rng;

fn shard_servers(
    program: &xtime::compiler::CamProgram,
    n_shards: usize,
    strategy: ShardStrategy,
) -> Server {
    let plan = partition(
        program,
        n_shards,
        &PartitionOptions { strategy, ..Default::default() },
    )
    .expect("partition");
    sharded_functional_pool(&plan, BatchPolicy { max_wait_us: 200, max_batch: 32, threads: None })
}

/// The acceptance criterion: on a 1024-tree ensemble, sharded logits are
/// bit-identical to the unsharded functional engine for every shard count
/// and both placement strategies.
#[test]
fn sharded_logits_match_unsharded_1024_trees() {
    let model = random_ensemble(1024, 4, 16, Task::Binary, 21);
    let program = compile(&model, &CompileOptions::default()).unwrap();
    assert_eq!(program.n_trees, 1024);
    let reference = CamEngine::new(&program);

    let mut rng = Rng::new(77);
    let queries: Vec<Vec<u16>> = (0..24)
        .map(|_| {
            let row: Vec<f32> = (0..program.n_features).map(|_| rng.f32()).collect();
            program.quantizer.bin_row(&row)
        })
        .collect();

    for strategy in [ShardStrategy::BalancedRows, ShardStrategy::BalancedTrees] {
        for n_shards in [2usize, 3, 5] {
            let server = shard_servers(&program, n_shards, strategy);
            for (i, bins) in queries.iter().enumerate() {
                let reply = server.infer_blocking(bins.clone());
                let want = reference.infer_bins(bins);
                assert_eq!(
                    reply.logits, want,
                    "{strategy:?} × {n_shards} shards, query {i}: logits drifted"
                );
                assert_eq!(reply.prediction, reference.decide(&want));
            }
            let stats = server.stats();
            assert_eq!(stats.errors, 0);
            assert_eq!(stats.shards.len(), n_shards);
            server.shutdown();
        }
    }
}

/// Multiclass: per-class partial sums must aggregate without mixing
/// classes, and the argmax decision must survive sharding.
#[test]
fn sharded_multiclass_matches_unsharded() {
    let model = random_ensemble(48, 3, 8, Task::MultiClass(3), 5);
    let program = compile(&model, &CompileOptions::default()).unwrap();
    let reference = CamEngine::new(&program);

    let mut rng = Rng::new(9);
    let server = shard_servers(&program, 3, ShardStrategy::BalancedRows);
    for i in 0..30 {
        let row: Vec<f32> = (0..program.n_features).map(|_| rng.f32()).collect();
        let bins = program.quantizer.bin_row(&row);
        let reply = server.infer_blocking(bins.clone());
        let want = reference.infer_bins(&bins);
        assert_eq!(reply.logits.len(), 3);
        assert_eq!(reply.logits, want, "query {i}");
        assert_eq!(reply.prediction, reference.decide(&want), "query {i}");
    }
    server.shutdown();
}

/// On a *trained* model (non-zero base score), sharded serving must still
/// reproduce the CPU reference's predictions sample-for-sample.
#[test]
fn sharded_predictions_match_trained_model() {
    let d = by_name("churn").unwrap().generate_n(1000);
    let model = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 32, max_leaves: 16, ..Default::default() },
        None,
    );
    let program = compile(&model, &CompileOptions::default()).unwrap();
    let server = shard_servers(&program, 4, ShardStrategy::BalancedRows);
    for i in 0..100 {
        let bins = program.quantizer.bin_row(d.row(i));
        let reply = server.infer_blocking(bins);
        assert_eq!(reply.prediction, model.predict(d.row(i)), "row {i}");
    }
    server.shutdown();
}

/// Shards cover every tree exactly once and preserve total CAM rows at
/// paper scale.
#[test]
fn shard_plans_preserve_the_ensemble() {
    let model = random_ensemble(1024, 4, 16, Task::Binary, 3);
    let program = compile(&model, &CompileOptions::default()).unwrap();
    for n_shards in [2usize, 4, 8] {
        let plan = partition(&program, n_shards, &PartitionOptions::default()).unwrap();
        let mut all: Vec<u32> = plan.assignment.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all.len(), 1024);
        assert_eq!(all, (0..1024u32).collect::<Vec<_>>());
        assert_eq!(
            plan.shards.iter().map(|s| s.total_rows()).sum::<usize>(),
            program.total_rows()
        );
        // Equal-topology trees → balanced-rows is perfectly even here.
        let rows = plan.rows_per_shard();
        assert_eq!(rows.iter().min(), rows.iter().max(), "{rows:?}");
    }
}
