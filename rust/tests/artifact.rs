//! Contract 9 (ISSUE 8): a compiled program that round-trips through
//! the content-addressed artifact store is verify-clean under the
//! static verifier and serves **bit-identically** to the in-memory
//! original — predictions, logits, per-shard partials, defect draws —
//! including when it is hot-loaded into a fleet via
//! `register_from_artifact` / `swap_to_digest` under sustained load
//! (where contract 6's drain guarantee must also hold).
//!
//! Plus the store's corruption surface: flipped or truncated blobs,
//! truncated manifests, and unknown format versions must all surface
//! as structured [`StoreError`]s — never a panic — and `gc` must keep
//! every referenced blob while sweeping unreferenced ones.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;
use xtime::artifact::{export_program, sha256_hex, ArtifactStore, CompressionMeta, StoreError};
use xtime::bench_support::random_query_bins;
use xtime::cam::DefectSpec;
use xtime::compiler::{
    compile, partition, CamEngine, CamProgram, CompileOptions, PartitionOptions, ShardPlan,
};
use xtime::coordinator::{Fleet, ModelConfig};
use xtime::data::by_name;
use xtime::trees::{gbdt, rf, GbdtParams, RfParams};
use xtime::util::{Json, Rng};

/// Unique per-test store root under the system temp dir, removed on drop.
struct TmpStore {
    root: PathBuf,
}

impl TmpStore {
    fn new(tag: &str) -> TmpStore {
        let root =
            std::env::temp_dir().join(format!("xtime-artifact-it-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        TmpStore { root }
    }

    fn open(&self) -> ArtifactStore {
        ArtifactStore::open(&self.root).expect("open store")
    }
}

impl Drop for TmpStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

/// Train a small ensemble on a catalog dataset and compile it.
fn train_program(dataset: &str, n_bits: u8, kind: &str, seed: u64) -> CamProgram {
    let data = by_name(dataset).expect("catalog dataset").generate_n(400);
    let model = match kind {
        "gbdt" => gbdt::train(
            &data,
            &GbdtParams { n_rounds: 4, max_leaves: 8, n_bits, seed, ..Default::default() },
            None,
        ),
        "rf" => rf::train(
            &data,
            &RfParams { n_estimators: 4, max_leaves: 8, n_bits, seed, ..Default::default() },
        ),
        other => panic!("unknown kind {other}"),
    };
    compile(&model, &CompileOptions::default()).expect("compile")
}

fn two_shard_plan(program: &CamProgram) -> ShardPlan {
    partition(program, 2, &PartitionOptions::default()).expect("partition")
}

fn bits2(m: &[Vec<f32>]) -> Vec<Vec<u32>> {
    m.iter().map(|r| r.iter().map(|x| x.to_bits()).collect()).collect()
}

fn bits2_f64(m: &[Vec<f64>]) -> Vec<Vec<u64>> {
    m.iter().map(|r| r.iter().map(|x| x.to_bits()).collect()).collect()
}

/// The tentpole property, over the task × bits × trainer grid: export →
/// reopen store → load is verify-clean with **zero** deny findings and
/// bit-identical on every inference surface, at planned-execution
/// thread counts 1/2/8.
#[test]
fn export_import_grid_is_verify_clean_and_bit_identical() {
    // churn = binary, eye = 3-class, rossmann = regression (Table II).
    for (dataset, kind, n_bits) in [
        ("churn", "gbdt", 4u8),
        ("churn", "rf", 8u8),
        ("eye", "gbdt", 6u8),
        ("eye", "rf", 4u8),
        ("rossmann", "gbdt", 8u8),
        ("rossmann", "rf", 6u8),
    ] {
        let tag = format!("grid-{dataset}-{kind}-{n_bits}");
        let tmp = TmpStore::new(&tag);
        let program = train_program(dataset, n_bits, kind, 7);
        let plan = two_shard_plan(&program);

        let id = {
            let mut store = tmp.open();
            export_program(&mut store, &program, Some(&plan)).expect("export")
        };
        // A *fresh* store handle: everything must come back off disk.
        let art = tmp.open().load(&id).unwrap_or_else(|e| panic!("{tag}: load: {e}"));
        assert_eq!(art.manifest.n_shards, 2, "{tag}");
        assert_eq!(art.program.task, program.task, "{tag}");

        // Verify-clean: zero deny findings on program and plan.
        let mut report = xtime::analysis::verify_program(&art.program);
        let loaded_plan = art.plan.as_ref().expect("plan travels with the artifact");
        report.merge(xtime::analysis::verify_shard_plan(&art.program, loaded_plan));
        assert_eq!(report.deny_count(), 0, "{tag}: deny findings on loaded artifact");

        // Bit-identity on every surface.
        let queries = random_query_bins(&program, 64, 0xA57 + n_bits as u64);
        let orig = CamEngine::new(&program);
        let back = CamEngine::new(&art.program);
        assert_eq!(
            bits2(&orig.infer_batch(&queries)),
            bits2(&back.infer_batch(&queries)),
            "{tag}: infer_batch"
        );
        assert_eq!(
            bits2_f64(&orig.partials_batch(&queries)),
            bits2_f64(&back.partials_batch(&queries)),
            "{tag}: partials_batch"
        );
        for threads in [1usize, 2, 8] {
            assert_eq!(
                bits2(&orig.infer_planned(&queries, threads)),
                bits2(&back.infer_planned(&queries, threads)),
                "{tag}: infer_planned × {threads} threads"
            );
        }
        // Per-shard partials: each loaded shard is bit-equal to the
        // shard the original partition produced.
        assert_eq!(loaded_plan.shards.len(), plan.shards.len(), "{tag}");
        for (si, (a, b)) in plan.shards.iter().zip(&loaded_plan.shards).enumerate() {
            assert_eq!(
                bits2_f64(&CamEngine::new(a).partials_batch(&queries)),
                bits2_f64(&CamEngine::new(b).partials_batch(&queries)),
                "{tag}: shard {si} partials"
            );
        }
    }
}

/// Defect injection is seeded off program content the engine reads, so
/// a bit-identical round trip must give bit-identical *defective*
/// engines too.
#[test]
fn defect_draws_agree_after_roundtrip() {
    let tmp = TmpStore::new("defects");
    let program = train_program("churn", 8, "gbdt", 11);
    let id = {
        let mut store = tmp.open();
        export_program(&mut store, &program, None).expect("export")
    };
    let art = tmp.open().load(&id).expect("load");
    let queries = random_query_bins(&program, 64, 0xDEF);
    for seed in [1u64, 9, 42] {
        let a = CamEngine::with_defects(&program, DefectSpec::memristor(2.0), seed);
        let b = CamEngine::with_defects(&art.program, DefectSpec::memristor(2.0), seed);
        assert_eq!(
            bits2(&a.infer_batch(&queries)),
            bits2(&b.infer_batch(&queries)),
            "defect draw seed {seed}"
        );
    }
}

/// The artifact id is a pure function of model content: same program →
/// same id across repeat exports and across independent stores.
#[test]
fn digest_is_stable_across_exports_and_stores() {
    let program = train_program("eye", 8, "gbdt", 3);
    let plan = two_shard_plan(&program);
    let (tmp_a, tmp_b) = (TmpStore::new("stable-a"), TmpStore::new("stable-b"));
    let mut sa = tmp_a.open();
    let mut sb = tmp_b.open();
    let id1 = export_program(&mut sa, &program, Some(&plan)).unwrap();
    let id2 = export_program(&mut sa, &program, Some(&plan)).unwrap();
    let id3 = export_program(&mut sb, &program, Some(&plan)).unwrap();
    assert_eq!(id1, id2, "re-export in the same store");
    assert_eq!(id1, id3, "export in an independent store");
    assert_eq!(sa.ls().len(), 1, "idempotent publish indexes once");
}

/// A flipped byte in a blob must surface as a digest mismatch when the
/// artifact is loaded — never as a decode panic.
#[test]
fn corrupt_blob_is_a_digest_mismatch() {
    let tmp = TmpStore::new("flip");
    let program = train_program("churn", 4, "gbdt", 5);
    let mut store = tmp.open();
    let id = export_program(&mut store, &program, None).unwrap();
    let digest = store.load(&id).unwrap().manifest.program_blob().unwrap().digest.clone();
    let path = store.blob_path(&digest);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    match store.load(&id) {
        Err(StoreError::DigestMismatch { expected, .. }) => assert_eq!(expected, digest),
        other => panic!("expected DigestMismatch, got {:?}", other.err()),
    }
}

/// Truncation — of a blob or of the manifest itself — is also caught by
/// the digest check before any decoder sees the bytes.
#[test]
fn truncated_blob_and_manifest_fail_structurally() {
    let tmp = TmpStore::new("trunc");
    let program = train_program("churn", 4, "gbdt", 6);
    let mut store = tmp.open();
    let id = export_program(&mut store, &program, None).unwrap();
    let digest = store.load(&id).unwrap().manifest.program_blob().unwrap().digest.clone();

    let blob = store.blob_path(&digest);
    let bytes = std::fs::read(&blob).unwrap();
    std::fs::write(&blob, &bytes[..bytes.len() / 3]).unwrap();
    assert!(
        matches!(store.load(&id), Err(StoreError::DigestMismatch { .. })),
        "truncated blob"
    );
    std::fs::write(&blob, &bytes).unwrap();
    assert!(store.load(&id).is_ok(), "restored blob loads again");

    let man = store.manifest_path(&id);
    let mbytes = std::fs::read(&man).unwrap();
    std::fs::write(&man, &mbytes[..mbytes.len() - 7]).unwrap();
    assert!(
        matches!(store.load(&id), Err(StoreError::DigestMismatch { .. })),
        "truncated manifest"
    );
}

/// A manifest from a future format version is refused with a typed
/// version error, not misparsed.
#[test]
fn unknown_format_version_is_refused() {
    let tmp = TmpStore::new("version");
    let program = train_program("churn", 4, "gbdt", 8);
    let mut store = tmp.open();
    let id = export_program(&mut store, &program, None).unwrap();
    // Rewrite the manifest claiming version 99, stored under its own
    // (correct) content id so the digest check passes and the version
    // gate is what fires.
    let text = std::fs::read_to_string(store.manifest_path(&id)).unwrap();
    let mut j = Json::parse(&text).unwrap();
    j.set("format_version", Json::Num(99.0));
    let bytes = j.to_string().into_bytes();
    let future_id = sha256_hex(&bytes);
    std::fs::write(store.manifest_path(&future_id), &bytes).unwrap();
    match store.load(&future_id) {
        Err(StoreError::UnknownVersion { found, supported }) => {
            assert_eq!(found, 99);
            assert_eq!(supported, xtime::artifact::FORMAT_VERSION);
        }
        other => panic!("expected UnknownVersion, got {:?}", other.err()),
    }
}

/// GC keeps blobs any indexed manifest still references — including a
/// program blob *shared* by two artifacts — and sweeps the rest.
#[test]
fn gc_keeps_referenced_blobs_and_drops_unreferenced() {
    let tmp = TmpStore::new("gc");
    let program = train_program("eye", 4, "gbdt", 9);
    let plan = two_shard_plan(&program);
    let mut store = tmp.open();
    // Two artifacts of the same program — with and without a plan —
    // share the program blob.
    let id_bare = export_program(&mut store, &program, None).unwrap();
    let id_plan = export_program(&mut store, &program, Some(&plan)).unwrap();
    assert_ne!(id_bare, id_plan);
    let prog_digest =
        store.load(&id_bare).unwrap().manifest.program_blob().unwrap().digest.clone();

    store.remove(&id_bare).unwrap();
    let r = store.gc().unwrap();
    assert!(store.blob_path(&prog_digest).exists(), "shared blob survives first gc");
    assert_eq!(r.removed_manifests, 1, "bare manifest swept");
    store.load(&id_plan).expect("remaining artifact still loads after gc");

    store.remove(&id_plan).unwrap();
    let r = store.gc().unwrap();
    assert!(r.removed_blobs >= 2, "program + plan blobs swept, got {r:?}");
    assert!(!store.blob_path(&prog_digest).exists());
    assert!(store.ls().is_empty());
    assert!(r.bytes_freed > 0);
}

/// Capacity-compressed programs travel too (ISSUE 10): the layout
/// annotation survives the round trip byte-for-byte, the manifest
/// carries the compression summary, the id stays a pure function of
/// content (and differs from the uncompressed export's id), and the
/// loaded program is verify-clean — V7 included — and bit-identical on
/// every inference surface.
#[test]
fn compressed_artifact_roundtrips_digest_stable_and_bit_identical() {
    let data = by_name("churn").unwrap().generate_n(400);
    let model = gbdt::train(
        &data,
        &GbdtParams { n_rounds: 8, max_leaves: 16, seed: 17, ..Default::default() },
        None,
    );
    let plain = compile(&model, &CompileOptions::default()).unwrap();
    let pressed =
        compile(&model, &CompileOptions { compress: true, ..Default::default() }).unwrap();
    assert!(pressed.layouts.is_some(), "compression pass ran");

    let (tmp_a, tmp_b) = (TmpStore::new("press-a"), TmpStore::new("press-b"));
    let mut sa = tmp_a.open();
    let mut sb = tmp_b.open();
    let id_plain = export_program(&mut sa, &plain, None).unwrap();
    let id1 = export_program(&mut sa, &pressed, None).unwrap();
    let id2 = export_program(&mut sa, &pressed, None).unwrap();
    let id3 = export_program(&mut sb, &pressed, None).unwrap();
    assert_eq!(id1, id2, "re-export is digest-stable");
    assert_eq!(id1, id3, "export in an independent store");
    assert_ne!(id1, id_plain, "the layout annotation gates the id");

    let art = tmp_a.open().load(&id1).expect("load compressed artifact");
    assert_eq!(
        art.manifest.compression,
        Some(CompressionMeta {
            rows: pressed.total_rows(),
            phys_rows: pressed.total_phys_rows(),
        }),
        "manifest summarizes the compression"
    );
    assert_eq!(art.program.layouts, pressed.layouts, "layouts survive byte-for-byte");
    assert_eq!(
        xtime::analysis::verify_program(&art.program).deny_count(),
        0,
        "loaded compressed program is verify-clean"
    );
    // The uncompressed artifact's manifest must not grow the key.
    let bare = sa.load(&id_plain).expect("load plain artifact");
    assert_eq!(bare.manifest.compression, None);

    let queries = random_query_bins(&pressed, 64, 0xC0DE);
    let orig = CamEngine::new(&pressed);
    let back = CamEngine::new(&art.program);
    assert_eq!(bits2(&orig.infer_batch(&queries)), bits2(&back.infer_batch(&queries)));
    assert_eq!(
        bits2_f64(&orig.partials_batch(&queries)),
        bits2_f64(&back.partials_batch(&queries))
    );
    for threads in [1usize, 2, 8] {
        assert_eq!(
            bits2(&orig.infer_planned(&queries, threads)),
            bits2(&back.infer_planned(&queries, threads)),
            "infer_planned × {threads} threads"
        );
    }
}

/// An old-format manifest that grew an unreadable `compression` field
/// (wrong type, or missing sub-fields) surfaces as a structured
/// [`StoreError::Corrupt`] naming the field — never a panic, never a
/// silently-ignored annotation.
#[test]
fn malformed_compression_manifest_field_is_corrupt_not_panic() {
    let tmp = TmpStore::new("press-bad");
    let program = train_program("churn", 8, "gbdt", 19);
    let mut store = tmp.open();
    let id = export_program(&mut store, &program, None).unwrap();
    let text = std::fs::read_to_string(store.manifest_path(&id)).unwrap();

    // Each tampered manifest is stored under its own (correct) content
    // id so the digest gate passes and the decoder is what rejects it.
    for tamper in [Json::Str("gzip".into()), {
        let mut c = Json::obj();
        c.set("rows", Json::Num(10.0)); // phys_rows missing
        c
    }] {
        let mut j = Json::parse(&text).unwrap();
        j.set("compression", tamper);
        let bytes = j.to_string().into_bytes();
        let bad_id = sha256_hex(&bytes);
        std::fs::write(store.manifest_path(&bad_id), &bytes).unwrap();
        match store.load(&bad_id) {
            Err(StoreError::Corrupt { detail, .. }) => {
                assert!(detail.contains("compression"), "detail names the field: {detail}")
            }
            other => panic!("expected Corrupt, got {:?}", other.err()),
        }
    }
}

/// Cold start through the fleet: `register_from_artifact` with no
/// explicit config replays the manifest's shard count, passes the
/// contract 8 verifier gate, and serves bit-identically to an engine
/// built from the in-memory original.
#[test]
fn fleet_register_from_artifact_serves_bit_identically() {
    let tmp = TmpStore::new("fleet-reg");
    let program = train_program("churn", 8, "gbdt", 13);
    let plan = two_shard_plan(&program);
    let mut store = tmp.open();
    let id = export_program(&mut store, &program, Some(&plan)).unwrap();

    let fleet = Fleet::new();
    fleet.register_from_artifact("churn", &store, &id, None).expect("register from artifact");
    let reference = CamEngine::new(&program);
    let data = by_name("churn").unwrap().generate_n(64);
    for i in 0..data.n_rows() {
        let reply = fleet.infer("churn", data.row(i)).expect("infer");
        let want = reference.infer_bins(&program.quantizer.bin_row(data.row(i)));
        assert_eq!(bits2(&[reply.logits]), bits2(&[want]), "row {i}");
    }
    // A missing digest is refused without touching the fleet.
    assert!(fleet.register_from_artifact("ghost", &store, &"0".repeat(64), None).is_err());
    assert_eq!(fleet.models(), vec!["churn".to_string()]);
    fleet.shutdown();
}

/// `swap_to_digest` under sustained concurrent load: every pre-swap
/// admission is answered by the old program (contract 6 — nothing
/// dropped across the cutover), every concurrent reply matches exactly
/// one of the two programs bit-for-bit, and post-swap traffic serves
/// the artifact-loaded program.
#[test]
fn swap_to_digest_under_load_is_bit_exact_and_drops_nothing() {
    let tmp = TmpStore::new("swap");
    let p_old = train_program("churn", 8, "gbdt", 21);
    let p_new = train_program("churn", 8, "gbdt", 22); // different seed → different model
    let mut store = tmp.open();
    let id_new = export_program(&mut store, &p_new, Some(&two_shard_plan(&p_new))).unwrap();

    let ref_old = CamEngine::new(&p_old);
    let ref_new = CamEngine::new(&p_new);
    let data = by_name("churn").unwrap().generate_n(128);
    let rows: Vec<Vec<f32>> = (0..data.n_rows()).map(|i| data.row(i).to_vec()).collect();
    let bins: Vec<Vec<u16>> = rows.iter().map(|r| p_old.quantizer.bin_row(r)).collect();
    assert!(
        bins.iter().any(|b| ref_old.infer_bins(b) != ref_new.infer_bins(b)),
        "test needs models that disagree somewhere"
    );

    let fleet = Arc::new(Fleet::new());
    fleet
        .register_program("churn", &p_old, ModelConfig::for_program(&p_old).with_queue_cap(0))
        .unwrap();

    // Backlog admitted strictly before the swap: all old-program replies.
    let admissions = fleet.submit_batch("churn", &rows).unwrap();

    std::thread::scope(|scope| {
        // Sustained concurrent traffic racing the swap.
        for t in 0..2u64 {
            let fleet = Arc::clone(&fleet);
            let (ref_old, ref_new) = (&ref_old, &ref_new);
            let p_old = &p_old;
            let mut rng = Rng::new(0x5AB + t);
            scope.spawn(move || {
                for i in 0..80 {
                    let row: Vec<f32> =
                        (0..p_old.n_features).map(|_| rng.f32()).collect();
                    let reply = fleet.infer("churn", &row).unwrap_or_else(|e| {
                        panic!("client {t} request {i} dropped during swap: {e}")
                    });
                    let b = p_old.quantizer.bin_row(&row);
                    let (old, new) = (ref_old.infer_bins(&b), ref_new.infer_bins(&b));
                    assert!(
                        reply.logits == old || reply.logits == new,
                        "client {t} request {i}: reply matches neither program"
                    );
                }
            });
        }
        std::thread::sleep(Duration::from_millis(2));
        fleet.swap_to_digest("churn", &store, &id_new, None).expect("swap to digest");
    });

    for (i, adm) in admissions.into_iter().enumerate() {
        let reply = adm
            .recv()
            .unwrap_or_else(|e| panic!("pre-swap request {i} dropped across swap: {e}"));
        assert_eq!(
            bits2(&[reply.logits]),
            bits2(&[ref_old.infer_bins(&bins[i])]),
            "pre-swap request {i} must be served by the old program"
        );
    }
    for (i, row) in rows.iter().take(16).enumerate() {
        let reply = fleet.infer("churn", row).unwrap();
        assert_eq!(
            bits2(&[reply.logits]),
            bits2(&[ref_new.infer_bins(&bins[i])]),
            "post-swap request {i} must be served by the artifact-loaded program"
        );
    }
    let stats = fleet.stats();
    assert_eq!(stats.shed, 0, "queue-cap 0 swap must shed nothing");
    assert_eq!(stats.models[0].errors, 0);
    fleet.shutdown();
}
