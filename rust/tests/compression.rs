//! Compressed-vs-uncompressed bit-identity: the differential harness
//! behind DESIGN.md §5 contract 11. Capacity compression (row
//! merging + packing + arena dedup, `compiler/compress.rs`) must leave
//! every observable output of the engine untouched — predictions, f32
//! logits (compared bit for bit via `to_bits`), f64 per-shard partials,
//! and `SearchStats` charge accounting — across tasks, 4/6/8-bit
//! precisions, GBDT and RF ensembles, defect draws, 1- and 2-shard
//! deployments, and planned-path thread counts 1/2/8. Mirrors
//! `batch_agreement.rs`: `assert_eq!` on raw values, never a tolerance.

use xtime::bench_support::{random_ensemble, random_query_bins};
use xtime::cam::DefectSpec;
use xtime::compiler::{
    compile, partition, CamEngine, CamProgram, CompileOptions, PartitionOptions,
};
use xtime::data::{by_name, Task};
use xtime::trees::{gbdt, rf, Ensemble, GbdtParams, RfParams};
use xtime::util::prop;

/// Same pinned thread counts as the batch-agreement suite: one worker,
/// a split, and more workers than most test programs have cores.
const THREADS: [usize; 3] = [1, 2, 8];

/// Compile a model both ways. The compressed program must carry layouts
/// and identical *logical* contents — compression is an annotation.
fn compile_pair(model: &Ensemble) -> (CamProgram, CamProgram) {
    let plain = compile(model, &CompileOptions::default()).unwrap();
    let pressed =
        compile(model, &CompileOptions { compress: true, ..Default::default() }).unwrap();
    assert!(pressed.layouts.is_some(), "compress option must annotate the program");
    assert!(plain.layouts.is_none());
    assert_eq!(plain.cores.len(), pressed.cores.len());
    for (a, b) in plain.cores.iter().zip(&pressed.cores) {
        assert_eq!(a.rows, b.rows, "compression must never touch logical rows");
        assert_eq!(a.trees, b.trees);
    }
    (plain, pressed)
}

/// Exact agreement of two engines built from the plain / compressed
/// forms of one program, on every path: scalar, indexed batch, planned
/// at all pinned thread counts. Returns a witness for `prop::check`.
fn engines_agree(
    plain: &CamEngine,
    pressed: &CamEngine,
    batch: &[Vec<u16>],
    label: &str,
) -> prop::PropResult {
    for (i, bins) in batch.iter().enumerate() {
        prop::require(
            plain.partials_bins(bins) == pressed.partials_bins(bins),
            format!("{label}: row {i} f64 partials diverged"),
        )?;
        let (la, sa) = plain.infer_bins_stats(bins);
        let (lb, sb) = pressed.infer_bins_stats(bins);
        let (ba, bb): (Vec<u32>, Vec<u32>) = (
            la.iter().map(|l| l.to_bits()).collect(),
            lb.iter().map(|l| l.to_bits()).collect(),
        );
        prop::require(ba == bb, format!("{label}: row {i} logit bits diverged"))?;
        prop::require(
            plain.decide(&la) == pressed.decide(&lb),
            format!("{label}: row {i} decision diverged"),
        )?;
        prop::require(
            sa.charged_rows == sb.charged_rows,
            format!(
                "{label}: row {i} charged_rows {} vs {}",
                sa.charged_rows, sb.charged_rows
            ),
        )?;
        prop::require(
            sa.matches == sb.matches,
            format!("{label}: row {i} matches {} vs {}", sa.matches, sb.matches),
        )?;
    }
    let (pa, sa) = plain.partials_batch_stats(batch);
    let (pb, sb) = pressed.partials_batch_stats(batch);
    prop::require(pa == pb, format!("{label}: indexed batch partials diverged"))?;
    prop::require(
        (sa.charged_rows, sa.matches) == (sb.charged_rows, sb.matches),
        format!(
            "{label}: indexed batch stats ({}, {}) vs ({}, {})",
            sa.charged_rows, sa.matches, sb.charged_rows, sb.matches
        ),
    )?;
    for &threads in &THREADS {
        let (qa, ta) = plain.partials_planned_stats(batch, threads);
        let (qb, tb) = pressed.partials_planned_stats(batch, threads);
        prop::require(
            qa == qb,
            format!("{label}: planned({threads}T) partials diverged"),
        )?;
        prop::require(
            plain.infer_planned(batch, threads) == pressed.infer_planned(batch, threads),
            format!("{label}: planned({threads}T) logits diverged"),
        )?;
        prop::require(
            (ta.charged_rows, ta.matches) == (tb.charged_rows, tb.matches),
            format!(
                "{label}: planned({threads}T) stats ({}, {}) vs ({}, {})",
                ta.charged_rows, ta.matches, tb.charged_rows, tb.matches
            ),
        )?;
    }
    Ok(())
}

fn random_bin_batch(
    g: &mut prop::Gen,
    n_features: usize,
    n_bins: usize,
    rows: usize,
) -> Vec<Vec<u16>> {
    (0..rows)
        .map(|_| (0..n_features).map(|_| g.usize_in(0, n_bins) as u16).collect())
        .collect()
}

#[test]
fn compressed_equals_plain_binary_8bit_gbdt() {
    let d = by_name("churn").unwrap().generate_n(1200);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 12, max_leaves: 16, ..Default::default() },
        None,
    );
    let (plain, pressed) = compile_pair(&m);
    let (ep, ec) = (CamEngine::new(&plain), CamEngine::new(&pressed));
    prop::check(40, 0xC0135, |g| {
        let batch = random_bin_batch(g, plain.n_features, plain.n_bins as usize, g.usize_in(1, 17));
        engines_agree(&ep, &ec, &batch, "binary-8bit")
    });
}

#[test]
fn compressed_equals_plain_multiclass_multicore() {
    let d = by_name("eye").unwrap().generate_n(1000);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 9, max_leaves: 16, ..Default::default() },
        None,
    );
    // Small cores force multi-core placement: per-core layouts.
    let plain = compile(&m, &CompileOptions { core_rows: 48, ..Default::default() }).unwrap();
    let pressed = compile(
        &m,
        &CompileOptions { core_rows: 48, compress: true, ..Default::default() },
    )
    .unwrap();
    assert!(plain.cores_per_replica() > 1);
    let (ep, ec) = (CamEngine::new(&plain), CamEngine::new(&pressed));
    prop::check(30, 0xC0EE7E, |g| {
        let batch = random_bin_batch(g, plain.n_features, plain.n_bins as usize, g.usize_in(1, 13));
        engines_agree(&ep, &ec, &batch, "multiclass")
    });
}

#[test]
fn compressed_equals_plain_regression_rf() {
    let d = by_name("rossmann").unwrap().generate_n(900);
    let m = rf::train(&d, &RfParams { n_estimators: 8, max_leaves: 32, ..Default::default() });
    let (plain, pressed) = compile_pair(&m);
    let (ep, ec) = (CamEngine::new(&plain), CamEngine::new(&pressed));
    prop::check(30, 0xC02F62, |g| {
        let batch = random_bin_batch(g, plain.n_features, plain.n_bins as usize, g.usize_in(1, 13));
        engines_agree(&ep, &ec, &batch, "regression-rf")
    });
}

#[test]
fn compressed_equals_plain_low_precision() {
    // 4- and 6-bit grids give coarser windows → far more shared
    // intervals and mergeable siblings, the regime where the dedup and
    // merge machinery does real work.
    for n_bits in [4u8, 6] {
        let d = by_name("telco").unwrap().generate_n(800);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 6, max_leaves: 8, n_bits, ..Default::default() },
            None,
        );
        let (plain, pressed) = compile_pair(&m);
        assert_eq!(plain.n_bins, 1 << n_bits);
        let (ep, ec) = (CamEngine::new(&plain), CamEngine::new(&pressed));
        prop::check(30, 0xC04B17 + n_bits as u64, |g| {
            let batch =
                random_bin_batch(g, plain.n_features, plain.n_bins as usize, g.usize_in(1, 17));
            engines_agree(&ep, &ec, &batch, &format!("{n_bits}-bit"))
        });
    }
}

#[test]
fn compressed_equals_plain_under_defects() {
    // Defect draws are keyed on *logical* rows (contract 11), so the
    // same spec + seed perturbs both builds identically and bit-identity
    // must survive every draw — including the dedup rebuild from
    // perturbed cells.
    let d = by_name("churn").unwrap().generate_n(1000);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 10, max_leaves: 16, ..Default::default() },
        None,
    );
    let (plain, pressed) = compile_pair(&m);
    prop::check(12, 0xC0DEFEC7, |g| {
        let spec = DefectSpec {
            memristor_pct: g.f64_unit() * 0.3,
            dac_pct: g.f64_unit() * 0.2,
        };
        let seed = g.u64();
        let ep = CamEngine::with_defects(&plain, spec, seed);
        let ec = CamEngine::with_defects(&pressed, spec, seed);
        let batch = random_bin_batch(g, plain.n_features, plain.n_bins as usize, 8);
        engines_agree(&ep, &ec, &batch, "defects")
    });
}

#[test]
fn compressed_shards_reproduce_plain_shards() {
    // Sharding a compressed program recomputes per-shard layouts; the
    // f64 per-shard partials — the unit of cross-shard aggregation —
    // must match the uncompressed partition shard for shard, row for
    // row, at 1 and 2 shards.
    let model = random_ensemble(256, 4, 16, Task::Binary, 11);
    let (plain, pressed) = compile_pair(&model);
    let batch = random_query_bins(&plain, 32, 0x5AFE);
    for n_shards in [1usize, 2] {
        let (pp, pc) = if n_shards == 1 {
            (vec![plain.clone()], vec![pressed.clone()])
        } else {
            let a = partition(&plain, n_shards, &PartitionOptions::default()).unwrap();
            let b = partition(&pressed, n_shards, &PartitionOptions::default()).unwrap();
            assert!(
                b.shards.iter().all(|s| s.layouts.is_some()),
                "shards of a compressed program must be recompressed"
            );
            (a.shards, b.shards)
        };
        for (s, (sp, sc)) in pp.iter().zip(&pc).enumerate() {
            let (ep, ec) = (CamEngine::new(sp), CamEngine::new(sc));
            for (i, bins) in batch.iter().enumerate() {
                assert_eq!(
                    ep.partials_bins(bins),
                    ec.partials_bins(bins),
                    "{n_shards}-shard deployment, shard {s}, row {i}: f64 partials"
                );
            }
            engines_agree(&ep, &ec, &batch, &format!("{n_shards}-shard s{s}")).unwrap();
        }
    }
}

#[test]
fn sparse_benchmark_model_compresses_at_least_2x() {
    // The ISSUE 10 capacity claim: shallow trees over many features are
    // the paper's sparse regime; merging + packing must at least halve
    // the physical row count on the 1024-tree benchmark ensemble.
    let model = random_ensemble(1024, 4, 32, Task::Binary, 7);
    let (plain, pressed) = compile_pair(&model);
    let (rows, phys) = (pressed.total_rows(), pressed.total_phys_rows());
    assert_eq!(plain.total_rows(), rows);
    assert!(
        rows as f64 / phys as f64 >= 2.0,
        "expected ≥2× row reduction on the sparse benchmark model, got {rows} → {phys}"
    );
    // Spot-check bit-identity on the big model too (scalar + planned).
    let (ep, ec) = (CamEngine::new(&plain), CamEngine::new(&pressed));
    let batch = random_query_bins(&plain, 16, 0xB16);
    engines_agree(&ep, &ec, &batch, "sparse-benchmark").unwrap();
}

#[test]
fn compressed_program_roundtrips_and_verifies_clean() {
    // Codec + verifier integration: the annotated program survives its
    // canonical JSON round trip exactly and passes the V1–V7 gate, at 1
    // and 2 shards.
    let d = by_name("telco").unwrap().generate_n(900);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 8, max_leaves: 16, ..Default::default() },
        None,
    );
    let (_, pressed) = compile_pair(&m);
    let text = pressed.to_json().to_string();
    let back = CamProgram::from_json(&xtime::util::Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.to_json().to_string(), text, "canonical round trip");
    assert_eq!(back.layouts, pressed.layouts);
    for n_shards in [1usize, 2] {
        let report = xtime::analysis::verify(&pressed, n_shards);
        assert!(
            report.is_clean(),
            "compressed program must verify clean at {n_shards} shard(s):\n{}",
            report.render()
        );
    }
}
