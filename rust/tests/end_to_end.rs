//! End-to-end integration: train → compile → serve, across backends; the
//! whole Table II catalog at reduced tree counts; serialization round
//! trips.

use std::path::{Path, PathBuf};
use xtime::compiler::{compile, CamEngine, CompileOptions};
use xtime::coordinator::{BatchPolicy, CpuExactBackend, FunctionalBackend, Server, XlaBackend};
use xtime::data::{catalog, Task};
use xtime::runtime::XlaCamEngine;
use xtime::trees::{metrics, paper_model, train_paper_model, Ensemble};

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping XLA parts: run `make artifacts` first");
        None
    }
}

/// Train every Table II dataset (reduced trees), compile, and verify the
/// functional CAM engine reproduces CPU predictions sample-for-sample.
#[test]
fn whole_catalog_compiles_and_agrees() {
    for spec in catalog() {
        let data = spec.generate_n(1200);
        let mspec = paper_model(spec.name).unwrap();
        let trees = if data.task.n_outputs() > 1 { 3 * data.task.n_outputs() } else { 8 };
        let model = train_paper_model(&data, &mspec, 8, mspec.n_leaves_max.min(32), Some(trees));
        let program = compile(&model, &CompileOptions::default())
            .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        let engine = CamEngine::new(&program);
        for i in 0..100 {
            let row = data.row(i);
            let got = engine.predict(&program, row);
            let want = model.predict(row);
            if data.task == Task::Regression {
                // Regression outputs are raw sums; the engine accumulates
                // in f64 vs the reference's f32 tree order — identical up
                // to rounding.
                assert!(
                    (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                    "{}: row {i}: {got} vs {want}",
                    spec.name
                );
            } else {
                assert_eq!(got, want, "{}: row {i} disagrees", spec.name);
            }
        }
        // Accuracy sanity: the model must beat chance on its own data.
        let score = metrics::score(&model, &data);
        let chance = match data.task {
            Task::Regression => 0.2,
            Task::Binary => 0.6,
            Task::MultiClass(k) => 1.5 / k as f64,
        };
        assert!(score > chance, "{}: score {score} ≤ chance {chance}", spec.name);
    }
}

/// The three backends must serve identical predictions through the
/// dynamic-batching server.
#[test]
fn all_backends_serve_identically() {
    let spec = xtime::data::by_name("churn").unwrap();
    let data = spec.generate_n(1000);
    let mspec = paper_model("churn").unwrap();
    let model = train_paper_model(&data, &mspec, 8, 16, Some(10));
    let program = compile(&model, &CompileOptions::default()).unwrap();

    let mut backends: Vec<Box<dyn xtime::coordinator::Backend>> = vec![
        Box::new(CpuExactBackend { model: model.clone() }),
        Box::new(FunctionalBackend::new(&program)),
    ];
    if let Some(dir) = artifacts() {
        backends.push(Box::new(XlaBackend {
            engine: XlaCamEngine::new(&program, &dir, 8).expect("xla engine"),
        }));
    }

    let mut all_preds: Vec<Vec<f32>> = Vec::new();
    for backend in backends {
        let name = backend.name();
        let server = Server::start(backend, BatchPolicy::default(), program.n_features);
        let preds: Vec<f32> = (0..60)
            .map(|i| server.infer_blocking(program.quantizer.bin_row(data.row(i))).prediction)
            .collect();
        eprintln!("{name}: served 60");
        all_preds.push(preds);
    }
    for w in all_preds.windows(2) {
        assert_eq!(w[0], w[1], "backends disagree");
    }
}

/// Model JSON round trip preserves predictions exactly.
#[test]
fn model_serialization_roundtrip() {
    let spec = xtime::data::by_name("eye").unwrap();
    let data = spec.generate_n(800);
    let mspec = paper_model("eye").unwrap();
    let model = train_paper_model(&data, &mspec, 8, 16, Some(9));
    let tmp = std::env::temp_dir().join("xtime_e2e_model.json");
    model.save(&tmp).unwrap();
    let back = Ensemble::load(&tmp).unwrap();
    for i in 0..100 {
        assert_eq!(model.predict(data.row(i)), back.predict(data.row(i)), "row {i}");
    }
    let _ = std::fs::remove_file(&tmp);
}

/// Program JSON round trip preserves the functional engine's outputs.
#[test]
fn program_serialization_roundtrip() {
    let spec = xtime::data::by_name("telco").unwrap();
    let data = spec.generate_n(700);
    let mspec = paper_model("telco").unwrap();
    let model = train_paper_model(&data, &mspec, 8, 4, Some(12));
    let program = compile(&model, &CompileOptions::default()).unwrap();
    let tmp = std::env::temp_dir().join("xtime_e2e_program.json");
    program.save(&tmp).unwrap();
    let back = xtime::compiler::CamProgram::load(&tmp).unwrap();
    let e1 = CamEngine::new(&program);
    let e2 = CamEngine::new(&back);
    for i in 0..60 {
        let row = data.row(i);
        assert_eq!(e1.infer_row(&program, row), e2.infer_row(&back, row), "row {i}");
    }
    let _ = std::fs::remove_file(&tmp);
}
