//! Wire-protocol conformance battery (ISSUE 6): the framed-TCP front
//! end must survive hostile bytes without panicking or wedging its
//! accept loop, agree bit-exactly with the in-process fleet path
//! (DESIGN.md §5 contract 7), and make shed decisions before a refused
//! row's feature payload is ever deserialized (shed-before-parse,
//! asserted through the listener's decode counter).
//!
//! Models are `random_ensemble` topologies (no training) so the battery
//! runs in CI-smoke time.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};
use xtime::bench_support::random_ensemble;
use xtime::compiler::{compile, CamEngine, CamProgram, CompileOptions};
use xtime::coordinator::{Backend, BatchPolicy, Fleet, FunctionalBackend, ModelConfig};
use xtime::data::Task;
use xtime::serve::{
    decode_reply, encode_request, read_frame, write_frame, ReplyFrame, RequestView,
    RowOutcome, WireClient, WireServer, MAX_FRAME_BYTES,
};
use xtime::util::prop::{self, require};
use xtime::util::Rng;

fn program(seed: u64, n_features: usize, task: Task) -> CamProgram {
    let model = random_ensemble(24, 4, n_features, task, seed);
    compile(&model, &CompileOptions::default()).unwrap()
}

fn random_rows(n_features: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (0..n_features).map(|_| rng.f32()).collect()).collect()
}

/// A fleet with one functional route, wrapped for wire serving.
fn serve_one(
    name: &str,
    p: &CamProgram,
    cfg: ModelConfig,
) -> (Arc<Fleet>, WireServer, String) {
    let fleet = Arc::new(Fleet::new());
    fleet.register_program(name, p, cfg).unwrap();
    let server = WireServer::start(fleet.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    (fleet, server, addr)
}

fn teardown(fleet: Arc<Fleet>, server: WireServer) {
    server.shutdown();
    // After the wire shutdown joined every handler, the Arc is unique.
    Arc::try_unwrap(fleet).ok().expect("wire shutdown leaves the fleet unshared").shutdown();
}

// ---- encode/decode round-trip properties ------------------------------

/// Random batches (shape, tenant text, payload bits incl. NaN) survive
/// a request encode → lazy parse → per-row decode round trip exactly.
#[test]
fn prop_request_roundtrip_random_batches() {
    prop::check(128, 0x31E6, |g| {
        let n_features = g.usize_in(1, 24);
        let n_rows = g.usize_in(0, 12);
        let id = g.u64();
        let tenants = ["m", "telco", "tenant-é™", "", "a b/c"];
        let tenant = *g.pick(&tenants);
        let rows: Vec<Vec<f32>> = (0..n_rows)
            .map(|_| {
                (0..n_features)
                    .map(|_| {
                        // Exercise odd payloads too: NaN and subnormals
                        // must cross the wire bit-exactly.
                        if g.bool() {
                            g.f32_in(-1e6, 1e6)
                        } else {
                            *g.pick(&[f32::NAN, 0.0, -0.0, f32::MIN_POSITIVE, 1e-40])
                        }
                    })
                    .collect()
            })
            .collect();
        let frame = encode_request(id, tenant, n_features, &rows);
        let view = RequestView::parse(&frame[4..])
            .map_err(|e| format!("parse failed: {e}"))?;
        require(view.id == id, format!("id {} != {id}", view.id))?;
        require(view.tenant == tenant, format!("tenant {:?}", view.tenant))?;
        require(view.n_rows == n_rows, "row count")?;
        require(view.n_features == n_features, "feature count")?;
        for (i, row) in rows.iter().enumerate() {
            let got = view.row(i);
            let same = row.len() == got.len()
                && row.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits());
            require(same, format!("row {i} bits changed"))?;
        }
        Ok(())
    });
}

/// Random reply frames (every row-outcome kind, random logit widths)
/// survive encode → decode exactly.
#[test]
fn prop_reply_roundtrip_random_outcomes() {
    prop::check(128, 0x52E7, |g| {
        let id = g.u64();
        let queue_depth = g.u64() as u32;
        let n_rows = g.usize_in(0, 10);
        let rows: Vec<RowOutcome> = (0..n_rows)
            .map(|_| match g.usize_in(0, 3) {
                0 => RowOutcome::Served {
                    prediction: g.f32_in(-10.0, 10.0),
                    logits: g.vec_f32(g.usize_in(0, 6), -5.0, 5.0),
                },
                1 => RowOutcome::Shed { queue_depth: g.u64() as u32 },
                _ => RowOutcome::Failed {
                    error: format!("shard {}: fault", g.usize_in(0, 9)),
                },
            })
            .collect();
        let frame = xtime::serve::encode_reply(id, queue_depth, &rows);
        match decode_reply(&frame[4..]).map_err(|e| format!("decode failed: {e}"))? {
            ReplyFrame::Batch { id: gid, queue_depth: gq, rows: grows } => {
                require(gid == id && gq == queue_depth, "header fields")?;
                require(grows.len() == rows.len(), "row count")?;
                for (i, (want, have)) in rows.iter().zip(&grows).enumerate() {
                    let same = match (want, have) {
                        (
                            RowOutcome::Served { prediction: p1, logits: l1 },
                            RowOutcome::Served { prediction: p2, logits: l2 },
                        ) => {
                            p1.to_bits() == p2.to_bits()
                                && l1.len() == l2.len()
                                && l1.iter().zip(l2).all(|(a, b)| a.to_bits() == b.to_bits())
                        }
                        (a, b) => a == b,
                    };
                    require(same, format!("row {i} changed"))?;
                }
                Ok(())
            }
            other => Err(format!("expected batch, got {other:?}")),
        }
    });
}

// ---- hostile-bytes battery --------------------------------------------

/// Helper: raw socket + read one reply frame body.
fn raw_reply(stream: &mut TcpStream) -> Option<Vec<u8>> {
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    read_frame(stream).ok().flatten()
}

/// A truncated frame (length prefix promises more than the peer sends)
/// gets a protocol-error reply, the connection closes, and the server
/// keeps accepting fresh connections.
#[test]
fn truncated_frame_yields_protocol_error_and_server_survives() {
    let p = program(1, 8, Task::Binary);
    let (fleet, server, addr) = serve_one("m", &p, ModelConfig::for_program(&p));

    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.write_all(&100u32.to_le_bytes()).unwrap(); // promise 100 bytes…
    stream.write_all(&[0xAB; 10]).unwrap(); // …send 10
    stream.shutdown(Shutdown::Write).unwrap(); // EOF mid-frame
    let body = raw_reply(&mut stream).expect("server must answer before closing");
    match decode_reply(&body).unwrap() {
        ReplyFrame::ProtocolError { reason, .. } => {
            assert!(reason.contains("disconnected"), "reason: {reason}")
        }
        other => panic!("expected protocol error, got {other:?}"),
    }

    // Fresh connection on the same listener is healthy.
    let mut client = WireClient::connect(&addr).unwrap();
    let reply = client.request("m", &random_rows(8, 2, 2)).unwrap();
    assert_eq!(reply.rows.len(), 2);
    assert!(server.stats().protocol_errors >= 1);
    teardown(fleet, server);
}

/// An oversized length prefix is refused before any body byte is read
/// (no multi-gigabyte allocation), with a protocol-error reply.
#[test]
fn oversized_length_prefix_is_refused_up_front() {
    let p = program(3, 8, Task::Binary);
    let (fleet, server, addr) = serve_one("m", &p, ModelConfig::for_program(&p));

    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
    let body = raw_reply(&mut stream).expect("reply before close");
    match decode_reply(&body).unwrap() {
        ReplyFrame::ProtocolError { reason, .. } => {
            assert!(reason.contains("ceiling"), "reason: {reason}");
            assert!(reason.contains(&MAX_FRAME_BYTES.to_string()));
        }
        other => panic!("expected protocol error, got {other:?}"),
    }
    let mut client = WireClient::connect(&addr).unwrap();
    assert!(client.request("m", &random_rows(8, 1, 4)).is_ok());
    teardown(fleet, server);
}

/// Garbage bytes under a valid length prefix (bad magic) close only
/// that connection, cleanly.
#[test]
fn garbage_body_yields_protocol_error() {
    let p = program(5, 8, Task::Binary);
    let (fleet, server, addr) = serve_one("m", &p, ModelConfig::for_program(&p));

    let mut stream = TcpStream::connect(&addr).unwrap();
    let garbage = [0x5Au8; 64];
    stream.write_all(&(garbage.len() as u32).to_le_bytes()).unwrap();
    stream.write_all(&garbage).unwrap();
    let body = raw_reply(&mut stream).expect("reply before close");
    match decode_reply(&body).unwrap() {
        ReplyFrame::ProtocolError { reason, .. } => {
            assert!(reason.contains("magic"), "reason: {reason}")
        }
        other => panic!("expected protocol error, got {other:?}"),
    }
    // The connection is closed after a protocol error: the next read
    // sees EOF.
    let mut probe = [0u8; 1];
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    assert_eq!(stream.read(&mut probe).unwrap_or(0), 0, "server must hang up");
    assert_eq!(server.stats().protocol_errors, 1);
    teardown(fleet, server);
}

/// A zero-row batch is well-framed but unserviceable: `Rejected`, and
/// the **same** connection then serves a healthy request (reject ≠
/// protocol error).
#[test]
fn zero_row_batch_is_rejected_and_connection_stays_usable() {
    let p = program(7, 8, Task::Binary);
    let (fleet, server, addr) = serve_one("m", &p, ModelConfig::for_program(&p));

    let mut client = WireClient::connect(&addr).unwrap();
    let err = client.request_shaped("m", 8, &[]).unwrap_err();
    assert!(err.contains("rejected"), "got: {err}");
    assert!(err.contains("empty batch"), "got: {err}");
    // Same connection, next frame: served normally.
    let reply = client.request("m", &random_rows(8, 3, 8)).unwrap();
    assert_eq!(reply.rows.len(), 3);
    let ws = server.stats();
    assert_eq!(ws.rejected_frames, 1);
    assert_eq!(ws.protocol_errors, 0);
    teardown(fleet, server);
}

/// Unknown tenants and arity mismatches are rejects too — the route
/// error text matches the in-process API's, and the connection lives.
#[test]
fn unknown_tenant_and_arity_mismatch_are_rejects() {
    let p = program(9, 8, Task::Binary);
    let (fleet, server, addr) = serve_one("m", &p, ModelConfig::for_program(&p));

    let mut client = WireClient::connect(&addr).unwrap();
    let err = client.request("ghost", &random_rows(8, 1, 9)).unwrap_err();
    assert!(err.contains("unknown model `ghost`"), "got: {err}");
    let err = client.request("m", &random_rows(5, 2, 10)).unwrap_err();
    assert!(err.contains("expects 8 features, got 5"), "got: {err}");
    // Still usable.
    assert!(client.request("m", &random_rows(8, 1, 11)).is_ok());
    assert_eq!(server.stats().rejected_frames, 2);
    // Neither reject admitted or decoded anything.
    assert_eq!(server.stats().rows_decoded, 1);
    teardown(fleet, server);
}

/// A peer that vanishes mid-payload (socket dropped without EOF
/// courtesy) must not wedge the accept loop or leak the handler: the
/// server records a protocol error and keeps serving others.
#[test]
fn mid_frame_disconnect_leaves_server_healthy() {
    let p = program(11, 8, Task::Binary);
    let (fleet, server, addr) = serve_one("m", &p, ModelConfig::for_program(&p));

    {
        let mut stream = TcpStream::connect(&addr).unwrap();
        let frame = encode_request(1, "m", 8, &random_rows(8, 4, 12));
        stream.write_all(&frame[..frame.len() / 2]).unwrap();
        // Dropped here: RST/FIN mid-frame.
    }
    // The handler notices asynchronously; poll until it has.
    let t0 = Instant::now();
    while server.stats().protocol_errors == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "mid-frame disconnect never surfaced"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    // Accept loop unharmed.
    let mut client = WireClient::connect(&addr).unwrap();
    assert!(client.request("m", &random_rows(8, 2, 13)).is_ok());
    teardown(fleet, server);
}

// ---- contract 7: wire vs in-process bit-identity ----------------------

/// The same batch through the TCP front end and through
/// `Fleet::infer_batch` yields byte-identical logits and predictions —
/// and both match the single-engine reference (extends the contract-4/6
/// agreement pattern to the wire).
#[test]
fn wire_and_in_process_predictions_are_bit_identical() {
    let p = program(21, 12, Task::MultiClass(3));
    let reference = CamEngine::new(&p);
    let (fleet, server, addr) =
        serve_one("mc", &p, ModelConfig::for_program(&p).with_shards(2));
    let rows = random_rows(12, 32, 22);

    let mut client = WireClient::connect(&addr).unwrap();
    let wire = client.request("mc", &rows).unwrap();
    assert_eq!(wire.rows.len(), rows.len());
    let direct = fleet.infer_batch("mc", &rows).unwrap();

    for (i, (w, d)) in wire.rows.iter().zip(&direct).enumerate() {
        let d = d.as_ref().expect("in-process row served");
        match w {
            RowOutcome::Served { prediction, logits } => {
                assert_eq!(
                    prediction.to_bits(),
                    d.prediction.to_bits(),
                    "row {i} prediction"
                );
                let wb: Vec<u32> = logits.iter().map(|v| v.to_bits()).collect();
                let db: Vec<u32> = d.logits.iter().map(|v| v.to_bits()).collect();
                assert_eq!(wb, db, "row {i} logits wire vs in-process");
                assert_eq!(
                    *logits,
                    reference.infer_bins(&p.quantizer.bin_row(&rows[i])),
                    "row {i} logits vs reference engine"
                );
            }
            other => panic!("row {i}: expected Served, got {other:?}"),
        }
    }
    teardown(fleet, server);
}

// ---- shed-before-parse ------------------------------------------------

/// Blocks inside `infer` until the test drops the gate sender, so no
/// queue slot can be released while a test's admission window is open.
struct GatedBackend {
    inner: FunctionalBackend,
    gate: Receiver<()>,
}

impl Backend for GatedBackend {
    fn name(&self) -> &'static str {
        "gated"
    }
    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }
    fn task(&self) -> Task {
        self.inner.task()
    }
    fn infer(&mut self, batch: &[Vec<u16>]) -> anyhow::Result<Vec<Vec<f32>>> {
        // Blocks until the sender drops (Err) or sends; either opens it.
        let _ = self.gate.recv();
        self.inner.infer(batch)
    }
}

fn gated_fleet(p: &CamProgram, queue_cap: usize) -> (Arc<Fleet>, Sender<()>) {
    let (gate_tx, gate_rx) = channel();
    let fleet = Arc::new(Fleet::new());
    let cfg = ModelConfig::for_program(p)
        .with_policy(BatchPolicy { max_wait_us: 0, max_batch: 32, threads: None })
        .with_queue_cap(queue_cap);
    fleet
        .register_backends(
            "tiny",
            vec![Box::new(GatedBackend { inner: FunctionalBackend::new(p), gate: gate_rx })],
            Vec::new(),
            cfg,
        )
        .unwrap();
    (fleet, gate_tx)
}

/// The wire mirror of the fleet 4/60 test: one 60-row frame against a
/// stalled backend with queue cap 4 admits exactly 4 rows and sheds 56
/// — and the 56 refused rows never have their feature payload decoded
/// (`rows_decoded` counts exactly the admitted rows).
#[test]
fn shed_before_parse_single_frame_is_deterministic() {
    let p = program(31, 8, Task::Binary);
    let (fleet, gate) = gated_fleet(&p, 4);
    let server = WireServer::start(fleet.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let rows = random_rows(8, 60, 32);
    let handle = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut client = WireClient::connect(&addr).unwrap();
            client.request("tiny", &rows)
        })
    };
    // Wait until the frame's admission pass has fully resolved: every
    // row either admitted (the backend holds them behind the gate) or
    // shed — snapshotting mid-pass would observe a partial shed count.
    let t0 = Instant::now();
    while server.stats().rows_admitted + server.stats().rows_shed < 60 {
        assert!(t0.elapsed() < Duration::from_secs(20), "frame never arrived");
        std::thread::sleep(Duration::from_millis(5));
    }
    let ws = server.stats();
    assert_eq!(ws.rows_offered, 60);
    assert_eq!(ws.rows_admitted, 4, "exactly the queue cap admits");
    assert_eq!(ws.rows_shed, 56);
    assert_eq!(ws.rows_admitted + ws.rows_shed, ws.rows_offered, "every row accounted");
    // THE shed-before-parse assertion: only admitted rows were decoded.
    assert_eq!(ws.rows_decoded, 4, "shed rows must never be deserialized");

    drop(gate); // open the gate: the 4 admitted rows get served
    let reply = handle.join().unwrap().expect("batch reply");
    let served = reply
        .rows
        .iter()
        .filter(|r| matches!(r, RowOutcome::Served { .. }))
        .count();
    let shed = reply
        .rows
        .iter()
        .filter(|r| matches!(r, RowOutcome::Shed { queue_depth: 4 }))
        .count();
    assert_eq!((served, shed), (4, 56));

    let stats = fleet.stats();
    assert_eq!((stats.admitted, stats.shed), (4, 56), "fleet totals agree with the wire");
    teardown(fleet, server);
}

/// Concurrent wire clients against the stalled route: per-client
/// admission racing is fair game, but the totals stay deterministic —
/// `admitted + shed == offered`, exactly `cap` admitted, and still no
/// payload decode for any shed row.
#[test]
fn shed_accounting_exact_under_concurrent_wire_clients() {
    let p = program(41, 8, Task::Binary);
    let (fleet, gate) = gated_fleet(&p, 4);
    let server = WireServer::start(fleet.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let handles: Vec<_> = (0..3)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let rows = random_rows(8, 20, 42 + c);
                let mut client = WireClient::connect(&addr).unwrap();
                client.request("tiny", &rows)
            })
        })
        .collect();
    // All three frames admit/shed against the gated queue; once every
    // row is accounted, release the backend.
    let t0 = Instant::now();
    while server.stats().rows_admitted + server.stats().rows_shed < 60 {
        assert!(t0.elapsed() < Duration::from_secs(20), "frames never resolved");
        std::thread::sleep(Duration::from_millis(5));
    }
    let ws = server.stats();
    assert_eq!(ws.rows_offered, 60);
    assert_eq!(ws.rows_admitted, 4, "cap admits exactly 4 across all clients");
    assert_eq!(ws.rows_shed, 56);
    assert_eq!(ws.rows_decoded, ws.rows_admitted, "decode only after admission");

    drop(gate);
    let mut served = 0usize;
    let mut shed = 0usize;
    for h in handles {
        let reply = h.join().unwrap().expect("batch reply");
        assert_eq!(reply.rows.len(), 20);
        for r in &reply.rows {
            match r {
                RowOutcome::Served { .. } => served += 1,
                RowOutcome::Shed { .. } => shed += 1,
                RowOutcome::Failed { error } => panic!("unexpected failure: {error}"),
            }
        }
    }
    assert_eq!((served, shed), (4, 56));
    let stats = fleet.stats();
    assert_eq!((stats.admitted, stats.shed), (4, 56));
    teardown(fleet, server);
}

// ---- misc wire behaviors ----------------------------------------------

/// Several frames over one connection: ids echo back in order and the
/// connection is reusable indefinitely.
#[test]
fn sequential_frames_on_one_connection() {
    let p = program(51, 6, Task::Binary);
    let (fleet, server, addr) = serve_one("m", &p, ModelConfig::for_program(&p));
    let mut client = WireClient::connect(&addr).unwrap();
    for k in 1..=5 {
        let reply = client.request("m", &random_rows(6, k, 50 + k as u64)).unwrap();
        assert_eq!(reply.rows.len(), k);
        assert!(reply.rows.iter().all(|r| matches!(r, RowOutcome::Served { .. })));
    }
    let ws = server.stats();
    assert_eq!(ws.frames, 5);
    assert_eq!(ws.rows_offered, (1..=5).sum::<usize>() as u64);
    assert_eq!(ws.connections, 1);
    teardown(fleet, server);
}

/// Backend failures surface as per-row `Failed` outcomes over the wire
/// (mirroring the in-process error-reply contract) — the connection and
/// server both stay up.
#[test]
fn backend_failure_maps_to_failed_rows_not_connection_loss() {
    struct FailingBackend;
    impl Backend for FailingBackend {
        fn name(&self) -> &'static str {
            "always-fails"
        }
        fn max_batch(&self) -> usize {
            8
        }
        fn task(&self) -> Task {
            Task::Binary
        }
        fn infer(&mut self, _batch: &[Vec<u16>]) -> anyhow::Result<Vec<Vec<f32>>> {
            Err(anyhow::anyhow!("injected fault"))
        }
    }
    let fleet = Arc::new(Fleet::new());
    fleet
        .register_backends(
            "flaky",
            vec![Box::new(FailingBackend)],
            Vec::new(),
            ModelConfig {
                shards: 1,
                batch_policy: BatchPolicy::default(),
                queue_cap: 0,
                quantizer: xtime::data::FeatureQuantizer {
                    n_bits: 1,
                    edges: vec![vec![0.5]],
                },
                verify: xtime::analysis::VerifyPolicy::Skip,
                compress: false,
            },
        )
        .unwrap();
    let server = WireServer::start(fleet.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let mut client = WireClient::connect(&addr).unwrap();
    let reply = client.request("flaky", &[vec![0.3], vec![0.7]]).unwrap();
    for (i, r) in reply.rows.iter().enumerate() {
        match r {
            RowOutcome::Failed { error } => {
                assert!(error.contains("injected fault"), "row {i}: {error}")
            }
            other => panic!("row {i}: expected Failed, got {other:?}"),
        }
    }
    // Connection still fine for the next (equally doomed) request.
    assert!(client.request("flaky", &[vec![0.1]]).is_ok());
    teardown(fleet, server);
}

/// `write_frame`/`read_frame` are inverses over a real socket too (the
/// in-memory round trip lives in the frame module's unit tests).
#[test]
fn frame_io_roundtrip_over_loopback() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let echo = std::thread::spawn(move || {
        let (mut conn, _) = listener.accept().unwrap();
        while let Some(body) = read_frame(&mut conn).unwrap() {
            let mut framed = (body.len() as u32).to_le_bytes().to_vec();
            framed.extend_from_slice(&body);
            write_frame(&mut conn, &framed).unwrap();
        }
    });
    let mut stream = TcpStream::connect(addr).unwrap();
    for seed in 0..4u64 {
        let frame = encode_request(seed, "echo", 3, &random_rows(3, 2, seed));
        write_frame(&mut stream, &frame).unwrap();
        let body = read_frame(&mut stream).unwrap().unwrap();
        assert_eq!(&body[..], &frame[4..], "seed {seed}");
    }
    stream.shutdown(Shutdown::Both).unwrap();
    echo.join().unwrap();
}
