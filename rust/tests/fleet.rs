//! Fleet behavior under stress (ISSUE 5): swap-under-load bit-identity,
//! exact shed accounting under a slow backend, drain-on-unregister, and
//! multi-model concurrent clients.
//!
//! The drain contract these tests pin (DESIGN.md §5 contract 6): every
//! request admitted before a `swap`/`unregister` receives its reply
//! from the server — and therefore the program — it was admitted to,
//! bit-exactly; shed accounting is exact because `admitted + shed`
//! equals offered requests by construction (each submit increments
//! exactly one counter) and a queue slot is released only when a reply
//! has been sent.

use std::sync::Arc;
use std::time::Duration;
use xtime::bench_support::random_ensemble;
use xtime::compiler::{
    compile, partition, CamEngine, CamProgram, CompileOptions, PartitionOptions,
};
use xtime::coordinator::{
    Admission, Backend, BatchPolicy, Fleet, FunctionalBackend, ModelConfig,
};
use xtime::data::Task;
use xtime::util::Rng;

/// Wraps a healthy functional backend with a per-batch delay so
/// swaps/unregisters race a deep backlog of queued requests.
struct SlowBackend {
    inner: FunctionalBackend,
    delay: Duration,
}

impl Backend for SlowBackend {
    fn name(&self) -> &'static str {
        "slow"
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn task(&self) -> Task {
        self.inner.task()
    }

    fn infer(&mut self, batch: &[Vec<u16>]) -> anyhow::Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.delay);
        self.inner.infer(batch)
    }

    fn infer_partials(&mut self, batch: &[Vec<u16>]) -> anyhow::Result<Vec<Vec<f64>>> {
        std::thread::sleep(self.delay);
        self.inner.infer_partials(batch)
    }
}

fn program(seed: u64, n_features: usize) -> CamProgram {
    let model = random_ensemble(48, 4, n_features, Task::Binary, seed);
    compile(&model, &CompileOptions::default()).unwrap()
}

/// N slow functional shards of `program` (sharded exactly like
/// `Fleet::register_program`, but with the injected delay).
fn slow_shards(
    program: &CamProgram,
    n: usize,
    delay: Duration,
) -> (Vec<Box<dyn Backend>>, Vec<f32>) {
    if n <= 1 {
        let b = SlowBackend { inner: FunctionalBackend::new(program), delay };
        return (vec![Box::new(b) as Box<dyn Backend>], Vec::new());
    }
    let plan = partition(program, n, &PartitionOptions::default()).unwrap();
    let backends = plan
        .shards
        .iter()
        .map(|s| {
            Box::new(SlowBackend { inner: FunctionalBackend::new(s), delay })
                as Box<dyn Backend>
        })
        .collect();
    (backends, plan.base_score)
}

fn random_rows(n_features: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (0..n_features).map(|_| rng.f32()).collect()).collect()
}

/// Swap under load: requests admitted *before* the swap must all be
/// answered — bit-identically — by the **old** program, even though the
/// new program is live by the time their batches are served; requests
/// after the swap serve the new program. Old and new replies never
/// interleave wrongly because each admission is bound to one server.
#[test]
fn swap_under_load_is_bit_exact_and_drops_nothing() {
    let p1 = program(1, 16);
    let p2 = program(2, 16);
    let ref1 = CamEngine::new(&p1);
    let ref2 = CamEngine::new(&p2);
    let rows = random_rows(16, 32, 11);
    let bins: Vec<Vec<u16>> = rows.iter().map(|r| p1.quantizer.bin_row(r)).collect();
    // The swap must be observable: the two programs genuinely disagree.
    assert!(
        bins.iter().any(|b| ref1.infer_bins(b) != ref2.infer_bins(b)),
        "test needs programs that differ on some query"
    );

    let fleet = Fleet::new();
    let cfg = ModelConfig::for_program(&p1)
        .with_policy(BatchPolicy { max_wait_us: 0, max_batch: 4, threads: None })
        .with_queue_cap(0);
    let (backends, base) = slow_shards(&p1, 2, Duration::from_millis(10));
    fleet.register_backends("hot", backends, base, cfg).unwrap();

    // Build a deep backlog on the old server…
    let admissions = fleet.submit_batch("hot", &rows).unwrap();
    // …then swap while most of it is still queued. `swap_backends`
    // returns only after the old server drained.
    fleet.swap_program("hot", &p2, ModelConfig::for_program(&p2)).unwrap();

    for (i, adm) in admissions.into_iter().enumerate() {
        let reply = adm.recv().unwrap_or_else(|e| {
            panic!("pre-swap request {i} was dropped across the swap: {e}")
        });
        assert_eq!(
            reply.logits,
            ref1.infer_bins(&bins[i]),
            "pre-swap request {i} must be served by the OLD program"
        );
    }
    // Post-swap traffic serves the new program.
    for (i, row) in rows.iter().take(8).enumerate() {
        let reply = fleet.infer("hot", row).unwrap();
        assert_eq!(
            reply.logits,
            ref2.infer_bins(&bins[i]),
            "post-swap request {i} must be served by the NEW program"
        );
    }
    // The swap reset the route's counters; fleet lifetime totals kept
    // counting across it.
    let stats = fleet.stats();
    assert_eq!(stats.admitted, 32 + 8);
    assert_eq!(stats.shed, 0);
    assert_eq!(stats.models[0].admitted, 8, "route counters restart at swap");
    fleet.shutdown();
}

/// Swap racing live concurrent clients: every reply is bit-exact under
/// exactly one of the two programs — never an aggregation that mixes
/// shards of both — and nothing errors or drops.
#[test]
fn swap_during_concurrent_traffic_serves_old_or_new_exactly() {
    let p1 = program(3, 12);
    let p2 = program(4, 12);
    let ref1 = CamEngine::new(&p1);
    let ref2 = CamEngine::new(&p2);

    let fleet = Arc::new(Fleet::new());
    fleet
        .register_program(
            "live",
            &p1,
            ModelConfig::for_program(&p1).with_shards(2).with_queue_cap(0),
        )
        .unwrap();

    std::thread::scope(|scope| {
        for t in 0..3 {
            let fleet = Arc::clone(&fleet);
            let (ref1, ref2) = (&ref1, &ref2);
            let p1 = &p1;
            scope.spawn(move || {
                let rows = random_rows(12, 120, 100 + t);
                for (i, row) in rows.iter().enumerate() {
                    let reply = fleet.infer("live", row).unwrap_or_else(|e| {
                        panic!("client {t} request {i} failed during swap: {e}")
                    });
                    let bins = p1.quantizer.bin_row(row);
                    let (want_old, want_new) =
                        (ref1.infer_bins(&bins), ref2.infer_bins(&bins));
                    assert!(
                        reply.logits == want_old || reply.logits == want_new,
                        "client {t} request {i}: logits match neither program"
                    );
                }
            });
        }
        std::thread::sleep(Duration::from_millis(5));
        fleet.swap_program("live", &p2, ModelConfig::for_program(&p2)).unwrap();
    });

    let stats = fleet.stats();
    assert_eq!(stats.admitted, 3 * 120);
    assert_eq!(stats.shed, 0);
    assert_eq!(stats.models[0].errors, 0);
}

/// Unregister under load: the fleet blocks until the route drained, so
/// every queued reply arrives even though the model is gone.
#[test]
fn unregister_under_load_drains_every_queued_reply() {
    let p = program(5, 16);
    let reference = CamEngine::new(&p);
    let rows = random_rows(16, 24, 21);

    let fleet = Fleet::new();
    let cfg = ModelConfig::for_program(&p)
        .with_policy(BatchPolicy { max_wait_us: 0, max_batch: 4, threads: None })
        .with_queue_cap(0);
    let (backends, base) = slow_shards(&p, 2, Duration::from_millis(10));
    fleet.register_backends("gone", backends, base, cfg).unwrap();

    let admissions = fleet.submit_batch("gone", &rows).unwrap();
    fleet.unregister("gone").unwrap();
    for (i, adm) in admissions.into_iter().enumerate() {
        let reply = adm
            .recv()
            .unwrap_or_else(|e| panic!("request {i} dropped at unregister: {e}"));
        assert_eq!(reply.logits, reference.infer_bins(&p.quantizer.bin_row(&rows[i])));
    }
    assert!(fleet.infer("gone", &rows[0]).is_err(), "route must be gone");
    assert!(fleet.models().is_empty());
}

/// Shed accounting exactness: with a backend stalled for longer than the
/// whole submit loop takes, the queue admits exactly `cap` requests and
/// sheds the rest — and every counter (admission results, per-model
/// stats, fleet totals) agrees to the request.
#[test]
fn shed_accounting_is_exact_under_slow_backend() {
    let p = program(6, 8);
    let fleet = Fleet::new();
    let cfg = ModelConfig::for_program(&p)
        .with_policy(BatchPolicy { max_wait_us: 0, max_batch: 32, threads: None })
        .with_queue_cap(4);
    // The stall must outlast the submit loop by a wide margin even on an
    // oversubscribed CI box: 64 channel sends vs 1.5 s.
    let (backends, base) = slow_shards(&p, 1, Duration::from_millis(1_500));
    fleet.register_backends("tiny", backends, base, cfg).unwrap();

    let rows = random_rows(8, 64, 31);
    let mut accepted = Vec::new();
    let mut shed_seen = 0usize;
    // Submit far faster than the first batch's stall: no queue slot is
    // released during the loop, so exactly `cap` requests admit.
    for row in &rows {
        match fleet.submit("tiny", row).unwrap() {
            Admission::Accepted(rx) => accepted.push(rx),
            Admission::Shed { queue_depth } => {
                assert_eq!(queue_depth, 4, "shed reports the configured bound");
                shed_seen += 1;
            }
        }
    }
    assert_eq!(accepted.len(), 4, "exactly the queue cap admits");
    assert_eq!(shed_seen, 60);
    assert_eq!(accepted.len() + shed_seen, rows.len(), "every request accounted");

    let stats = fleet.model_stats("tiny").unwrap();
    assert_eq!(stats.admitted, 4);
    assert_eq!(stats.shed, 60);
    let fleet_stats = fleet.stats();
    assert_eq!((fleet_stats.admitted, fleet_stats.shed), (4, 60));

    // Every admitted request is still served correctly.
    let reference = CamEngine::new(&p);
    for (rx, row) in accepted.into_iter().zip(&rows) {
        let reply = rx.recv().expect("admitted request must be served");
        assert!(reply.is_ok());
        assert_eq!(reply.logits, reference.infer_bins(&p.quantizer.bin_row(row)));
    }
    // With all replies delivered the queue gauge returns to zero (the
    // worker releases the slot just after the send; spin briefly).
    let t0 = std::time::Instant::now();
    loop {
        let depth = fleet.model_stats("tiny").unwrap().queue_depth;
        if depth == 0 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "queue never drained: {depth}");
        std::thread::yield_now();
    }
    assert_eq!(fleet.model_stats("tiny").unwrap().served, 4);
}

/// A fleet route over simulated PCIe cards (one `SimCardBackend` per
/// shard): same bit-identity as the functional pool, and the simulated
/// device counters accrue per card — the §III-D multi-card deployment
/// served through the multi-tenant front end.
#[test]
fn fleet_route_over_sim_cards_is_bit_identical_and_metered() {
    use xtime::sim::{CardConfig, ChipConfig, SimCardBackend};

    let p = program(10, 16);
    let reference = CamEngine::new(&p);
    let plan = partition(&p, 2, &PartitionOptions::default()).unwrap();
    let cards: Vec<SimCardBackend> = plan
        .shards
        .iter()
        .map(|s| SimCardBackend::new(s, &ChipConfig::default(), &CardConfig::default()))
        .collect();
    let counters: Vec<_> = cards.iter().map(|c| c.counters()).collect();
    let backends: Vec<Box<dyn Backend>> =
        cards.into_iter().map(|c| Box::new(c) as Box<dyn Backend>).collect();

    let fleet = Fleet::new();
    let cfg = ModelConfig::for_program(&p);
    fleet.register_backends("cards", backends, plan.base_score.clone(), cfg).unwrap();
    let rows = random_rows(16, 12, 41);
    for (i, reply) in fleet.infer_batch("cards", &rows).unwrap().into_iter().enumerate() {
        let reply = reply.unwrap();
        assert_eq!(
            reply.logits,
            reference.infer_bins(&p.quantizer.bin_row(&rows[i])),
            "row {i}"
        );
    }
    fleet.shutdown();
    for c in &counters {
        assert_eq!(c.samples(), 12, "every simulated card sees every row");
        assert!(c.busy_s() > 0.0);
    }
}

/// Three tenants, concurrent clients on each: replies never cross
/// routes (each model's logits match its own reference bit-exactly) and
/// per-model/fleet counters add up.
#[test]
fn multi_model_concurrent_clients_stay_isolated() {
    let programs: Vec<CamProgram> =
        vec![program(7, 8), program(8, 12), program(9, 16)];
    let names = ["alpha", "beta", "gamma"];
    let references: Vec<CamEngine> = programs.iter().map(CamEngine::new).collect();

    let fleet = Arc::new(Fleet::new());
    for (i, (name, p)) in names.iter().zip(&programs).enumerate() {
        fleet
            .register_program(
                name,
                p,
                ModelConfig::for_program(p).with_shards(i + 1).with_queue_cap(0),
            )
            .unwrap();
    }
    assert_eq!(fleet.models(), names.iter().map(|s| s.to_string()).collect::<Vec<_>>());

    std::thread::scope(|scope| {
        for (mi, name) in names.iter().enumerate() {
            for client in 0..2u64 {
                let fleet = Arc::clone(&fleet);
                let p = &programs[mi];
                let reference = &references[mi];
                scope.spawn(move || {
                    let rows = random_rows(p.n_features, 30, 1000 + 10 * mi as u64 + client);
                    if client == 0 {
                        // Row-at-a-time client.
                        for (i, row) in rows.iter().enumerate() {
                            let reply = fleet.infer(name, row).unwrap();
                            assert_eq!(
                                reply.logits,
                                reference.infer_bins(&p.quantizer.bin_row(row)),
                                "{name} client {client} row {i}"
                            );
                        }
                    } else {
                        // Batched client through the same route.
                        let replies = fleet.infer_batch(name, &rows).unwrap();
                        for (i, reply) in replies.into_iter().enumerate() {
                            let reply = reply.unwrap();
                            assert_eq!(
                                reply.logits,
                                reference.infer_bins(&p.quantizer.bin_row(&rows[i])),
                                "{name} batch client row {i}"
                            );
                        }
                    }
                });
            }
        }
    });

    let stats = fleet.stats();
    assert_eq!(stats.admitted, 3 * 2 * 30);
    assert_eq!(stats.shed, 0);
    for (i, m) in stats.models.iter().enumerate() {
        assert_eq!(m.admitted, 60, "{}", m.name);
        assert_eq!(m.served, 60, "{}", m.name);
        assert_eq!(m.errors, 0, "{}", m.name);
        // BTreeMap order: alpha, beta, gamma — shard pools 1, 2, 3.
        assert_eq!(m.shards, i + 1, "{}", m.name);
    }
}

/// Epoch-CAS regression (ISSUE 9 satellite 3): a `swap`/`unregister`
/// pinned to a deployment epoch that has since been replaced must fail
/// with a structured error — not silently clobber the concurrently
/// re-registered route (last-writer-wins was the old behavior). The
/// live route keeps serving its own program bit-identically throughout,
/// and a swap pinned to the *current* epoch still succeeds.
#[test]
fn stale_epoch_swap_and_unregister_fail_structured_not_last_writer_wins() {
    let p1 = program(41, 12);
    let p2 = program(42, 12);
    let p3 = program(43, 12);
    let ref2 = CamEngine::new(&p2);
    let ref3 = CamEngine::new(&p3);
    let rows = random_rows(12, 16, 99);

    let fleet = Fleet::new();
    fleet
        .register_program("hot", &p1, ModelConfig::for_program(&p1).with_queue_cap(0))
        .unwrap();
    let e1 = fleet.route_epoch("hot").unwrap();

    // An operator replaces the deployment out from under the first
    // registrant: unregister + fresh register under the same name.
    fleet.unregister("hot").unwrap();
    fleet
        .register_program("hot", &p2, ModelConfig::for_program(&p2).with_queue_cap(0))
        .unwrap();
    let e2 = fleet.route_epoch("hot").unwrap();
    assert_ne!(e1, e2, "re-registration must mint a fresh epoch");

    // The first registrant's swap, pinned to its (stale) epoch, must be
    // refused with a structured error naming both epochs...
    let (backends, base) = slow_shards(&p3, 1, Duration::from_millis(0));
    let err = fleet
        .swap_backends_expecting("hot", e1, backends, base, ModelConfig::for_program(&p3))
        .unwrap_err();
    assert!(
        err.contains("deployment changed concurrently"),
        "swap error should explain the race, got: {err}"
    );
    assert!(
        err.contains(&format!("{e1}")) && err.contains(&format!("{e2}")),
        "swap error should name expected and live epochs, got: {err}"
    );

    // ...and so must its unregister.
    let err = fleet.unregister_expecting("hot", e1).unwrap_err();
    assert!(
        err.contains("deployment changed concurrently"),
        "unregister error should explain the race, got: {err}"
    );

    // The concurrently re-registered route was NOT clobbered: it still
    // serves p2 bit-identically at its own epoch.
    assert_eq!(fleet.route_epoch("hot").unwrap(), e2);
    for row in &rows {
        let reply = fleet.infer("hot", row).unwrap();
        assert_eq!(reply.logits, ref2.infer_bins(&p2.quantizer.bin_row(row)));
    }

    // A swap pinned to the CURRENT epoch goes through, mints a fresh
    // epoch, and serves the replacement program.
    let (backends, base) = slow_shards(&p3, 1, Duration::from_millis(0));
    fleet
        .swap_backends_expecting("hot", e2, backends, base, ModelConfig::for_program(&p3))
        .unwrap();
    let e3 = fleet.route_epoch("hot").unwrap();
    assert_ne!(e2, e3);
    for row in &rows {
        let reply = fleet.infer("hot", row).unwrap();
        assert_eq!(reply.logits, ref3.infer_bins(&p3.quantizer.bin_row(row)));
    }

    // Stale unregister still refused post-swap; current-epoch succeeds.
    assert!(fleet.unregister_expecting("hot", e2).is_err());
    fleet.unregister_expecting("hot", e3).unwrap();
    assert!(fleet.route_epoch("hot").is_none());
    fleet.shutdown();
}
