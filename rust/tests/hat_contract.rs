//! Contract 5 (DESIGN.md §5): hardware-aware-trained ensembles deploy
//! losslessly — across deployment precisions (4/6/8 bits), task families
//! (binary / multi-class / regression Table II generators) and both
//! trainer families:
//!
//! 1. `compile_for_deploy` reports **zero threshold-snapping error**
//!    (every trained threshold lies exactly on the CAM grid), and
//! 2. the compiled program's decisions agree with `Ensemble::logits`
//!    (the training-side reference) on held-out rows, with logits equal
//!    to the f64-vs-f32 summation-order tolerance of contract 1.

use xtime::compiler::{compile_for_deploy, requantize, CamEngine, CompileOptions};
use xtime::data::{by_name, Task};
use xtime::trees::hat::{self, HatParams};
use xtime::trees::{gbdt, GbdtParams, ModelKind, RfParams};

fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs()))
}

/// Decision agreement under contract 1's numeric slack: decisions must
/// match exactly unless the reference's decision itself hinges on a
/// near-tie finer than the f64-vs-f32 summation-order difference.
fn decisions_agree(task: Task, cam_logits: &[f32], cpu_logits: &[f32]) -> bool {
    match task {
        Task::Regression => close(cam_logits[0], cpu_logits[0]),
        Task::Binary => {
            // Mirror `Task::decide`: class = logit > 0.
            (cam_logits[0] > 0.0) == (cpu_logits[0] > 0.0) || cpu_logits[0].abs() < 1e-4
        }
        Task::MultiClass(_) => {
            let argmax = |l: &[f32]| {
                let mut best = 0usize;
                for c in 1..l.len() {
                    if l[c] > l[best] {
                        best = c;
                    }
                }
                best
            };
            let (ca, cb) = (argmax(cam_logits), argmax(cpu_logits));
            if ca == cb {
                return true;
            }
            // Near-tie: the two top reference logits are closer than the
            // representable summation-order difference.
            let mut sorted: Vec<f32> = cpu_logits.to_vec();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            (sorted[0] - sorted[1]).abs() < 1e-4
        }
    }
}

fn check_deployment(name: &str, n: usize, bits: u8, params: &HatParams) {
    let data = by_name(name).unwrap().generate_n(n);
    let split = data.split(0.8, 0.0, 41);
    let model = hat::train(&split.train, params, None);
    assert_eq!(model.quantizer.n_bits, bits, "{name}@{bits}: model not on the deploy grid");

    let (program, report) = compile_for_deploy(&model, bits, &CompileOptions::default())
        .unwrap_or_else(|e| panic!("{name}@{bits}: compile failed: {e}"));
    assert!(report.n_thresholds > 0, "{name}@{bits}: no thresholds checked");
    assert_eq!(
        report.n_exact, report.n_thresholds,
        "{name}@{bits}: off-grid thresholds in a HAT model: {report:?}"
    );
    report.assert_lossless(&format!("{name}@{bits}"));
    assert_eq!(program.n_bins, 1u16 << bits);

    // Numeric agreement: engine vs training-side reference.
    let engine = CamEngine::new(&program);
    let rows = split.test.n_rows().min(250);
    for i in 0..rows {
        let row = split.test.row(i);
        let cam = engine.infer_row(&program, row);
        let cpu = model.logits(row);
        for c in 0..cam.len() {
            assert!(
                close(cam[c], cpu[c]),
                "{name}@{bits} row {i} class {c}: {} vs {}",
                cam[c],
                cpu[c]
            );
        }
        assert!(
            decisions_agree(program.task, &cam, &cpu),
            "{name}@{bits} row {i}: decisions diverged beyond numeric slack"
        );
    }
}

#[test]
fn hat_gbdt_deploys_losslessly_across_bits_and_tasks() {
    // 4/6/8 bits × binary (churn) / multi-class (eye) / regression
    // (rossmann) Table II generators.
    for &bits in &[4u8, 6, 8] {
        for &(name, n) in &[("churn", 1200usize), ("eye", 1200), ("rossmann", 1000)] {
            let params = HatParams {
                deploy_bits: bits,
                kind: ModelKind::Gbdt,
                gbdt: GbdtParams { n_rounds: 6, max_leaves: 16, ..Default::default() },
                ..Default::default()
            };
            check_deployment(name, n, bits, &params);
        }
    }
}

#[test]
fn hat_rf_deploys_losslessly() {
    // The paper's RF dataset (gas) through the RF trainer at both
    // hardware precisions.
    for &bits in &[4u8, 8] {
        let params = HatParams {
            deploy_bits: bits,
            kind: ModelKind::RandomForest,
            rf: RfParams { n_estimators: 5, max_leaves: 16, ..Default::default() },
            ..Default::default()
        };
        check_deployment("gas", 1200, bits, &params);
    }
}

#[test]
fn ptq_of_high_precision_model_is_measurably_lossy() {
    // The contrast that motivates HAT: the same architecture trained at
    // 11 bits and snapped to 4 reports off-grid thresholds, while the
    // HAT model reports none (asserted above). This is the Fig. 9a
    // story at test scale.
    let data = by_name("churn").unwrap().generate_n(2000);
    let split = data.split(0.8, 0.0, 41);
    let uncon = gbdt::train(
        &split.train,
        &GbdtParams { n_rounds: 10, max_leaves: 32, n_bits: 11, ..Default::default() },
        None,
    );
    let (snapped, report) = requantize(&uncon, 4);
    assert!(!report.lossless(), "11→4-bit PTQ reported lossless: {report:?}");
    assert!(report.max_snap_err > 0.0);
    // The snapped model deploys on the 4-bit grid and its *own* redeploy
    // is lossless (idempotence of grid alignment).
    let (_, second) = requantize(&snapped, 4);
    assert!(second.lossless(), "re-snapping an on-grid model must be exact: {second:?}");
    let (program, _) = compile_for_deploy(&snapped, 4, &CompileOptions::default()).unwrap();
    assert_eq!(program.n_bins, 16);
}
