//! Simulator invariants against the paper's analytic pipeline equations
//! (Eq. 4 / Eq. 5, §III-C) and the NoC reduction correctness.

use xtime::compiler::{compile, CompileOptions};
use xtime::data::by_name;
use xtime::sim::{ideal_latency_cycles, simulate, ChipConfig, Workload};
use xtime::trees::{gbdt, GbdtParams};

/// Eq. (4): with ≤ 4 trees per core the pipeline accepts a sample every
/// λ_CAM = 4 cycles → 250 MSamples/s at 1 GHz (modulo the feature
/// broadcast, which for ≤ 8 features is 1 flit and does not bind).
#[test]
fn eq4_core_throughput_250_msps() {
    let d = by_name("churn").unwrap().generate_n(800);
    // 1 tree → II = 4.
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 1, max_leaves: 64, ..Default::default() },
        None,
    );
    let p = compile(&m, &CompileOptions::default()).unwrap();
    assert_eq!(p.max_trees_per_core(), 1);
    let cfg = ChipConfig::default();
    let rep = simulate(&p, &cfg, &Workload::saturating(50_000), 0.05);
    // Churn has 10 features → 2 input flits → input binds at 500 MS/s;
    // the core bound is 250 MS/s and must be the one observed.
    let msps = rep.throughput_msps;
    assert!((240.0..251.0).contains(&msps), "Eq.4 violated: {msps} MS/s");
}

/// Eq. (5): 5 trees per core → a bubble per extra tree → 200 MSamples/s.
#[test]
fn eq5_bubbles_drop_throughput_to_200_msps() {
    let d = by_name("churn").unwrap().generate_n(800);
    // 5 small trees packed into one core.
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 5, max_leaves: 32, ..Default::default() },
        None,
    );
    let p = compile(&m, &CompileOptions::default()).unwrap();
    assert_eq!(p.cores_per_replica(), 1);
    assert_eq!(p.max_trees_per_core(), 5);
    let cfg = ChipConfig::default();
    let rep = simulate(&p, &cfg, &Workload::saturating(50_000), 0.05);
    let msps = rep.throughput_msps;
    assert!((190.0..201.0).contains(&msps), "Eq.5 violated: {msps} MS/s");
}

/// λ_C = 12 cycles for the paper's 2-queued-segment, ≤4-trees design
/// point; single-sample latency = broadcast + λ_C + reduction + CP.
#[test]
fn single_sample_latency_decomposition() {
    let d = by_name("gas").unwrap().generate_n(600);
    let m = gbdt::train(
        &d,
        &GbdtParams { n_rounds: 1, max_leaves: 16, ..Default::default() },
        None,
    );
    let p = compile(&m, &CompileOptions::default()).unwrap();
    let cfg = ChipConfig::default();
    // gas: 129 features → 17 input flits, 2 queued segments, 6 classes.
    let expect = 17 // input serialization
        + 6 // broadcast hops
        + cfg.core_latency(8, 2, p.max_trees_per_core()) // 2 segments
        + 6 // upstream hops
        + 6 // class flit serialization
        + 6; // CP argmax over 6 classes
    assert_eq!(ideal_latency_cycles(&p, &cfg), expect);
    let rep = simulate(&p, &cfg, &Workload { n_samples: 1, inject_interval: 0 }, 0.05);
    assert_eq!(rep.latency_ns.mean as u64, expect); // 1 GHz → cycles == ns
}

/// The headline sanity: any Table II-sized single-sample inference stays
/// in the ~100 ns decade (vs µs–ms on GPU).
#[test]
fn hundred_ns_decade_for_all_datasets() {
    let cfg = ChipConfig::default();
    for name in ["churn", "eye", "gas", "telco"] {
        let d = by_name(name).unwrap().generate_n(500);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 4, max_leaves: 16, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let lat = ideal_latency_cycles(&p, &cfg) as f64 * cfg.cycle_ns();
        assert!(lat < 150.0, "{name}: {lat} ns");
    }
}

/// NoC reduction correctness under every §III-D mode, driven through the
/// compiled router configuration with the functional values.
#[test]
fn noc_reduction_matches_direct_sum() {
    use xtime::util::Rng;
    let mut rng = Rng::new(42);
    for (dataset, replicas) in [("churn", 1), ("eye", 1), ("churn", 4), ("covertype", 2)] {
        let d = by_name(dataset).unwrap().generate_n(700);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 6, max_leaves: 8, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions { replicas, core_rows: 32, ..Default::default() })
            .unwrap();
        // Inject a random logit per used slot, reduce through the tree,
        // and compare per-(class, replica) totals to the direct sum.
        let cores = p.cores_per_replica();
        let mut slot_values = Vec::new();
        let mut direct: std::collections::BTreeMap<(u16, u32), f32> = Default::default();
        for r in 0..p.n_replicas {
            for (i, core) in p.cores.iter().enumerate() {
                let v = rng.f32() - 0.5;
                slot_values.push((r * cores + i, v));
                *direct.entry((core.class, r as u32)).or_default() += v;
            }
        }
        let reduced = p.noc.reduce(&slot_values);
        let mut got: std::collections::BTreeMap<(u16, u32), f32> = Default::default();
        for (class, rep, v) in reduced {
            *got.entry((class, rep)).or_default() += v;
        }
        assert_eq!(direct.len(), got.len(), "{dataset}: stream count");
        for (k, v) in &direct {
            let g = got[k];
            assert!((g - v).abs() < 1e-4, "{dataset}: group {k:?}: {g} vs {v}");
        }
    }
}
