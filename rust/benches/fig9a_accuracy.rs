//! Regenerates **Fig. 9(a)**: model score per dataset under the four
//! training regimes —
//!   Unconstrained      (float-grade 11-bit thresholds, free topology),
//!   X-TIME 8bit        (≤4096 trees, ≤256 leaves, 8-bit bins),
//!   X-TIME 4bit        (4-bit bins, 2× leaves for iso-area),
//!   Only RF            (random forests only, 4-bit quantized) —
//! reproducing the claims that 8-bit matches the unconstrained baseline,
//! 4-bit loses noticeably on regression/wide-multiclass, and RF-only
//! degrades further.
//!
//! Run: `cargo bench --bench fig9a_accuracy` (XTIME_FAST=1 to smoke-test)

use xtime::bench_support::{bench_dataset, fast_mode};  // fig9a trains its own regimes
use xtime::data::Task;
use xtime::trees::{gbdt, metrics, paper_model, rf, GbdtParams, ModelKind, RfParams};
use xtime::util::bench::Table;

fn main() {
    let datasets = ["churn", "eye", "covertype", "gas", "gesture", "telco", "rossmann"];
    let trees_cap = if fast_mode() { 48 } else { 256 };
    println!("Fig. 9(a) reproduction (≤{trees_cap} trees per config):");

    let mut table =
        Table::new(&["dataset", "Unconstrained", "X-TIME 8bit", "X-TIME 4bit", "Only RF"]);
    for name in datasets {
        let data = bench_dataset(name);
        let split = data.split(0.8, 0.0, 17);
        let spec = paper_model(name).unwrap();
        let k = data.task.n_outputs();
        let rounds = (trees_cap / k).max(2);

        let mut scores = Vec::new();
        // Unconstrained: 11-bit bins ≈ float thresholds, generous leaves.
        for (bits, leaves) in [(11u8, 512usize), (8, spec.n_leaves_max), (4, spec.n_leaves_max * 2)]
        {
            let model = match spec.kind {
                ModelKind::Gbdt => gbdt::train(
                    &split.train,
                    &GbdtParams {
                        n_rounds: rounds,
                        max_leaves: leaves,
                        n_bits: bits,
                        ..Default::default()
                    },
                    None,
                ),
                ModelKind::RandomForest => rf::train(
                    &split.train,
                    &RfParams {
                        n_estimators: rounds,
                        max_leaves: leaves,
                        n_bits: bits,
                        ..Default::default()
                    },
                ),
            };
            scores.push(metrics::score(&model, &split.test));
        }
        // Only RF @4 bits (the paper's post-training-quantized RF case).
        let rf_model = rf::train(
            &split.train,
            &RfParams {
                n_estimators: rounds,
                max_leaves: spec.n_leaves_max,
                n_bits: 4,
                ..Default::default()
            },
        );
        scores.push(metrics::score(&rf_model, &split.test));

        table.row(&[
            format!(
                "{name}{}",
                if data.task == Task::Regression { " (R²)" } else { "" }
            ),
            format!("{:.3}", scores[0]),
            format!("{:.3}", scores[1]),
            format!("{:.3}", scores[2]),
            format!("{:.3}", scores[3]),
        ]);
    }
    table.print("Fig. 9(a) — score by training constraint");
    println!(
        "\npaper claims: 8-bit ≈ unconstrained; 4-bit loses ~20% on rossmann\n\
         and ~18% on gas; RF-only significantly degrades several datasets."
    );
}
