//! Regenerates **Fig. 9(a)**: model score per dataset under five
//! training/deployment regimes —
//!   Unconstrained      (float-grade 11-bit thresholds, free topology),
//!   HAT 8bit           (hardware-aware training on the 8-bit grid:
//!                       grid-aligned thresholds + variation-aware
//!                       scoring; ≤4096 trees, ≤256 leaves),
//!   PTQ 4bit           (the unconstrained model post-training-quantized
//!                       onto the 4-bit grid — the naive deployment whose
//!                       accuracy cliff Fig. 9a measures),
//!   HAT 4bit           (hardware-aware training directly on the 4-bit
//!                       grid, 2× leaves for iso-area, capped at the
//!                       256-word core),
//!   Only RF            (random forests only, 4-bit grid) —
//! reproducing the claims that 8-bit matches the unconstrained baseline
//! and that hardware-aware training recovers most of the 4-bit loss that
//! post-training quantization suffers.
//!
//! Every HAT model is additionally compiled with
//! `compile_for_deploy`, and the lossless-snapping assertion (DESIGN.md
//! §5, contract 5) is enforced: a HAT-trained ensemble must map onto the
//! CAM grid with zero threshold error.
//!
//! Run: `cargo bench --bench fig9a_accuracy` (XTIME_FAST=1 to smoke-test)

use xtime::bench_support::{bench_dataset, fast_mode}; // fig9a trains its own regimes
use xtime::compiler::{compile_for_deploy, requantize, CompileOptions};
use xtime::data::Task;
use xtime::trees::hat::{self, HatParams};
use xtime::trees::{gbdt, metrics, paper_model, rf, GbdtParams, ModelKind, RfParams};
use xtime::util::bench::Table;

fn main() {
    let datasets = ["churn", "eye", "covertype", "gas", "gesture", "telco", "rossmann"];
    let trees_cap = if fast_mode() { 48 } else { 256 };
    println!("Fig. 9(a) reproduction (≤{trees_cap} trees per config):");

    let mut table = Table::new(&[
        "dataset",
        "Unconstrained",
        "HAT 8bit",
        "PTQ 4bit",
        "HAT 4bit",
        "Only RF",
        "HAT recovery",
    ]);
    let mut recovered = 0usize;
    for name in datasets {
        let data = bench_dataset(name);
        let split = data.split(0.8, 0.0, 17);
        let spec = paper_model(name).unwrap();
        let k = data.task.n_outputs();
        let rounds = (trees_cap / k).max(2);

        // Unconstrained: 11-bit bins ≈ float thresholds, generous leaves.
        let uncon = match spec.kind {
            ModelKind::Gbdt => gbdt::train(
                &split.train,
                &GbdtParams {
                    n_rounds: rounds,
                    max_leaves: 512,
                    n_bits: 11,
                    ..Default::default()
                },
                None,
            ),
            ModelKind::RandomForest => rf::train(
                &split.train,
                &RfParams {
                    n_estimators: rounds,
                    max_leaves: 512,
                    n_bits: 11,
                    ..Default::default()
                },
            ),
        };
        let s_uncon = metrics::score(&uncon, &split.test);

        // Hardware-aware training at deployment precision: thresholds on
        // the exact deploy grid + variation-aware split scoring.
        let hat_train = |bits: u8, leaves: usize| {
            let params = HatParams {
                deploy_bits: bits,
                kind: spec.kind,
                gbdt: GbdtParams {
                    n_rounds: rounds,
                    max_leaves: leaves,
                    ..Default::default()
                },
                rf: RfParams {
                    n_estimators: rounds,
                    max_leaves: leaves,
                    ..Default::default()
                },
                ..Default::default()
            };
            hat::train(&split.train, &params, None)
        };
        let hat8 = hat_train(8, spec.n_leaves_max);
        // 4-bit: 2× leaves for iso-area, capped by the 256-word core.
        let hat4 = hat_train(4, (spec.n_leaves_max * 2).min(256));
        let s_hat8 = metrics::score(&hat8, &split.test);
        let s_hat4 = metrics::score(&hat4, &split.test);

        // Contract 5: HAT models must compile with zero snapping error.
        for (m, bits) in [(&hat8, 8u8), (&hat4, 4u8)] {
            let (_, report) = compile_for_deploy(m, bits, &CompileOptions::default())
                .unwrap_or_else(|e| panic!("{name} HAT {bits}bit failed to compile: {e}"));
            report.assert_lossless(&format!("{name} HAT {bits}bit"));
        }

        // Post-training quantization of the unconstrained model onto the
        // 4-bit grid — the lossy baseline HAT recovers from.
        let (ptq4, ptq_report) = requantize(&uncon, 4);
        let s_ptq4 = metrics::score(&ptq4, &split.test);
        assert!(
            ptq_report.n_thresholds > 0,
            "{name}: PTQ saw no thresholds — nothing was measured"
        );

        // Only RF @4 bits (the paper's RF-only case).
        let rf_model = rf::train(
            &split.train,
            &RfParams {
                n_estimators: rounds,
                max_leaves: spec.n_leaves_max,
                n_bits: 4,
                ..Default::default()
            },
        );
        let s_rf = metrics::score(&rf_model, &split.test);

        // The Fig. 9a recovery shape: HAT-4bit strictly above PTQ-4bit
        // and within ~1 point of the 8-bit baseline.
        let recovery = s_hat4 > s_ptq4 && s_hat4 >= s_hat8 - 0.01;
        recovered += recovery as usize;

        table.row(&[
            format!("{name}{}", if data.task == Task::Regression { " (R²)" } else { "" }),
            format!("{s_uncon:.3}"),
            format!("{s_hat8:.3}"),
            format!(
                "{s_ptq4:.3} ({}/{} off-grid, mean err {:.4})",
                ptq_report.n_thresholds - ptq_report.n_exact,
                ptq_report.n_thresholds,
                ptq_report.mean_snap_err()
            ),
            format!("{s_hat4:.3}"),
            format!("{s_rf:.3}"),
            if recovery { "yes".into() } else { format!("no (Δptq {:+.3})", s_hat4 - s_ptq4) },
        ]);
    }
    table.print("Fig. 9(a) — score by training/deployment regime");
    println!(
        "\nHAT recovery (4-bit HAT > 4-bit PTQ, within ~1 point of 8-bit): \
         {recovered}/{} datasets.",
        datasets.len()
    );
    println!(
        "paper claims: 8-bit ≈ unconstrained; naive 4-bit deployment loses\n\
         noticeably on regression/wide-multiclass; hardware-aware training\n\
         (grid-aligned thresholds + variation-aware splits) recovers it;\n\
         RF-only degrades several datasets. Contract 5 held: every HAT\n\
         model compiled with zero threshold-snapping error."
    );
    // The recovery-shape acceptance check is an empirical claim about the
    // full-size models; the XTIME_FAST smoke run (CI) trains 8×-smaller
    // ensembles where the shape is not guaranteed, so there it only warns.
    if fast_mode() {
        if recovered < 3 {
            println!(
                "warning: recovery shape held on only {recovered}/{} datasets in FAST mode \
                 (acceptance needs ≥3; not a failure here — rerun without XTIME_FAST \
                 for the real check)",
                datasets.len()
            );
        }
    } else {
        assert!(
            recovered >= 3,
            "HAT recovery shape must hold on at least 3 Table II datasets (got {recovered})"
        );
    }
}
