//! Self-healing accuracy-recovery bench: for each defect rate, run one
//! full closed-loop cycle — strike a live simulated card with a
//! deterministic memristor-defect draw, let the [`HealthMonitor`] trip,
//! and let the [`SelfHealer`] retrain/verify/hot-swap under sustained
//! client load — and record the deployed-accuracy recovery curve:
//!
//!   ideal (clean card)  →  degraded (struck card)  →  recovered
//!                          (defect-aware retrain on the same draw)
//!
//! The recovery must stay inside the Fig. 9(b) defect-retrain envelope:
//! `recovered ≥ degraded` is guaranteed by construction (the retrain
//! loop keeps the best pass by defective-deployment score, falling back
//! to the input model) and `recovered / ideal ≥ ENVELOPE_MIN_RATIO` is
//! asserted per cycle. Zero dropped replies across every swap is also
//! asserted (contract 6).
//!
//! Run: `cargo bench --bench self_heal` (XTIME_FAST=1 to smoke-test).
//! Writes `BENCH_self_heal.json` (schema: docs/BENCHMARKS.md).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use xtime::bench_support::{fast_mode, write_bench_json};
use xtime::cam::DefectSpec;
use xtime::compiler::{compile, defective_score, CamEngine, CamProgram, CompileOptions};
use xtime::coordinator::{
    Admission, Backend, BatchPolicy, CanarySet, DriftConfig, DriftVerdict, Fleet, HealContext,
    HealthMonitor, ModelConfig, SelfHealer, VerifyPolicy, DEFAULT_QUEUE_CAP,
};
use xtime::data::{by_name, Dataset};
use xtime::sim::{CardConfig, ChipConfig, DefectInjector, SimCardBackend};
use xtime::trees::hat::{self, HatParams};
use xtime::trees::GbdtParams;
use xtime::util::bench::Table;
use xtime::util::Json;

/// Fig. 9(b) defect-retrain envelope floor: recovered deployed accuracy
/// relative to the clean card, at memristor defect rates ≤ 20%.
const ENVELOPE_MIN_RATIO: f64 = 0.85;

const MODEL: &str = "churn";

/// Most disruptive draw at `pct` over the Fig-9b seed range: replays
/// candidates offline through the exact defective engine the struck card
/// switches to, returning the seed with minimum canary agreement (plus
/// that agreement, used to set a trip threshold that is guaranteed to
/// breach).
fn most_disruptive_draw(
    program: &CamProgram,
    canaries: &[Vec<f32>],
    pct: f64,
    seed_base: u64,
) -> (DefectSpec, u64, f64) {
    let clean = CamEngine::new(program);
    let reference: Vec<f32> = canaries.iter().map(|r| clean.predict(program, r)).collect();
    let spec = DefectSpec::memristor(pct);
    let mut best = (seed_base, 1.0f64);
    for seed in seed_base..seed_base + 32 {
        let defective = CamEngine::with_defects(program, spec, seed);
        let agree = canaries
            .iter()
            .zip(&reference)
            .filter(|(row, want)| defective.predict(program, row) == **want)
            .count() as f64
            / canaries.len() as f64;
        if agree < best.1 {
            best = (seed, agree);
        }
    }
    (spec, best.0, best.1)
}

/// One full closed-loop heal cycle at `pct`, under sustained load, on a
/// fresh pristine deployment. Returns the JSON datapoint.
#[allow(clippy::too_many_arguments)]
fn heal_cycle(
    pct: f64,
    idx: usize,
    train: &Dataset,
    eval: &Dataset,
    model: &xtime::trees::Ensemble,
    params: &HatParams,
    canary_rows: &[Vec<f32>],
    table: &mut Table,
) -> Json {
    let options = CompileOptions::default();
    let program = compile(model, &options).expect("compiles");
    let (spec, seed, struck_agreement) =
        most_disruptive_draw(&program, canary_rows, pct, 0xF19B + 0x100 * idx as u64);
    assert!(
        struck_agreement < 1.0,
        "no draw at {pct} disturbs the canaries; raise pct or canary count"
    );

    let ideal_acc = defective_score(&program, DefectSpec::memristor(0.0), seed, eval);
    let degraded_acc = defective_score(&program, spec, seed, eval);

    let fleet = Arc::new(Fleet::new());
    let injector = DefectInjector::new();
    let backend = SimCardBackend::new(&program, &ChipConfig::default(), &CardConfig::default())
        .with_injector(injector.clone());
    fleet
        .register_backends(
            MODEL,
            vec![Box::new(backend) as Box<dyn Backend>],
            Vec::new(),
            ModelConfig::for_program(&program),
        )
        .expect("register");

    // Trip threshold pinned just above the struck agreement: even a mild
    // defect rate trips deterministically (operator-tuned sensitivity).
    let trigger = (struck_agreement + 0.02).min(0.99);
    let drift_cfg = DriftConfig {
        trigger_below: trigger,
        clear_above: trigger,
        breaches_to_trip: 2,
        grace_probes: 0,
    };
    let canary = CanarySet::pin(&fleet, MODEL, canary_rows.to_vec()).expect("pin");
    let mut monitor = HealthMonitor::new(canary, drift_cfg);

    let mut healer = SelfHealer::new(HealContext {
        fleet: fleet.clone(),
        model: MODEL.to_string(),
        train: train.clone(),
        eval: eval.clone(),
        params: params.clone(),
        options,
        chip: ChipConfig::default(),
        card: CardConfig::default(),
        batch_policy: BatchPolicy::default(),
        queue_cap: DEFAULT_QUEUE_CAP,
        verify: VerifyPolicy::default(),
        store: None,
    });

    let stop = AtomicBool::new(false);
    let dropped = AtomicU64::new(0);
    let answered = AtomicU64::new(0);
    let (recovered_acc, probes_to_trip, report) = std::thread::scope(|scope| {
        let fleet2 = Arc::clone(&fleet);
        let (stop_ref, dropped_ref, answered_ref) = (&stop, &dropped, &answered);
        scope.spawn(move || {
            let mut i = 0usize;
            while !stop_ref.load(Ordering::Relaxed) {
                let row = eval.row(i % eval.n_rows());
                i += 1;
                match fleet2.submit(MODEL, row) {
                    Ok(Admission::Accepted(rx)) => match rx.recv() {
                        Ok(_) => {
                            answered_ref.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            dropped_ref.fetch_add(1, Ordering::Relaxed);
                        }
                    },
                    Ok(Admission::Shed { .. }) => std::thread::yield_now(),
                    Err(_) => break,
                }
            }
        });

        injector.strike(spec, seed);
        let mut probes = 0usize;
        loop {
            let reading = monitor.probe(&fleet, MODEL).expect("probe");
            probes += 1;
            if reading.verdict == DriftVerdict::Drift {
                break;
            }
            assert!(probes < 32, "detector failed to trip at {pct}");
        }

        let (repaired, _inj, report) = healer.heal(model.clone(), &injector).expect("heal");
        let repaired_program = compile(&repaired, &CompileOptions::default()).expect("compiles");
        let recovered_acc = defective_score(&repaired_program, spec, seed, eval);

        monitor.rearm_with(&fleet, MODEL).expect("rearm");
        stop.store(true, Ordering::Relaxed);
        (recovered_acc, probes, report)
    });

    drop(healer);
    Arc::try_unwrap(fleet).ok().expect("fleet refs").shutdown();

    let dropped = dropped.load(Ordering::Relaxed);
    let answered = answered.load(Ordering::Relaxed);
    let ratio = recovered_acc / ideal_acc;
    assert_eq!(dropped, 0, "contract 6: zero dropped replies at {pct}");
    assert!(
        recovered_acc >= degraded_acc,
        "retrain must not lose deployed accuracy: {degraded_acc} -> {recovered_acc}"
    );
    assert!(
        ratio >= ENVELOPE_MIN_RATIO,
        "recovery {ratio:.4} below the Fig. 9(b) retrain envelope at {pct}"
    );

    table.row(&[
        format!("{:.0}", pct * 100.0),
        format!("{ideal_acc:.4}"),
        format!("{degraded_acc:.4}"),
        format!("{recovered_acc:.4}"),
        format!("{ratio:.4}"),
        format!("{}", report.retrain.passes),
        format!("{:.2}", report.wall_s),
    ]);

    let mut j = Json::obj();
    j.set("defect_pct", Json::Num(pct))
        .set("seed", Json::Num(seed as f64))
        .set("ideal_acc", Json::Num(ideal_acc))
        .set("degraded_acc", Json::Num(degraded_acc))
        .set("recovered_acc", Json::Num(recovered_acc))
        .set("recovery_ratio", Json::Num(ratio))
        .set("retrain_passes", Json::Num(report.retrain.passes as f64))
        .set("initial_affected", Json::Num(report.retrain.initial_affected as f64))
        .set("final_affected", Json::Num(report.retrain.final_affected as f64))
        .set("probes_to_trip", Json::Num(probes_to_trip as f64))
        .set("bit_identity_rows", Json::Num(report.bit_identity_rows as f64))
        .set("heal_wall_s", Json::Num(report.wall_s))
        .set("load_replies", Json::Num(answered as f64))
        .set("dropped_replies", Json::Num(dropped as f64));
    j
}

fn main() {
    let pcts: &[f64] = if fast_mode() { &[0.10] } else { &[0.05, 0.10, 0.20] };
    let n_rows = if fast_mode() { 1_200 } else { 3_000 };
    let n_canaries = 96;

    let data = by_name(MODEL).expect("catalog dataset").generate_n(n_rows);
    let split = data.split(0.8, 0.0, 97);
    let params = HatParams {
        deploy_bits: 4,
        gbdt: GbdtParams {
            n_rounds: if fast_mode() { 10 } else { 24 },
            max_leaves: 16,
            ..Default::default()
        },
        retrain_passes: 2,
        ..Default::default()
    };
    let model = hat::train(&split.train, &params, None);
    let canary_rows: Vec<Vec<f32>> =
        (0..n_canaries).map(|i| split.test.row(i % split.test.n_rows()).to_vec()).collect();

    println!(
        "self-heal recovery bench: {MODEL}, {} defect rate(s), {} canaries",
        pcts.len(),
        n_canaries
    );
    let mut table = Table::new(&[
        "defect %",
        "ideal acc",
        "degraded acc",
        "recovered acc",
        "rel. recovery",
        "passes",
        "heal s",
    ]);
    let cycles: Vec<Json> = pcts
        .iter()
        .enumerate()
        .map(|(idx, &pct)| {
            heal_cycle(
                pct,
                idx,
                &split.train,
                &split.test,
                &model,
                &params,
                &canary_rows,
                &mut table,
            )
        })
        .collect();
    table.print("self-heal — deployed accuracy: ideal → degraded → recovered");

    let mut j = Json::obj();
    j.set("bench", Json::Str("self_heal".to_string()))
        .set("dataset", Json::Str(MODEL.to_string()))
        .set("n_rows", Json::Num(n_rows as f64))
        .set("n_canaries", Json::Num(n_canaries as f64))
        .set("fast_mode", Json::Bool(fast_mode()))
        .set("envelope_min_ratio", Json::Num(ENVELOPE_MIN_RATIO))
        .set("cycles", Json::Arr(cycles));
    write_bench_json("self_heal", &j);
    println!("all cycles inside the Fig. 9(b) retrain envelope (≥ {ENVELOPE_MIN_RATIO}).");
}
