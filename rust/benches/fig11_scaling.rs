//! Regenerates **Fig. 11**: (a) throughput as a function of N_trees and
//! tree depth D — X-TIME flat vs GPU ∝ 1/(N_trees·D); (b) throughput as a
//! function of N_feat — GPU flat vs X-TIME decaying once the feature
//! broadcast saturates the input port.
//!
//! Uses exact-topology synthetic ensembles (training is irrelevant to
//! architecture throughput).
//!
//! Run: `cargo bench --bench fig11_scaling`

use xtime::baselines::{GpuModel, GpuWorkload};
use xtime::bench_support::{fast_mode, random_ensemble};
use xtime::compiler::{compile, CompileOptions};
use xtime::data::Task;
use xtime::sim::{simulate, ChipConfig, Workload};
use xtime::util::bench::{rate, Table};

fn xtime_tput(n_trees: usize, depth: usize, n_feat: usize, cfg: &ChipConfig) -> Option<f64> {
    let model = random_ensemble(n_trees, depth, n_feat, Task::Binary, 77);
    let program = compile(&model, &CompileOptions { replicas: 0, ..Default::default() }).ok()?;
    let n = if fast_mode() { 20_000 } else { 100_000 };
    let rep = simulate(&program, cfg, &Workload::saturating(n), 0.05);
    Some(rep.throughput_msps * 1e6)
}

fn main() {
    let cfg = ChipConfig::default();
    let gpu = GpuModel::default();

    // ---- (a) N_trees × D sweep ---------------------------------------------
    let mut table = Table::new(&[
        "N_trees", "D", "X-TIME", "GPU", "X-TIME/GPU",
    ]);
    let tree_counts: &[usize] = if fast_mode() { &[64, 512] } else { &[16, 64, 256, 1024, 4096] };
    for &d in &[4usize, 6, 8] {
        for &n_trees in tree_counts {
            let Some(xt) = xtime_tput(n_trees, d, 32, &cfg) else {
                table.row(&[
                    format!("{n_trees}"),
                    format!("{d}"),
                    "chip full".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            };
            let g = gpu.throughput_sps(&GpuWorkload {
                n_trees,
                mean_depth: d as f64,
                max_depth: d as f64,
                n_features: 32,
            });
            table.row(&[
                format!("{n_trees}"),
                format!("{d}"),
                rate(xt, "S"),
                rate(g, "S"),
                format!("{:.0}×", xt / g),
            ]);
        }
    }
    table.print("Fig. 11(a) — throughput vs N_trees and D (N_feat = 32)");
    println!(
        "paper shape: X-TIME constant in N_trees and D (until cores run\n\
         out); GPU ∝ 1/(N_trees · D) → the gap grows with model size.\n"
    );

    // ---- (b) N_feat sweep -----------------------------------------------------
    let mut table = Table::new(&["N_feat", "X-TIME", "GPU", "input flits"]);
    let feats: &[usize] = if fast_mode() { &[8, 64, 130] } else { &[8, 16, 32, 64, 100, 130] };
    for &f in feats {
        let xt = xtime_tput(128, 6, f, &cfg).expect("fits");
        let g = gpu.throughput_sps(&GpuWorkload {
            n_trees: 128,
            mean_depth: 6.0,
            max_depth: 6.0,
            n_features: f,
        });
        table.row(&[
            format!("{f}"),
            rate(xt, "S"),
            rate(g, "S"),
            format!("{}", cfg.input_flits(f)),
        ]);
    }
    table.print("Fig. 11(b) — throughput vs N_feat (128 trees, D = 6)");
    println!(
        "paper shape: GPU flat in N_feat; X-TIME decays ∝ 1/⌈8·N_feat/64⌉\n\
         once the broadcast of features to all cores binds (the paper's\n\
         stated pain point)."
    );
}
