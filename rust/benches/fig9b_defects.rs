//! Regenerates **Fig. 9(b)**: mean relative accuracy (defective / ideal)
//! as a function of the defect percentage, for memristor-conductance
//! flips and DAC output flips, averaged over independent draws and over
//! the classification datasets — including the paper's observation that
//! fewer-tree-per-class models (covertype) degrade faster.
//!
//! Run: `cargo bench --bench fig9b_defects` (XTIME_FAST=1 to smoke-test)

use xtime::bench_support::{bench_split, cached_model, fast_mode};
use xtime::cam::DefectSpec;
use xtime::compiler::{compile, CamEngine, CompileOptions};
use xtime::util::bench::Table;

fn accuracy(
    engine: &CamEngine,
    program: &xtime::compiler::CamProgram,
    data: &xtime::data::Dataset,
    n: usize,
) -> f64 {
    let mut hits = 0usize;
    for i in 0..n {
        hits += (engine.predict(program, data.row(i)) == data.y[i]) as usize;
    }
    hits as f64 / n as f64
}

fn main() {
    let runs = if fast_mode() { 5 } else { 30 }; // paper: 100
    let test_n = if fast_mode() { 200 } else { 500 };
    let datasets = ["churn", "eye", "gesture", "telco"];
    println!("Fig. 9(b) reproduction ({runs} defect draws × {} datasets):", datasets.len());

    let setups: Vec<_> = datasets
        .iter()
        .map(|name| {
            let model = cached_model(name, 8, 1, Some(if fast_mode() { 24 } else { 96 }));
            let program = compile(&model, &CompileOptions::default()).unwrap();
            let data = bench_split(name).test;
            let ideal = {
                let e = CamEngine::new(&program);
                accuracy(&e, &program, &data, test_n)
            };
            (*name, program, data, ideal)
        })
        .collect();

    let mut table = Table::new(&["defect %", "memristor rel.acc", "DAC rel.acc"]);
    for pct in [0.0, 0.002, 0.01, 0.02, 0.05, 0.10, 0.20] {
        let mut rel = [0.0f64; 2];
        for (which, mk) in
            [DefectSpec::memristor(pct), DefectSpec::dac(pct)].into_iter().enumerate()
        {
            let mut sum = 0.0;
            let mut count = 0usize;
            for (_, program, data, ideal) in &setups {
                for run in 0..runs {
                    let e = CamEngine::with_defects(program, mk, 0xF19B + run as u64);
                    sum += accuracy(&e, program, data, test_n) / ideal;
                    count += 1;
                }
            }
            rel[which] = sum / count as f64;
        }
        table.row(&[
            format!("{:.1}", pct * 100.0),
            format!("{:.4}", rel[0]),
            format!("{:.4}", rel[1]),
        ]);
    }
    table.print("Fig. 9(b) — mean relative accuracy vs defect rate");

    // Small-ensemble sensitivity (paper: covertype's 193 trees/class make
    // it the most defect-sensitive model).
    let small = cached_model("eye", 8, 1, Some(6));
    let large = cached_model("eye", 8, 1, Some(if fast_mode() { 48 } else { 120 }));
    let data = bench_split("eye").test;
    let mut rels = Vec::new();
    for model in [&small, &large] {
        let program = compile(model, &CompileOptions::default()).unwrap();
        let ideal = accuracy(&CamEngine::new(&program), &program, &data, test_n);
        let mut sum = 0.0;
        for run in 0..runs {
            let e = CamEngine::with_defects(&program, DefectSpec::memristor(0.10), run as u64);
            sum += accuracy(&e, &program, &data, test_n) / ideal;
        }
        rels.push(sum / runs as f64);
    }
    println!(
        "\nensemble-size sensitivity at 10% defects: {} trees → rel.acc {:.4}; {} trees → {:.4}",
        small.n_trees(),
        rels[0],
        large.n_trees(),
        rels[1]
    );
    println!("paper: fewer trees per class → each tree's error matters more.");
    println!("paper operating point: ~0.2% flips ⇒ accuracy drop < 0.5%.");
}
