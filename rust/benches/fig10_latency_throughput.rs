//! Regenerates **Fig. 10**: latency (a) and throughput (b) of X-TIME vs
//! the V100/FIL GPU model vs the Booster ASIC model, across all seven
//! Table II dataset/model pairs, with input batching + tree replication
//! where legal (regression/binary), and the speedup ratios the paper
//! headlines (churn: 9740× latency, 119× throughput vs GPU).
//!
//! Run: `cargo bench --bench fig10_latency_throughput`
//! (XTIME_FAST=1 for a smoke run)

use xtime::baselines::{BoosterModel, BoosterWorkload, GpuModel, GpuWorkload};
use xtime::bench_support::cached_model;
use xtime::compiler::{compile, CompileOptions};
use xtime::sim::{ideal_latency_cycles, simulate, ChipConfig, Workload};
use xtime::util::bench::{rate, t, times, Table};

fn main() {
    let cfg = ChipConfig::default();
    let gpu = GpuModel::default();
    let booster = BoosterModel::default();
    let datasets = ["churn", "eye", "covertype", "gas", "gesture", "telco", "rossmann"];

    let mut lat_table = Table::new(&[
        "dataset", "X-TIME", "GPU (V100/FIL)", "Booster", "vs GPU", "vs Booster",
    ]);
    let mut tput_table = Table::new(&[
        "dataset", "X-TIME", "GPU (V100/FIL)", "Booster", "vs GPU", "vs Booster",
    ]);

    for name in datasets {
        let model = cached_model(name, 8, 1, None);
        // Batching/replication fills the chip (Fig. 7c) for every task;
        // multi-class replicas still help until the class-flit ceiling.
        let program = compile(&model, &CompileOptions { replicas: 0, ..Default::default() })
            .unwrap_or_else(|e| panic!("{name}: {e}"));

        // ---- X-TIME ------------------------------------------------------
        let n_samples = if xtime::bench_support::fast_mode() { 20_000 } else { 200_000 };
        let rep = simulate(&program, &cfg, &Workload::saturating(n_samples), 0.05);
        let xtime_lat_s = ideal_latency_cycles(&program, &cfg) as f64 * cfg.cycle_ns() * 1e-9;
        let xtime_tput = rep.throughput_msps * 1e6;

        // ---- GPU ----------------------------------------------------------
        let gw = GpuWorkload {
            n_trees: model.n_trees(),
            mean_depth: model.max_depth() as f64 * 0.8,
            max_depth: model.max_depth() as f64,
            n_features: model.n_features,
        };
        let gpu_lat = gpu.latency_s(&gw);
        let gpu_tput = gpu.throughput_sps(&gw);

        // ---- Booster (same fabric, O(D) LUT-walk core) ---------------------
        let bw = BoosterWorkload {
            max_depth: model.max_depth(),
            n_features: model.n_features,
            n_outputs: model.task.n_outputs(),
            n_replicas: program.n_replicas,
        };
        let boost_lat = booster.latency_s(&bw, &cfg);
        let boost_tput = booster.throughput_sps(&bw, &cfg);

        lat_table.row(&[
            name.to_string(),
            t(xtime_lat_s),
            t(gpu_lat),
            t(boost_lat),
            times(gpu_lat / xtime_lat_s),
            times(boost_lat / xtime_lat_s),
        ]);
        tput_table.row(&[
            name.to_string(),
            rate(xtime_tput, "S"),
            rate(gpu_tput, "S"),
            rate(boost_tput, "S"),
            times(xtime_tput / gpu_tput),
            times(xtime_tput / boost_tput),
        ]);
    }

    lat_table.print("Fig. 10(a) — inference latency");
    tput_table.print("Fig. 10(b) — inference throughput");
    println!(
        "\npaper shape: X-TIME ~100 ns vs GPU 10 µs–ms (10³–10⁴× gap, peak\n\
         9740× on churn); throughput 10–120× over GPU (peak 119× on churn);\n\
         Booster within ~1 decade on latency but ~8× lower throughput on\n\
         the regression dataset (1/4D core bound)."
    );
}
