//! §Perf hot-path bench: measured wall-clock of the repository's own
//! serving stack on this machine (not a paper figure — the optimization
//! target of EXPERIMENTS.md §Perf).
//!
//! Reports per-batch and per-sample times for:
//!   * the functional CAM engine — scalar (row-at-a-time) reference
//!     path, the indexed batch path (binary-search interval
//!     resolution), and the planned path (LUT + arena + query
//!     blocking) at 1 and N worker threads,
//!   * the exact CPU tree-walk,
//!   * the XLA AOT artifact (PJRT CPU, `fast_u8` layout) when built,
//! plus the end-to-end dynamic-batching server throughput, and a
//! dedicated scalar/indexed/planned(1T)/planned(NT) table on the
//! 1024-tree acceptance model whose rows/s are also written to
//! `BENCH_hotpath.json` at the repo root (the perf trajectory CI
//! uploads; record headline numbers in CHANGES.md too).
//!
//! This bench doubles as the CI agreement gate: before timing anything
//! it asserts the planned path (1T and NT) is bit-identical to the
//! scalar path on the smoke model and exits non-zero otherwise.
//!
//! Run: `cargo bench --bench hotpath` (XTIME_FAST=1 shrinks for CI)

use std::path::Path;
use xtime::bench_support::{
    cached_model, fast_mode, random_ensemble, random_query_bins, write_bench_json,
};
use xtime::compiler::{compile, compress_program, CamEngine, CompileOptions};
use xtime::coordinator::{BatchPolicy, Server, XlaBackend};
use xtime::data::{by_name, Task};
use xtime::runtime::XlaCamEngine;
use xtime::util::bench::{rate, t, time_fn, times, Table};
use xtime::util::Json;

/// CI gate: planned (1T and NT) must reproduce the scalar path bit for
/// bit — partials, logits and `SearchStats` — on `batch`. Panics (→
/// non-zero bench exit, failing the CI job) on any divergence.
fn assert_planned_agrees(engine: &CamEngine, batch: &[Vec<u16>], nt: usize, label: &str) {
    let mut want_stats = (0usize, 0usize);
    let mut want: Vec<Vec<f64>> = Vec::with_capacity(batch.len());
    for bins in batch {
        let (p, s) = engine.partials_bins_stats(bins);
        want_stats.0 += s.charged_rows;
        want_stats.1 += s.matches;
        want.push(p);
    }
    for threads in [1, nt] {
        let (got, stats) = engine.partials_planned_stats(batch, threads);
        assert_eq!(got, want, "{label}: planned({threads}T) partials diverged from scalar");
        assert_eq!(
            (stats.charged_rows, stats.matches),
            want_stats,
            "{label}: planned({threads}T) SearchStats diverged from scalar"
        );
    }
    println!("planned/scalar agreement on {label}: ✓ (1T and {nt}T)");
}

/// CI gate for contract 11: the capacity-compressed engine must
/// reproduce the uncompressed one bit for bit — logits, f64 partials
/// and `SearchStats` (`charged_rows` counts logical rows on both
/// sides) — on every execution path. Panics on any divergence.
fn assert_compressed_agrees(plain: &CamEngine, pressed: &CamEngine, batch: &[Vec<u16>], nt: usize) {
    assert_eq!(
        plain.infer_batch(batch),
        pressed.infer_batch(batch),
        "compressed engine diverged from uncompressed on infer_batch"
    );
    for threads in [1, nt] {
        let (a, sa) = plain.partials_planned_stats(batch, threads);
        let (b, sb) = pressed.partials_planned_stats(batch, threads);
        assert_eq!(a, b, "compressed planned({threads}T) partials diverged");
        assert_eq!(
            (sa.charged_rows, sa.matches),
            (sb.charged_rows, sb.matches),
            "compressed planned({threads}T) SearchStats diverged"
        );
    }
    println!("compressed/uncompressed agreement: ✓ (indexed, planned 1T and {nt}T)");
}

fn main() {
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let fast = fast_mode();
    let nt = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(2, 8);
    // 64 trees × ~130 leaves ≈ 8k CAM rows → fits the n16384 bucket.
    let model = cached_model("churn", 8, 1, Some(if fast { 16 } else { 64 }));
    let program = compile(&model, &CompileOptions::default()).unwrap();
    let n_data = if fast { 512 } else { 4096 };
    let data = by_name("churn").unwrap().generate_n(n_data);
    let bins: Vec<Vec<u16>> =
        (0..n_data).map(|i| program.quantizer.bin_row(data.row(i))).collect();

    println!(
        "hot-path bench: churn model, {} trees, {} CAM rows, {} features",
        model.n_trees(),
        program.total_rows(),
        program.n_features
    );

    let mut table = Table::new(&["path", "batch", "per batch", "per sample", "rate"]);

    // Exact CPU tree-walk (single thread).
    let cpu_rows = if fast { 64 } else { 256 };
    let s = time_fn(3, 20, || {
        for b in bins.iter().take(cpu_rows) {
            std::hint::black_box(model.logits_bins(b));
        }
    });
    table.row(&[
        "cpu tree-walk".into(),
        "1".into(),
        t(s.median / cpu_rows as f64),
        t(s.median / cpu_rows as f64),
        rate(cpu_rows as f64 / s.median, "S"),
    ]);

    // Functional CAM engine — scalar reference path (per-cell scan).
    let cam = CamEngine::new(&program);
    let scalar_rows = if fast { 16 } else { 64 };
    let s = time_fn(1, 5, || {
        for b in bins.iter().take(scalar_rows) {
            std::hint::black_box(cam.infer_bins(b));
        }
    });
    let churn_scalar_rate = scalar_rows as f64 / s.median;
    table.row(&[
        "cam-functional (scalar)".into(),
        "1".into(),
        t(s.median / scalar_rows as f64),
        t(s.median / scalar_rows as f64),
        rate(churn_scalar_rate, "S"),
    ]);

    // Functional CAM engine — indexed batch path (binary-search interval
    // resolution over the plan arena).
    let batch_rows = if fast { 64 } else { 256 };
    let batch: Vec<Vec<u16>> = bins.iter().take(batch_rows).cloned().collect();

    // CI agreement gate on the smoke model, before anything is timed.
    let smoke: Vec<Vec<u16>> = batch.iter().take(32).cloned().collect();
    assert_planned_agrees(&cam, &smoke, nt, "churn smoke model");

    let s = time_fn(1, 5, || {
        std::hint::black_box(cam.infer_batch(&batch));
    });
    let churn_batch_rate = batch_rows as f64 / s.median;
    table.row(&[
        "cam-functional (indexed)".into(),
        format!("{batch_rows}"),
        t(s.median),
        t(s.median / batch_rows as f64),
        rate(churn_batch_rate, "S"),
    ]);

    // Planned path: LUT + arena + query blocking, 1 and N threads.
    for threads in [1usize, nt] {
        let s = time_fn(1, 5, || {
            std::hint::black_box(cam.infer_planned(&batch, threads));
        });
        table.row(&[
            format!("cam-functional (planned, {threads}T)"),
            format!("{batch_rows}"),
            t(s.median),
            t(s.median / batch_rows as f64),
            rate(batch_rows as f64 / s.median, "S"),
        ]);
    }
    println!(
        "indexed/scalar on churn: {}",
        times(churn_batch_rate / churn_scalar_rate)
    );

    // XLA artifact, per device batch.
    if artifacts.join("manifest.json").exists() {
        let xla = XlaCamEngine::new(&program, &artifacts, 64).expect("xla engine");
        let cap = xla.max_batch();
        let xbatch: Vec<Vec<u16>> = bins.iter().take(cap).cloned().collect();
        let s = time_fn(2, 10, || {
            std::hint::black_box(xla.infer_bins_batch(&xbatch).unwrap());
        });
        table.row(&[
            format!("xla-aot ({})", xla.bucket().file),
            format!("{cap}"),
            t(s.median),
            t(s.median / cap as f64),
            rate(cap as f64 / s.median, "S"),
        ]);

        // Single-sample latency path (batch=1 bucket if available).
        if let Ok(xla1) = XlaCamEngine::new(&program, &artifacts, 1) {
            let one = vec![bins[0].clone()];
            let s = time_fn(2, 10, || {
                std::hint::black_box(xla1.infer_bins_batch(&one).unwrap());
            });
            table.row(&[
                format!("xla-aot ({})", xla1.bucket().file),
                "1".into(),
                t(s.median),
                t(s.median),
                rate(1.0 / s.median, "S"),
            ]);
        }

        // End-to-end server (submit→reply) under closed-loop load.
        let server = Server::start(
            Box::new(XlaBackend {
                engine: XlaCamEngine::new(&program, &artifacts, 64).unwrap(),
            }),
            BatchPolicy::default(),
            program.n_features,
        );
        let n = n_data;
        let t0 = std::time::Instant::now();
        let pending: Vec<_> = (0..n).map(|i| server.submit(bins[i % bins.len()].clone())).collect();
        for rx in pending {
            rx.recv().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        table.row(&[
            "server (xla, dyn-batch)".into(),
            format!("{:.0}", server.stats().mean_batch),
            "-".into(),
            t(wall / n as f64),
            rate(n as f64 / wall, "req"),
        ]);
    } else {
        println!("(artifacts missing — XLA rows skipped; run `make artifacts`)");
    }

    table.print("serving hot path on this machine");

    // The execution-path lever at acceptance scale: the same 1024-tree
    // topology the sharding tests and shard_scaling bench use. These
    // rows/s go to BENCH_hotpath.json (and CHANGES.md headlines).
    let n_trees = 1024;
    let big = random_ensemble(n_trees, 4, 32, Task::Binary, 7);
    let big_prog = compile(&big, &CompileOptions::default()).expect("compile 1024-tree model");
    let engine = CamEngine::new(&big_prog);
    let n_queries = if fast { 128 } else { 512 };
    let qbins = random_query_bins(&big_prog, n_queries, 0xB16);

    // Agreement gate at acceptance scale too (small slice — the scalar
    // path is slow).
    let gate: Vec<Vec<u16>> = qbins.iter().take(8).cloned().collect();
    assert_planned_agrees(&engine, &gate, nt, "1024-tree model");

    let big_scalar_rows = if fast { 8 } else { 32 };
    let s_scalar = time_fn(1, 5, || {
        for b in qbins.iter().take(big_scalar_rows) {
            std::hint::black_box(engine.infer_bins(b));
        }
    });
    let s_index = time_fn(1, 5, || {
        std::hint::black_box(engine.infer_batch(&qbins));
    });
    let s_planned1 = time_fn(1, 5, || {
        std::hint::black_box(engine.infer_planned(&qbins, 1));
    });
    let s_plannedn = time_fn(1, 5, || {
        std::hint::black_box(engine.infer_planned(&qbins, nt));
    });
    let scalar_rate = big_scalar_rows as f64 / s_scalar.median;
    let index_rate = n_queries as f64 / s_index.median;
    let planned1_rate = n_queries as f64 / s_planned1.median;
    let plannedn_rate = n_queries as f64 / s_plannedn.median;

    let mut big_table = Table::new(&["path", "batch", "per sample", "rows/s", "speedup"]);
    let mut push = |name: String, batch: String, sec_per: f64, r: f64| {
        big_table.row(&[name, batch, t(sec_per), rate(r, "row"), times(r / scalar_rate)]);
    };
    push(
        "scalar (per-cell scan)".into(),
        "1".into(),
        s_scalar.median / big_scalar_rows as f64,
        scalar_rate,
    );
    push(
        "indexed (binary search)".into(),
        format!("{n_queries}"),
        s_index.median / n_queries as f64,
        index_rate,
    );
    push(
        "planned (LUT+arena, 1T)".into(),
        format!("{n_queries}"),
        s_planned1.median / n_queries as f64,
        planned1_rate,
    );
    push(
        format!("planned (LUT+arena, {nt}T)"),
        format!("{n_queries}"),
        s_plannedn.median / n_queries as f64,
        plannedn_rate,
    );

    // Capacity compression (ISSUE 10, contract 11): the same acceptance
    // model with the sparsity-aware compression pass applied. The gate
    // proves bit-identity before anything is timed; the acceptance
    // floor is a ≥2× CAM-row reduction on this topology.
    let mut pressed_prog = big_prog.clone();
    let creport = compress_program(&mut pressed_prog);
    println!("compression: {}", creport.render());
    assert!(
        creport.row_reduction() >= 2.0,
        "acceptance: 1024-tree model must compress ≥2× in CAM rows, got {:.2}×",
        creport.row_reduction()
    );
    let pressed = CamEngine::new(&pressed_prog);
    assert_compressed_agrees(&engine, &pressed, &gate, nt);
    let s_press1 = time_fn(1, 5, || {
        std::hint::black_box(pressed.infer_planned(&qbins, 1));
    });
    let s_pressn = time_fn(1, 5, || {
        std::hint::black_box(pressed.infer_planned(&qbins, nt));
    });
    let press1_rate = n_queries as f64 / s_press1.median;
    let pressn_rate = n_queries as f64 / s_pressn.median;
    push(
        "planned, compressed (1T)".into(),
        format!("{n_queries}"),
        s_press1.median / n_queries as f64,
        press1_rate,
    );
    push(
        format!("planned, compressed ({nt}T)"),
        format!("{n_queries}"),
        s_pressn.median / n_queries as f64,
        pressn_rate,
    );

    big_table.print(&format!(
        "functional engine scalar vs indexed vs planned — {n_trees}-tree model, {} CAM rows",
        big_prog.total_rows()
    ));

    // Machine-readable trajectory datapoint at the repo root.
    let mut paths = Json::obj();
    let path_row = |rate_rps: f64, threads: usize| {
        let mut o = Json::obj();
        o.set("rows_per_s", Json::Num(rate_rps)).set("threads", Json::Num(threads as f64));
        o
    };
    paths
        .set("scalar", path_row(scalar_rate, 1))
        .set("indexed", path_row(index_rate, 1))
        .set("planned_1t", path_row(planned1_rate, 1))
        .set("planned_nt", path_row(plannedn_rate, nt));
    let mut model = Json::obj();
    model
        .set("trees", Json::Num(n_trees as f64))
        .set("cam_rows", Json::Num(big_prog.total_rows() as f64))
        .set("features", Json::Num(big_prog.n_features as f64))
        .set("cores", Json::Num(engine.n_cores() as f64));
    let mut speedup = Json::obj();
    speedup
        .set("indexed_vs_scalar", Json::Num(index_rate / scalar_rate))
        .set("planned_1t_vs_scalar", Json::Num(planned1_rate / scalar_rate))
        .set("planned_nt_vs_scalar", Json::Num(plannedn_rate / scalar_rate))
        .set("planned_nt_vs_indexed", Json::Num(plannedn_rate / index_rate));
    // Compression datapoint: the full CompressionReport plus the
    // compressed-path rates (docs/BENCHMARKS.md `compression` block).
    let mut compression = creport.to_json();
    compression
        .set("phys_rows", Json::Num(pressed_prog.total_phys_rows() as f64))
        .set("planned_1t_rows_per_s", Json::Num(press1_rate))
        .set("planned_nt_rows_per_s", Json::Num(pressn_rate))
        .set("planned_nt_vs_uncompressed", Json::Num(pressn_rate / plannedn_rate));
    let mut j = Json::obj();
    j.set("bench", Json::Str("hotpath".into()))
        .set("fast_mode", Json::Bool(fast))
        .set("n_queries", Json::Num(n_queries as f64))
        .set("model", model)
        .set("paths", paths)
        .set("speedup", speedup)
        .set("compression", compression);
    write_bench_json("hotpath", &j);
}
