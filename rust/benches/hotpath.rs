//! §Perf hot-path bench: measured wall-clock of the repository's own
//! serving stack on this machine (not a paper figure — the optimization
//! target of EXPERIMENTS.md §Perf).
//!
//! Reports per-batch and per-sample times for:
//!   * the XLA AOT artifact (PJRT CPU, `fast_u8` layout),
//!   * the functional CAM engine,
//!   * the exact CPU tree-walk,
//! plus the end-to-end dynamic-batching server throughput.
//!
//! Run: `cargo bench --bench hotpath`

use std::path::Path;
use xtime::bench_support::cached_model;
use xtime::compiler::{compile, CamEngine, CompileOptions};
use xtime::coordinator::{BatchPolicy, Server, XlaBackend};
use xtime::data::by_name;
use xtime::runtime::XlaCamEngine;
use xtime::util::bench::{rate, t, time_fn, Table};

fn main() {
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    // 64 trees × ~130 leaves ≈ 8k CAM rows → fits the n16384 bucket.
    let model = cached_model("churn", 8, 1, Some(64));
    let program = compile(&model, &CompileOptions::default()).unwrap();
    let data = by_name("churn").unwrap().generate_n(4096);
    let bins: Vec<Vec<u16>> =
        (0..4096).map(|i| program.quantizer.bin_row(data.row(i))).collect();

    println!(
        "hot-path bench: churn model, {} trees, {} CAM rows, {} features",
        model.n_trees(),
        program.total_rows(),
        program.n_features
    );

    let mut table = Table::new(&["path", "batch", "per batch", "per sample", "rate"]);

    // Exact CPU tree-walk (single thread).
    let s = time_fn(3, 20, || {
        for b in bins.iter().take(256) {
            std::hint::black_box(model.logits_bins(b));
        }
    });
    table.row(&[
        "cpu tree-walk".into(),
        "1".into(),
        t(s.median / 256.0),
        t(s.median / 256.0),
        rate(256.0 / s.median, "S"),
    ]);

    // Functional CAM engine.
    let cam = CamEngine::new(&program);
    let s = time_fn(1, 5, || {
        for b in bins.iter().take(64) {
            std::hint::black_box(cam.infer_bins(b));
        }
    });
    table.row(&[
        "cam-functional".into(),
        "1".into(),
        t(s.median / 64.0),
        t(s.median / 64.0),
        rate(64.0 / s.median, "S"),
    ]);

    // XLA artifact, per device batch.
    if artifacts.join("manifest.json").exists() {
        let xla = XlaCamEngine::new(&program, &artifacts, 64).expect("xla engine");
        let cap = xla.max_batch();
        let batch: Vec<Vec<u16>> = bins.iter().take(cap).cloned().collect();
        let s = time_fn(2, 10, || {
            std::hint::black_box(xla.infer_bins_batch(&batch).unwrap());
        });
        table.row(&[
            format!("xla-aot ({})", xla.bucket().file),
            format!("{cap}"),
            t(s.median),
            t(s.median / cap as f64),
            rate(cap as f64 / s.median, "S"),
        ]);

        // Single-sample latency path (batch=1 bucket if available).
        if let Ok(xla1) = XlaCamEngine::new(&program, &artifacts, 1) {
            let one = vec![bins[0].clone()];
            let s = time_fn(2, 10, || {
                std::hint::black_box(xla1.infer_bins_batch(&one).unwrap());
            });
            table.row(&[
                format!("xla-aot ({})", xla1.bucket().file),
                "1".into(),
                t(s.median),
                t(s.median),
                rate(1.0 / s.median, "S"),
            ]);
        }

        // End-to-end server (submit→reply) under closed-loop load.
        let server = Server::start(
            Box::new(XlaBackend {
                engine: XlaCamEngine::new(&program, &artifacts, 64).unwrap(),
            }),
            BatchPolicy::default(),
            program.n_features,
        );
        let n = 4096;
        let t0 = std::time::Instant::now();
        let pending: Vec<_> = (0..n).map(|i| server.submit(bins[i % bins.len()].clone())).collect();
        for rx in pending {
            rx.recv().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        table.row(&[
            "server (xla, dyn-batch)".into(),
            format!("{:.0}", server.stats().mean_batch),
            "-".into(),
            t(wall / n as f64),
            rate(n as f64 / wall, "req"),
        ]);
    } else {
        println!("(artifacts missing — XLA rows skipped; run `make artifacts`)");
    }

    table.print("serving hot path on this machine");
}
