//! §Perf hot-path bench: measured wall-clock of the repository's own
//! serving stack on this machine (not a paper figure — the optimization
//! target of EXPERIMENTS.md §Perf).
//!
//! Reports per-batch and per-sample times for:
//!   * the functional CAM engine — scalar (row-at-a-time) reference path
//!     vs the batched feature-major interval index (`infer_batch`),
//!   * the exact CPU tree-walk,
//!   * the XLA AOT artifact (PJRT CPU, `fast_u8` layout) when built,
//! plus the end-to-end dynamic-batching server throughput, and a
//! dedicated scalar-vs-batched table on the 1024-tree acceptance model
//! (record its rows/s in CHANGES.md when the hot path changes).
//!
//! Run: `cargo bench --bench hotpath` (XTIME_FAST=1 shrinks for CI)

use std::path::Path;
use xtime::bench_support::{cached_model, fast_mode, random_ensemble, random_query_bins};
use xtime::compiler::{compile, CamEngine, CompileOptions};
use xtime::coordinator::{BatchPolicy, Server, XlaBackend};
use xtime::data::{by_name, Task};
use xtime::runtime::XlaCamEngine;
use xtime::util::bench::{rate, t, time_fn, times, Table};

fn main() {
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let fast = fast_mode();
    // 64 trees × ~130 leaves ≈ 8k CAM rows → fits the n16384 bucket.
    let model = cached_model("churn", 8, 1, Some(if fast { 16 } else { 64 }));
    let program = compile(&model, &CompileOptions::default()).unwrap();
    let n_data = if fast { 512 } else { 4096 };
    let data = by_name("churn").unwrap().generate_n(n_data);
    let bins: Vec<Vec<u16>> =
        (0..n_data).map(|i| program.quantizer.bin_row(data.row(i))).collect();

    println!(
        "hot-path bench: churn model, {} trees, {} CAM rows, {} features",
        model.n_trees(),
        program.total_rows(),
        program.n_features
    );

    let mut table = Table::new(&["path", "batch", "per batch", "per sample", "rate"]);

    // Exact CPU tree-walk (single thread).
    let cpu_rows = if fast { 64 } else { 256 };
    let s = time_fn(3, 20, || {
        for b in bins.iter().take(cpu_rows) {
            std::hint::black_box(model.logits_bins(b));
        }
    });
    table.row(&[
        "cpu tree-walk".into(),
        "1".into(),
        t(s.median / cpu_rows as f64),
        t(s.median / cpu_rows as f64),
        rate(cpu_rows as f64 / s.median, "S"),
    ]);

    // Functional CAM engine — scalar reference path (per-cell scan).
    let cam = CamEngine::new(&program);
    let scalar_rows = if fast { 16 } else { 64 };
    let s = time_fn(1, 5, || {
        for b in bins.iter().take(scalar_rows) {
            std::hint::black_box(cam.infer_bins(b));
        }
    });
    let churn_scalar_rate = scalar_rows as f64 / s.median;
    table.row(&[
        "cam-functional (scalar)".into(),
        "1".into(),
        t(s.median / scalar_rows as f64),
        t(s.median / scalar_rows as f64),
        rate(churn_scalar_rate, "S"),
    ]);

    // Functional CAM engine — batched interval index.
    let batch_rows = if fast { 64 } else { 256 };
    let batch: Vec<Vec<u16>> = bins.iter().take(batch_rows).cloned().collect();
    let s = time_fn(1, 5, || {
        std::hint::black_box(cam.infer_batch(&batch));
    });
    let churn_batch_rate = batch_rows as f64 / s.median;
    table.row(&[
        "cam-functional (batched)".into(),
        format!("{batch_rows}"),
        t(s.median),
        t(s.median / batch_rows as f64),
        rate(churn_batch_rate, "S"),
    ]);
    println!(
        "batched/scalar on churn: {}",
        times(churn_batch_rate / churn_scalar_rate)
    );

    // XLA artifact, per device batch.
    if artifacts.join("manifest.json").exists() {
        let xla = XlaCamEngine::new(&program, &artifacts, 64).expect("xla engine");
        let cap = xla.max_batch();
        let xbatch: Vec<Vec<u16>> = bins.iter().take(cap).cloned().collect();
        let s = time_fn(2, 10, || {
            std::hint::black_box(xla.infer_bins_batch(&xbatch).unwrap());
        });
        table.row(&[
            format!("xla-aot ({})", xla.bucket().file),
            format!("{cap}"),
            t(s.median),
            t(s.median / cap as f64),
            rate(cap as f64 / s.median, "S"),
        ]);

        // Single-sample latency path (batch=1 bucket if available).
        if let Ok(xla1) = XlaCamEngine::new(&program, &artifacts, 1) {
            let one = vec![bins[0].clone()];
            let s = time_fn(2, 10, || {
                std::hint::black_box(xla1.infer_bins_batch(&one).unwrap());
            });
            table.row(&[
                format!("xla-aot ({})", xla1.bucket().file),
                "1".into(),
                t(s.median),
                t(s.median),
                rate(1.0 / s.median, "S"),
            ]);
        }

        // End-to-end server (submit→reply) under closed-loop load.
        let server = Server::start(
            Box::new(XlaBackend {
                engine: XlaCamEngine::new(&program, &artifacts, 64).unwrap(),
            }),
            BatchPolicy::default(),
            program.n_features,
        );
        let n = n_data;
        let t0 = std::time::Instant::now();
        let pending: Vec<_> = (0..n).map(|i| server.submit(bins[i % bins.len()].clone())).collect();
        for rx in pending {
            rx.recv().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        table.row(&[
            "server (xla, dyn-batch)".into(),
            format!("{:.0}", server.stats().mean_batch),
            "-".into(),
            t(wall / n as f64),
            rate(n as f64 / wall, "req"),
        ]);
    } else {
        println!("(artifacts missing — XLA rows skipped; run `make artifacts`)");
    }

    table.print("serving hot path on this machine");

    // The batched-vs-scalar lever at acceptance scale: the same
    // 1024-tree topology the sharding tests and shard_scaling bench use.
    // This is the number to record in CHANGES.md.
    let n_trees = 1024;
    let big = random_ensemble(n_trees, 4, 32, Task::Binary, 7);
    let big_prog = compile(&big, &CompileOptions::default()).expect("compile 1024-tree model");
    let engine = CamEngine::new(&big_prog);
    let n_queries = if fast { 128 } else { 512 };
    let qbins = random_query_bins(&big_prog, n_queries, 0xB16);

    let big_scalar_rows = if fast { 8 } else { 32 };
    let s_scalar = time_fn(1, 5, || {
        for b in qbins.iter().take(big_scalar_rows) {
            std::hint::black_box(engine.infer_bins(b));
        }
    });
    let s_batch = time_fn(1, 5, || {
        std::hint::black_box(engine.infer_batch(&qbins));
    });
    let scalar_rate = big_scalar_rows as f64 / s_scalar.median;
    let batch_rate = n_queries as f64 / s_batch.median;

    let mut big_table = Table::new(&["path", "batch", "per sample", "rows/s", "speedup"]);
    big_table.row(&[
        "scalar (per-cell scan)".into(),
        "1".into(),
        t(s_scalar.median / big_scalar_rows as f64),
        rate(scalar_rate, "row"),
        times(1.0),
    ]);
    big_table.row(&[
        "batched (interval index)".into(),
        format!("{n_queries}"),
        t(s_batch.median / n_queries as f64),
        rate(batch_rate, "row"),
        times(batch_rate / scalar_rate),
    ]);
    big_table.print(&format!(
        "functional engine scalar vs batched — {n_trees}-tree model, {} CAM rows",
        big_prog.total_rows()
    ));
}
