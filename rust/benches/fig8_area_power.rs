//! Regenerates **Fig. 8** (area and peak-power breakdown of the 4096-core
//! chip) and the **§V-B energy point** (~0.3 nJ/decision reachable for
//! small-feature models).
//!
//! Run: `cargo bench --bench fig8_area_power`

use xtime::bench_support::cached_model;
use xtime::compiler::{compile, compress_program, CamEngine, CompileOptions};
use xtime::data::by_name;
use xtime::sim::{chip_area, chip_peak_power, Activity, ChipConfig};
use xtime::util::bench::Table;

fn main() {
    let cfg = ChipConfig::default();

    let area = chip_area(&cfg);
    let mut t = Table::new(&["component", "area (mm²)", "share"]);
    for (name, v) in area.rows("mm²") {
        t.row(&[name, format!("{v:.2}"), format!("{:.1}%", 100.0 * v / area.total())]);
    }
    t.row(&["TOTAL".into(), format!("{:.2}", area.total()), "100%".into()]);
    t.print("Fig. 8(a) — area breakdown");

    let power = chip_peak_power(&cfg);
    let mut t = Table::new(&["component", "peak power (W)", "share"]);
    for (name, v) in power.rows("W") {
        t.row(&[name, format!("{v:.2}"), format!("{:.1}%", 100.0 * v / power.total())]);
    }
    t.row(&["TOTAL".into(), format!("{:.2}", power.total()), "100%".into()]);
    t.print("Fig. 8(b) — peak power breakdown");
    println!("\npaper: 19 W peak, aCAM-dominated, \"comparable to GPU idle power (~25 W)\"");

    // §V-B energy/decision on the churn-style binary model, with the
    // selective-precharge activity measured by the functional engine.
    let model = cached_model("churn", 8, 1, Some(64));
    let program = compile(&model, &CompileOptions::default()).unwrap();
    let engine = CamEngine::new(&program);
    let data = by_name("churn").unwrap().generate_n(256);
    let mut charged = 0usize;
    for i in 0..128 {
        let bins = program.quantizer.bin_row(data.row(i));
        charged += engine.infer_bins_stats(&bins).1.charged_rows;
    }
    let frac = charged as f64 / 128.0 / program.total_rows() as f64 - 1.0; // beyond segment 1
    let act = Activity::estimate(&program, &cfg, frac.clamp(0.01, 1.0));
    println!(
        "\n§V-B energy point: churn-style model ({} trees, {} rows, {} cores) → {:.3} nJ/decision",
        model.n_trees(),
        program.total_rows(),
        program.cores_per_replica(),
        act.energy_nj()
    );
    println!("paper: \"down to 0.3 nJ/Dec\" for high-throughput operation");

    // Capacity-compression delta (ISSUE 10): the same model after the
    // sparsity-aware pass. Physical words drop, so the charged
    // match-line/sub-cell population — and with it search energy —
    // drops too, while the logical row set (and the decision bits) are
    // unchanged by contract 11.
    let mut pressed = program.clone();
    let report = compress_program(&mut pressed);
    let act_pressed = Activity::estimate(&pressed, &cfg, frac.clamp(0.01, 1.0));
    let mut t = Table::new(&["layout", "CAM rows", "phys words", "nJ/decision"]);
    t.row(&[
        "uncompressed".into(),
        format!("{}", program.total_rows()),
        format!("{}", program.total_rows()),
        format!("{:.3}", act.energy_nj()),
    ]);
    t.row(&[
        "compressed".into(),
        format!("{}", pressed.total_rows()),
        format!("{}", pressed.total_phys_rows()),
        format!("{:.3}", act_pressed.energy_nj()),
    ]);
    t.print(&format!(
        "capacity compression — {:.2}× rows, {:.2}× search energy (bit-identical decisions)",
        report.row_reduction(),
        act.energy_nj() / act_pressed.energy_nj()
    ));
}
