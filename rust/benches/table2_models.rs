//! Regenerates **Table II** (datasets and models characterization):
//! per dataset — task, samples, N_feat, N_classes, model family, N_trees,
//! N_leaves,max — for the trained stand-in models, plus their measured
//! accuracy (not in the paper's table but recorded for EXPERIMENTS.md).
//!
//! Run: `cargo bench --bench table2_models` (XTIME_FAST=1 for a smoke run)

use xtime::bench_support::{bench_split, cached_model, tree_scale};
use xtime::data::by_name;
use xtime::trees::{metrics, paper_model};
use xtime::util::bench::Table;

fn main() {
    println!("Table II reproduction (tree scale ×{}):", tree_scale());
    let mut table = Table::new(&[
        "Dataset", "ID", "Task", "Samples", "N_feat", "N_classes", "Model", "N_trees",
        "N_leaves,max", "score",
    ]);
    for (id, name) in
        ["churn", "eye", "covertype", "gas", "gesture", "telco", "rossmann"].iter().enumerate()
    {
        let spec = by_name(name).unwrap();
        let mspec = paper_model(name).unwrap();
        let model = cached_model(name, 8, 1, None);
        let split = bench_split(name);
        let score = metrics::score(&model, &split.test);
        table.row(&[
            name.to_string(),
            format!("{}", id + 1),
            spec.task.name(),
            format!("{}", spec.paper_samples),
            format!("{}", spec.n_features),
            format!("{}", spec.task.n_classes()),
            mspec.kind.name().to_string(),
            format!("{}", model.n_trees()),
            format!("{}", model.max_leaves()),
            format!("{score:.3}"),
        ]);
    }
    table.print("Table II — datasets and models");
    println!(
        "\npaper targets: N_trees = 404/2352/1351/1356/1895/159/2017, \
         N_leaves,max = 256/256/231/217/256/4/256"
    );
}
