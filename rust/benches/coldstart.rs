//! Artifact cold start (ISSUE 8): how much faster is loading a compiled
//! model back out of the content-addressed store than re-deriving it
//! from data (train + compile)?
//!
//! The HAT retrain → redeploy loop (PR 3) and the fleet's hot-swap path
//! (PR 5) both assumed an in-memory program; the artifact store makes
//! "redeploy" a disk read instead. This bench measures that gap and
//! asserts the loaded program stays bit-identical to the original on a
//! random query batch (contract 9) — a benchmark that silently measured
//! a *different* model would be worthless.
//!
//! Writes BENCH_coldstart.json (schema in docs/BENCHMARKS.md).
//!
//! Run: `cargo bench --bench coldstart` (XTIME_FAST=1 to shrink)

use std::time::Instant;
use xtime::artifact::{export_program, ArtifactStore};
use xtime::bench_support::{fast_mode, random_query_bins, write_bench_json};
use xtime::compiler::{compile, CamEngine, CompileOptions};
use xtime::data::by_name;
use xtime::trees::{gbdt, GbdtParams};
use xtime::util::bench::{t, times, Table};
use xtime::util::Json;

fn main() {
    let dataset = "churn";
    let (n_rows, n_rounds) = if fast_mode() { (1_000, 8) } else { (6_000, 64) };
    let load_iters = 5usize;

    let data = by_name(dataset).expect("catalog").generate_n(n_rows);

    let t0 = Instant::now();
    let model = gbdt::train(
        &data,
        &GbdtParams { n_rounds, max_leaves: 32, ..Default::default() },
        None,
    );
    let train_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let program = compile(&model, &CompileOptions::default()).expect("compile");
    let compile_s = t0.elapsed().as_secs_f64();

    let root = std::env::temp_dir().join(format!("xtime-coldstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let mut store = ArtifactStore::open(&root).expect("open store");

    let t0 = Instant::now();
    let id = export_program(&mut store, &program, None).expect("export");
    let export_s = t0.elapsed().as_secs_f64();
    let artifact_bytes: u64 = {
        let art = store.load(&id).expect("load");
        art.manifest.blobs.values().map(|b| b.size).sum()
    };

    let mut load_times = Vec::with_capacity(load_iters);
    let mut loaded = None;
    for _ in 0..load_iters {
        // Re-open each iteration: a true cold start pays the index read
        // and the digest verification, not just the file read.
        let t0 = Instant::now();
        let store = ArtifactStore::open(&root).expect("open store");
        let art = store.load(&id).expect("load");
        load_times.push(t0.elapsed().as_secs_f64());
        loaded = Some(art);
    }
    let load_mean_s = load_times.iter().sum::<f64>() / load_times.len() as f64;
    let load_min_s = load_times.iter().cloned().fold(f64::INFINITY, f64::min);

    // Contract 9 spot check: the loaded program is the same model.
    let art = loaded.expect("at least one load");
    let queries = random_query_bins(&program, 256, 0xC01D);
    let a = CamEngine::new(&program).infer_batch(&queries);
    let b = CamEngine::new(&art.program).infer_batch(&queries);
    assert!(
        a.iter().zip(&b).all(|(x, y)| {
            x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        }),
        "loaded program diverges from the original — bench is void"
    );

    let retrain_s = train_s + compile_s;
    let speedup = retrain_s / load_mean_s.max(1e-12);

    let mut table = Table::new(&["stage", "time", "notes"]);
    table.row(&["train".into(), t(train_s), format!("{} trees on {n_rows} rows", program.n_trees)]);
    table.row(&["compile".into(), t(compile_s), format!("{} CAM rows", program.total_rows())]);
    table.row(&["export".into(), t(export_s), format!("{artifact_bytes} bytes → {}", &id[..12])]);
    table.row(&[
        "load (cold)".into(),
        t(load_mean_s),
        format!("mean of {load_iters}, min {}", t(load_min_s)),
    ]);
    table.row(&["speedup".into(), times(speedup), "retrain / load".into()]);
    table.print(&format!("artifact cold start — {dataset}, fast_mode={}", fast_mode()));

    let mut j = Json::obj();
    j.set("bench", Json::Str("coldstart".into()))
        .set("fast_mode", Json::Bool(fast_mode()))
        .set("dataset", Json::Str(dataset.into()))
        .set("n_trees", Json::Num(program.n_trees as f64))
        .set("n_rows_train", Json::Num(n_rows as f64))
        .set("artifact_id", Json::Str(id.clone()))
        .set("artifact_bytes", Json::Num(artifact_bytes as f64))
        .set("train_s", Json::Num(train_s))
        .set("compile_s", Json::Num(compile_s))
        .set("export_s", Json::Num(export_s))
        .set("load_iters", Json::Num(load_iters as f64))
        .set("load_mean_s", Json::Num(load_mean_s))
        .set("load_min_s", Json::Num(load_min_s))
        .set("speedup_vs_retrain", Json::Num(speedup));
    let path = write_bench_json("coldstart", &j);
    println!("wrote {}", path.display());

    let _ = std::fs::remove_dir_all(&root);
}
