//! Ablations of the design choices DESIGN.md calls out (not paper
//! figures — supporting evidence for the paper's §III design decisions):
//!
//!  A1. **In-network reduction** (§III-D): config-bit accumulation at the
//!      routers vs shipping every core's logit flit to the CP.
//!  A2. **Two-cycle macro-cell** (§III-B): 2 cells / 2 cycles vs the
//!      rejected 3-cell single-cycle OR variant (larger area) vs plain
//!      4-bit cells (1 cycle, but Fig. 9a accuracy loss).
//!  A3. **Input batching / replication** (Fig. 7c): chip throughput vs
//!      replica count.
//!  A4. **Defect-aware co-design training** (§V-A outlook): bin-jitter
//!      training vs standard under memristor defects.
//!
//! Run: `cargo bench --bench ablations` (XTIME_FAST=1 to smoke-test)

use xtime::bench_support::{bench_split, fast_mode};
use xtime::cam::DefectSpec;
use xtime::compiler::{compile, CamEngine, CompileOptions};
use xtime::sim::{chip_area, simulate, ChipConfig, Workload};
use xtime::trees::{gbdt, GbdtParams};
use xtime::util::bench::{rate, Table};

fn main() {
    let split = bench_split("eye"); // multiclass: reduction matters most
    let model = gbdt::train(
        &split.train,
        &GbdtParams {
            n_rounds: if fast_mode() { 12 } else { 48 },
            max_leaves: 64,
            ..Default::default()
        },
        None,
    );
    let program = compile(&model, &CompileOptions { replicas: 0, core_rows: 64, ..Default::default() })
        .unwrap();
    let n = if fast_mode() { 20_000 } else { 100_000 };

    // ---- A1: in-network reduction --------------------------------------
    let mut cfg = ChipConfig::default();
    let with = simulate(&program, &cfg, &Workload::saturating(n), 0.05);
    cfg.in_network_reduction = false;
    let without = simulate(&program, &cfg, &Workload::saturating(n), 0.05);
    let mut t = Table::new(&["router accumulation", "throughput", "bound", "mean latency (ns)"]);
    t.row(&[
        "on  (paper)".into(),
        rate(with.throughput_msps * 1e6, "S"),
        with.bottleneck.into(),
        format!("{:.0}", with.latency_ns.mean),
    ]);
    t.row(&[
        "off (all flits to CP)".into(),
        rate(without.throughput_msps * 1e6, "S"),
        without.bottleneck.into(),
        format!("{:.0}", without.latency_ns.mean),
    ]);
    t.print("A1 — in-network reduction (eye model, multi-core layout)");
    println!(
        "→ {:.1}× throughput from router accumulation\n",
        with.throughput_msps / without.throughput_msps
    );

    // ---- A2: macro-cell variants ----------------------------------------
    let base_cfg = ChipConfig::default();
    let area8 = chip_area(&base_cfg).total();
    let mut t = Table::new(&["cell design", "λ_CAM", "rel. area", "8-bit capable"]);
    t.row(&["2 cells / 2 cycles (paper)".into(), "4".into(), "1.00×".into(), "yes".into()]);
    // The rejected design: 3 cells + complex routing per §III-B ≈ 1.5× the
    // aCAM area for one fewer search cycle.
    t.row(&["3 cells / 1 cycle (rejected)".into(), "3".into(), "1.50×".into(), "yes".into()]);
    t.row(&["plain 4-bit cell".into(), "3".into(), "0.50×".into(), "no (Fig. 9a loss)".into()]);
    t.print(&format!("A2 — precision cell variants (chip aCAM area baseline {area8:.1} mm²)"));
    let tput_gain = 4.0 / 3.0;
    println!(
        "→ the 1-cycle variant buys ≤{tput_gain:.2}× core throughput for 1.5× aCAM area;\n  \
         at the chip level the input/output fabric usually binds first, so the\n  \
         paper's compact 2-cycle cell is the right trade.\n"
    );

    // ---- A3: replication sweep -------------------------------------------
    // Use a deliberately core-bound mapping (8 small trees packed into
    // one core → II = 8 → 125 MS/s per replica) so replication has a
    // bound to lift: churn's 2-flit input ceiling is 500 MS/s.
    let churn = bench_split("churn");
    let packed = gbdt::train(
        &churn.train,
        &GbdtParams { n_rounds: 8, max_leaves: 16, ..Default::default() },
        None,
    );
    let mut t = Table::new(&["replicas", "trees/core", "throughput", "bound"]);
    for replicas in [1usize, 2, 4, 8, 0] {
        let p = compile(&packed, &CompileOptions { replicas, ..Default::default() }).unwrap();
        let rep = simulate(&p, &ChipConfig::default(), &Workload::saturating(n), 0.05);
        t.row(&[
            if replicas == 0 { format!("{} (fill chip)", p.n_replicas) } else { format!("{replicas}") },
            format!("{}", p.max_trees_per_core()),
            rate(rep.throughput_msps * 1e6, "S"),
            rep.bottleneck.into(),
        ]);
    }
    t.print("A3 — input batching (Fig. 7c replication; churn, 8 trees/core)");

    // ---- A4: defect-aware training ----------------------------------------
    let split = bench_split("churn");
    let rounds = if fast_mode() { 16 } else { 48 };
    let standard = gbdt::train(
        &split.train,
        &GbdtParams { n_rounds: rounds, max_leaves: 32, ..Default::default() },
        None,
    );
    let robust = gbdt::train(
        &split.train,
        &GbdtParams { n_rounds: rounds, max_leaves: 32, bin_jitter: 0.05, ..Default::default() },
        None,
    );
    let runs = if fast_mode() { 5 } else { 20 };
    let mut t = Table::new(&["training", "clean acc", "acc @5% defects", "acc @15% defects"]);
    for (name, m) in [("standard", &standard), ("defect-aware (5% jitter)", &robust)] {
        let p = compile(m, &CompileOptions::default()).unwrap();
        let clean = eval(&CamEngine::new(&p), &p, &split.test);
        let mut at = [0.0f64; 2];
        for (i, pct) in [0.05, 0.15].into_iter().enumerate() {
            let mut sum = 0.0;
            for run in 0..runs {
                let e = CamEngine::with_defects(&p, DefectSpec::memristor(pct), 900 + run as u64);
                sum += eval(&e, &p, &split.test);
            }
            at[i] = sum / runs as f64;
        }
        t.row(&[
            name.into(),
            format!("{clean:.4}"),
            format!("{:.4}", at[0]),
            format!("{:.4}", at[1]),
        ]);
    }
    t.print("A4 — defect-aware co-design training (churn)");
}

fn eval(engine: &CamEngine, program: &xtime::compiler::CamProgram, data: &xtime::data::Dataset) -> f64 {
    let n = 400.min(data.n_rows());
    let mut hits = 0usize;
    for i in 0..n {
        hits += (engine.predict(program, data.row(i)) == data.y[i]) as usize;
    }
    hits as f64 / n as f64
}
