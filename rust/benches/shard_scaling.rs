//! Sharded serving scaling: throughput of the coordinator as the same
//! 1024-tree ensemble is spread across 1, 2, 4, 8 shard workers (one
//! functional backend each — the software stand-in for one PCIe card per
//! shard, §III-D), plus the cycle-simulated N-card projection.
//!
//! The paper scales to 4096-tree ensembles by spreading trees over CAM
//! cores; this bench shows the same lever one level up: spreading cores
//! over cards. Expected shape: wall throughput rises with shard count
//! until host cores or the batcher bind; the simulated-card aggregate
//! rises ~linearly until PCIe binds per card.
//!
//! Shard workers serve whole device batches through the functional
//! engine's batched interval index (`CamEngine::partials_batch` via
//! `FunctionalBackend`), so this sweep measures the batched hot path —
//! bit-identical to the scalar engine (`rust/tests/batch_agreement.rs`).
//!
//! Run: `cargo bench --bench shard_scaling` (XTIME_FAST=1 to shrink)

use xtime::bench_support::{
    fast_mode, random_ensemble, random_query_bins, sharded_functional_pool, write_bench_json,
};
use xtime::compiler::{compile, partition, CompileOptions, PartitionOptions};
use xtime::coordinator::BatchPolicy;
use xtime::data::Task;
use xtime::sim::{CardConfig, ChipConfig, SimCardBackend};
use xtime::util::bench::{rate, times, Table};
use xtime::util::Json;

fn main() {
    let n_trees = 1024;
    let n_requests = if fast_mode() { 400 } else { 4_000 };
    let shard_counts: &[usize] = if fast_mode() { &[1, 2] } else { &[1, 2, 4, 8] };

    let model = random_ensemble(n_trees, 4, 32, Task::Binary, 7);
    let program = compile(&model, &CompileOptions::default()).expect("compile");
    println!(
        "model: {} trees, {} CAM rows, {} cores; {} requests per point",
        program.n_trees,
        program.total_rows(),
        program.cores_per_replica(),
        n_requests
    );

    let bins = random_query_bins(&program, n_requests, 1234);

    let mut table = Table::new(&[
        "shards",
        "throughput",
        "speedup",
        "mean batch",
        "max shard busy (ms)",
        "sim N-card",
    ]);
    let mut base_tput = 0.0f64;
    let mut json_points: Vec<Json> = Vec::new();
    for &n in shard_counts {
        let plan = partition(&program, n, &PartitionOptions::default()).expect("partition");

        // Wall-clock serving throughput through the worker pool.
        let server = sharded_functional_pool(
            &plan,
            BatchPolicy { max_wait_us: 200, max_batch: 64, threads: None },
        );
        let t0 = std::time::Instant::now();
        let pending: Vec<_> = bins.iter().map(|b| server.submit(b.clone())).collect();
        for rx in pending {
            rx.recv().expect("reply");
        }
        let wall = t0.elapsed().as_secs_f64();
        let tput = n_requests as f64 / wall;
        if n == 1 {
            base_tput = tput;
        }
        let stats = server.stats();
        assert_eq!(stats.errors, 0);
        let max_busy_ms = stats
            .shards
            .iter()
            .map(|s| s.busy_us as f64 / 1e3)
            .fold(0.0, f64::max);
        server.shutdown();

        // Cycle-simulated projection: N independent cards, one per shard;
        // the ensemble finishes when the slowest card does.
        let sim_agg: f64 = plan
            .shards
            .iter()
            .map(|s| {
                SimCardBackend::new(s, &ChipConfig::default(), &CardConfig::default())
                    .projected_throughput_sps()
            })
            .fold(f64::INFINITY, f64::min);

        table.row(&[
            format!("{n}"),
            rate(tput, "req"),
            times(tput / base_tput),
            format!("{:.1}", stats.mean_batch),
            format!("{max_busy_ms:.0}"),
            rate(sim_agg, "req"),
        ]);
        let mut point = Json::obj();
        point
            .set("shards", Json::Num(n as f64))
            .set("throughput_rps", Json::Num(tput))
            .set("speedup_vs_1", Json::Num(tput / base_tput))
            .set("mean_batch", Json::Num(stats.mean_batch))
            .set("max_shard_busy_ms", Json::Num(max_busy_ms))
            .set("sim_card_rps", Json::Num(sim_agg));
        json_points.push(point);
    }
    table.print(&format!("sharded serving scaling — {n_trees}-tree ensemble"));

    // Machine-readable trajectory datapoint at the repo root.
    let mut model = Json::obj();
    model
        .set("trees", Json::Num(n_trees as f64))
        .set("cam_rows", Json::Num(program.total_rows() as f64))
        .set("cores", Json::Num(program.cores_per_replica() as f64));
    let mut j = Json::obj();
    j.set("bench", Json::Str("shard_scaling".into()))
        .set("fast_mode", Json::Bool(fast_mode()))
        .set("n_requests", Json::Num(n_requests as f64))
        .set("model", model)
        .set("points", Json::Arr(json_points));
    write_bench_json("shard_scaling", &j);
    println!(
        "shape: wall throughput grows with shards (per-shard work = rows/N);\n\
         `sim N-card` is the slowest simulated card's rate — the pool's\n\
         lock-step ceiling — which stays ~flat per card while per-card work\n\
         shrinks ∝ 1/N, so card count is the capacity lever (§III-D)."
    );
}
