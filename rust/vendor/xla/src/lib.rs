//! Offline **stub** of the xla-rs PJRT API used by `xtime::runtime`.
//!
//! The build image has neither crates.io access nor a PJRT plugin, so this
//! crate provides the exact type/method surface `runtime/engine.rs` needs,
//! with every entry point returning [`Error::Unavailable`]. The runtime
//! already degrades gracefully: engines are only constructed when an
//! `artifacts/manifest.json` exists, and tests/examples skip the XLA rows
//! otherwise.
//!
//! To light up the real PJRT hot path, point the `xla` path dependency in
//! the workspace `Cargo.toml` at a checkout of
//! <https://github.com/LaurentMazare/xla-rs> (API-compatible for the calls
//! used here) and rebuild.

use std::fmt;

/// Stub error: always "PJRT unavailable".
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: XLA/PJRT is not available in this build (vendored stub `xla` crate); \
                 use the functional backend, or swap in a real xla-rs checkout"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// A PJRT device handle (never instantiated by the stub).
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice(());

/// A PJRT client. [`PjRtClient::cpu`] always fails in the stub, so the
/// remaining methods are unreachable but keep callers type-checking.
#[derive(Debug, Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("creating PJRT CPU client")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling HLO computation")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable("uploading host buffer")
    }
}

/// A parsed HLO module proto.
#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("parsing HLO text file")
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A compiled, loaded executable.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing PJRT computation")
    }
}

/// A device-resident buffer.
#[derive(Debug, Clone)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("fetching device buffer")
    }
}

/// A host-side literal value.
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("unwrapping tuple literal")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("reading literal data")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
