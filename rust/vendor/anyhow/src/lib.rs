//! Minimal offline shim of the `anyhow` API surface this repository uses:
//! [`Error`], [`Result`], [`Context`] and the [`anyhow!`] macro.
//!
//! The build image has no crates.io access, so instead of the real crate we
//! vendor the ~100 lines the codebase actually needs. Semantics match
//! anyhow closely enough for error *reporting*: contexts chain outermost
//! first and both `{}` and `{:#}` render the full chain.

use std::error::Error as StdError;
use std::fmt;

/// A chained error: a message plus the contexts wrapped around it.
pub struct Error {
    /// Outermost context first; the root cause is last.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context (what `Context::context` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which keeps the blanket `From` below coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Fold `source()` links into the chain so `?` loses nothing.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(|| ...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_chains_outermost_first() {
        let base: std::result::Result<(), String> = Err("root".to_string());
        let e = base.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: root");
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("x = {}", 3).to_string(), "x = 3");
        let s = String::from("owned");
        assert_eq!(anyhow!(s).to_string(), "owned");
    }

    #[test]
    fn question_mark_folds_sources() {
        fn inner() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().root_cause(), "boom");
    }
}
