//! Histogram-based tree grower shared by the GBDT and RF trainers.
//!
//! XGBoost's `hist` formulation: per-node, per-feature histograms of
//! gradient/hessian sums over quantized feature bins; the best split
//! maximizes the second-order gain
//! `GL²/(HL+λ) + GR²/(HR+λ) − G²/(H+λ)`. Growth is best-first
//! ("leaf-wise" à la LightGBM) bounded by `max_leaves` and `max_depth`,
//! which is exactly the `N_leaves,max` constraint the X-TIME hardware
//! imposes (§III-C: 256 addressable words per core).

use crate::trees::tree::{Node, Tree};
use crate::util::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Quantized feature matrix shared across all trees of a training run.
pub struct BinnedMatrix {
    /// Row-major `[n_rows × n_features]` bin indices.
    pub bins: Vec<u16>,
    pub n_rows: usize,
    pub n_features: usize,
    /// Global bin-count bound (`2^n_bits`).
    pub n_bins: usize,
}

impl BinnedMatrix {
    #[inline]
    pub fn bin(&self, row: usize, feature: usize) -> u16 {
        self.bins[row * self.n_features + feature]
    }

    pub fn row(&self, row: usize) -> &[u16] {
        &self.bins[row * self.n_features..(row + 1) * self.n_features]
    }
}

/// Growth hyper-parameters (shared GBDT/RF subset).
#[derive(Clone, Debug)]
pub struct GrowParams {
    pub max_leaves: usize,
    pub max_depth: usize,
    /// L2 regularization λ on leaf weights.
    pub lambda: f32,
    /// Minimum split gain γ.
    pub gamma: f32,
    /// Minimum hessian mass per child.
    pub min_child_weight: f64,
    /// Scale applied to fitted leaf values (learning rate; 1.0 for RF).
    pub leaf_scale: f32,
    /// Fraction of features considered: per tree (GBDT) or per split (RF).
    pub colsample: f64,
    /// If true, re-draw the feature subset at every split (RF style).
    pub col_per_split: bool,
    /// Variation-aware split scoring (hardware-aware training): the
    /// probability that a programmed CAM threshold drifts one bin in a
    /// given direction (the ±1-level conductance-flip model derived in
    /// `cam::analog`). When > 0 every candidate threshold is scored by
    /// its *expected* gain under that drift, so razor-thin splits whose
    /// gain evaporates one bin away are discounted in favour of splits
    /// that carry margin. 0.0 keeps the exact classic scoring.
    pub variation_flip_prob: f64,
}

impl Default for GrowParams {
    fn default() -> Self {
        GrowParams {
            max_leaves: 256,
            max_depth: 12,
            lambda: 1.0,
            gamma: 0.0,
            min_child_weight: 1.0,
            leaf_scale: 0.1,
            colsample: 1.0,
            col_per_split: false,
            variation_flip_prob: 0.0,
        }
    }
}

struct Candidate {
    gain: f32,
    node_slot: usize, // index into tree.nodes to overwrite on split
    feature: u32,
    threshold_bin: u16,
    rows: Vec<u32>,
    depth: usize,
    g_sum: f64,
    h_sum: f64,
}

impl PartialEq for Candidate {
    fn eq(&self, other: &Self) -> bool {
        self.gain == other.gain
    }
}
impl Eq for Candidate {}
impl PartialOrd for Candidate {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Candidate {
    fn cmp(&self, other: &Self) -> Ordering {
        self.gain.partial_cmp(&other.gain).unwrap_or(Ordering::Equal)
    }
}

/// Scratch buffers reused across nodes/trees to avoid re-allocation on the
/// training hot path.
pub struct GrowScratch {
    hist_g: Vec<f64>,
    hist_h: Vec<f64>,
    /// Per-threshold raw gains of one feature (variation-aware scoring);
    /// index = threshold bin, with the degenerate all-right (0) and
    /// all-left (n_bins) ends pinned to gain 0.
    gain: Vec<f32>,
    /// Whether a threshold satisfies the `min_child_weight` constraint.
    valid: Vec<bool>,
}

impl GrowScratch {
    pub fn new(n_features: usize, n_bins: usize) -> GrowScratch {
        GrowScratch {
            hist_g: vec![0.0; n_features * n_bins],
            hist_h: vec![0.0; n_features * n_bins],
            gain: vec![0.0; n_bins + 1],
            valid: vec![false; n_bins + 1],
        }
    }
}

/// Best split over the candidate feature set for one node.
struct BestSplit {
    gain: f32,
    feature: u32,
    threshold_bin: u16,
}

#[allow(clippy::too_many_arguments)]
fn find_best_split(
    m: &BinnedMatrix,
    rows: &[u32],
    g: &[f32],
    h: &[f32],
    feats: &[u32],
    g_sum: f64,
    h_sum: f64,
    p: &GrowParams,
    scratch: &mut GrowScratch,
) -> Option<BestSplit> {
    let nb = m.n_bins;
    let GrowScratch { hist_g, hist_h, gain, valid } = scratch;
    // Zero only the touched feature lanes.
    for &f in feats {
        let base = f as usize * nb;
        hist_g[base..base + nb].fill(0.0);
        hist_h[base..base + nb].fill(0.0);
    }
    // Histogram accumulation — the training hot loop.
    for &r in rows {
        let r = r as usize;
        let row_base = r * m.n_features;
        let gr = g[r] as f64;
        let hr = h[r] as f64;
        for &f in feats {
            let b = m.bins[row_base + f as usize] as usize;
            let idx = f as usize * nb + b;
            hist_g[idx] += gr;
            hist_h[idx] += hr;
        }
    }
    let parent_score = g_sum * g_sum / (h_sum + p.lambda as f64);
    let mut best: Option<BestSplit> = None;

    if p.variation_flip_prob > 0.0 {
        // Variation-aware scoring (hardware-aware training): the deployed
        // threshold drifts one bin down/up with probability `fp` each, so
        // a threshold is scored by its expected gain
        //   E = (1 − 2·fp)·gain(t) + fp·gain(t−1) + fp·gain(t+1),
        // with the degenerate ends (t = 0: everything right, t = n_bins:
        // everything left) contributing gain 0. Splits only eligible when
        // the *nominal* threshold satisfies `min_child_weight`.
        let fp = p.variation_flip_prob as f32;
        for &f in feats {
            let base = f as usize * nb;
            gain[0] = 0.0;
            gain[nb] = 0.0;
            let mut gl = 0.0f64;
            let mut hl = 0.0f64;
            for t in 1..nb {
                gl += hist_g[base + t - 1];
                hl += hist_h[base + t - 1];
                let gr_ = g_sum - gl;
                let hr_ = h_sum - hl;
                // An empty child means the drifted threshold is no split
                // at all: gain 0 (also dodges 0/0 when λ = 0).
                gain[t] = if hl <= 0.0 || hr_ <= 0.0 {
                    0.0
                } else {
                    (gl * gl / (hl + p.lambda as f64) + gr_ * gr_ / (hr_ + p.lambda as f64)
                        - parent_score) as f32
                        * 0.5
                };
                // Both children non-empty (hessians are strictly positive
                // for every loss here) and heavy enough.
                valid[t] =
                    hl > 0.0 && hr_ > 0.0 && hl >= p.min_child_weight && hr_ >= p.min_child_weight;
            }
            for t in 1..nb {
                if !valid[t] {
                    continue;
                }
                let e = (1.0 - 2.0 * fp) * gain[t] + fp * (gain[t - 1] + gain[t + 1]);
                if e > p.gamma && best.as_ref().map(|b| e > b.gain).unwrap_or(true) {
                    best = Some(BestSplit { gain: e, feature: f, threshold_bin: t as u16 });
                }
            }
        }
        return best;
    }

    for &f in feats {
        let base = f as usize * nb;
        let mut gl = 0.0f64;
        let mut hl = 0.0f64;
        // Split at bin t: left = bins < t, right = bins >= t.
        for t in 1..nb {
            gl += hist_g[base + t - 1];
            hl += hist_h[base + t - 1];
            if hl < p.min_child_weight {
                continue;
            }
            let gr_ = g_sum - gl;
            let hr_ = h_sum - hl;
            if hr_ < p.min_child_weight {
                break;
            }
            let gain = (gl * gl / (hl + p.lambda as f64) + gr_ * gr_ / (hr_ + p.lambda as f64)
                - parent_score) as f32
                * 0.5;
            if gain > p.gamma && best.as_ref().map(|b| gain > b.gain).unwrap_or(true) {
                best = Some(BestSplit { gain, feature: f, threshold_bin: t as u16 });
            }
        }
    }
    best
}

fn leaf_value(g_sum: f64, h_sum: f64, p: &GrowParams) -> f32 {
    (-(g_sum / (h_sum + p.lambda as f64)) as f32) * p.leaf_scale
}

fn draw_feats(n_features: usize, colsample: f64, rng: &mut Rng) -> Vec<u32> {
    let k = ((n_features as f64 * colsample).ceil() as usize).clamp(1, n_features);
    if k == n_features {
        (0..n_features as u32).collect()
    } else {
        rng.sample_indices(n_features, k).into_iter().map(|i| i as u32).collect()
    }
}

/// Grow one tree on the given sample rows with per-sample gradients `g`
/// and hessians `h` (both indexed by absolute row id).
pub fn grow_tree(
    m: &BinnedMatrix,
    rows: Vec<u32>,
    g: &[f32],
    h: &[f32],
    p: &GrowParams,
    rng: &mut Rng,
    scratch: &mut GrowScratch,
) -> Tree {
    let sums = |rows: &[u32]| -> (f64, f64) {
        let mut gs = 0.0;
        let mut hs = 0.0;
        for &r in rows {
            gs += g[r as usize] as f64;
            hs += h[r as usize] as f64;
        }
        (gs, hs)
    };

    let tree_feats = draw_feats(m.n_features, if p.col_per_split { 1.0 } else { p.colsample }, rng);

    let mut tree = Tree::default();
    let (g0, h0) = sums(&rows);
    tree.nodes.push(Node::Leaf { value: leaf_value(g0, h0, p) });
    let mut n_leaves = 1usize;

    let mut heap: BinaryHeap<Candidate> = BinaryHeap::new();
    let consider = |rows: Vec<u32>,
                        node_slot: usize,
                        depth: usize,
                        g_sum: f64,
                        h_sum: f64,
                        heap: &mut BinaryHeap<Candidate>,
                        rng: &mut Rng,
                        scratch: &mut GrowScratch| {
        if depth >= p.max_depth || rows.len() < 2 {
            return;
        }
        let feats: Vec<u32> = if p.col_per_split {
            draw_feats(m.n_features, p.colsample, rng)
        } else {
            tree_feats.clone()
        };
        if let Some(b) = find_best_split(m, &rows, g, h, &feats, g_sum, h_sum, p, scratch) {
            heap.push(Candidate {
                gain: b.gain,
                node_slot,
                feature: b.feature,
                threshold_bin: b.threshold_bin,
                rows,
                depth,
                g_sum,
                h_sum,
            });
        }
    };

    consider(rows, 0, 0, g0, h0, &mut heap, rng, &mut *scratch);

    while n_leaves < p.max_leaves {
        let Some(c) = heap.pop() else { break };
        // Partition rows by the chosen split.
        let mut left_rows = Vec::with_capacity(c.rows.len() / 2);
        let mut right_rows = Vec::with_capacity(c.rows.len() / 2);
        for &r in &c.rows {
            if m.bin(r as usize, c.feature as usize) >= c.threshold_bin {
                right_rows.push(r);
            } else {
                left_rows.push(r);
            }
        }
        debug_assert!(!left_rows.is_empty() && !right_rows.is_empty());
        let (gl, hl) = sums(&left_rows);
        let (gr_, hr_) = (c.g_sum - gl, c.h_sum - hl);

        let left_slot = tree.nodes.len();
        tree.nodes.push(Node::Leaf { value: leaf_value(gl, hl, p) });
        let right_slot = tree.nodes.len();
        tree.nodes.push(Node::Leaf { value: leaf_value(gr_, hr_, p) });
        tree.nodes[c.node_slot] = Node::Split {
            feature: c.feature,
            threshold_bin: c.threshold_bin,
            left: left_slot as u32,
            right: right_slot as u32,
        };
        n_leaves += 1;

        consider(left_rows, left_slot, c.depth + 1, gl, hl, &mut heap, rng, &mut *scratch);
        consider(right_rows, right_slot, c.depth + 1, gr_, hr_, &mut heap, rng, &mut *scratch);
    }
    tree
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix(bins: Vec<u16>, n_features: usize, n_bins: usize) -> BinnedMatrix {
        let n_rows = bins.len() / n_features;
        BinnedMatrix { bins, n_rows, n_features, n_bins }
    }

    /// Single feature, perfectly separable step target at bin 8.
    fn step_problem() -> (BinnedMatrix, Vec<f32>, Vec<f32>) {
        let n = 64;
        let bins: Vec<u16> = (0..n as u16).map(|i| i % 16).collect();
        let target: Vec<f32> = bins.iter().map(|&b| if b >= 8 { 1.0 } else { 0.0 }).collect();
        // Squared loss at pred=0 → g = -y, h = 1 (leaf value = mean y).
        let g: Vec<f32> = target.iter().map(|&y| -y).collect();
        let h = vec![1.0f32; n];
        (matrix(bins, 1, 16), g, h)
    }

    #[test]
    fn finds_the_planted_split() {
        let (m, g, h) = step_problem();
        let p = GrowParams { max_leaves: 2, leaf_scale: 1.0, lambda: 0.0, ..Default::default() };
        let mut rng = Rng::new(1);
        let mut scratch = GrowScratch::new(m.n_features, m.n_bins);
        let rows: Vec<u32> = (0..m.n_rows as u32).collect();
        let t = grow_tree(&m, rows, &g, &h, &p, &mut rng, &mut scratch);
        assert_eq!(t.n_leaves(), 2);
        match t.nodes[0] {
            Node::Split { feature, threshold_bin, .. } => {
                assert_eq!(feature, 0);
                assert_eq!(threshold_bin, 8);
            }
            _ => panic!("root is not a split"),
        }
        // Leaf values must be the class means (0 and 1).
        assert!((t.predict_bins(&[0]) - 0.0).abs() < 1e-6);
        assert!((t.predict_bins(&[15]) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn respects_max_leaves() {
        let n = 256;
        let mut rng_data = Rng::new(9);
        let bins: Vec<u16> = (0..n * 4).map(|_| rng_data.below(16) as u16).collect();
        let g: Vec<f32> = (0..n).map(|_| rng_data.f32() - 0.5).collect();
        let h = vec![1.0f32; n];
        let m = matrix(bins, 4, 16);
        for max_leaves in [1usize, 2, 4, 7, 16] {
            let p = GrowParams { max_leaves, lambda: 0.0, ..Default::default() };
            let mut rng = Rng::new(5);
            let mut scratch = GrowScratch::new(m.n_features, m.n_bins);
            let t = grow_tree(&m, (0..n as u32).collect(), &g, &h, &p, &mut rng, &mut scratch);
            assert!(t.n_leaves() <= max_leaves, "{} > {max_leaves}", t.n_leaves());
        }
    }

    #[test]
    fn respects_max_depth() {
        let n = 512;
        let mut rng_data = Rng::new(11);
        let bins: Vec<u16> = (0..n * 8).map(|_| rng_data.below(32) as u16).collect();
        let g: Vec<f32> = (0..n).map(|_| rng_data.f32() - 0.5).collect();
        let h = vec![1.0f32; n];
        let m = matrix(bins, 8, 32);
        let p = GrowParams { max_depth: 3, max_leaves: 256, lambda: 0.0, ..Default::default() };
        let mut rng = Rng::new(5);
        let mut scratch = GrowScratch::new(m.n_features, m.n_bins);
        let t = grow_tree(&m, (0..n as u32).collect(), &g, &h, &p, &mut rng, &mut scratch);
        assert!(t.depth() <= 3, "depth {}", t.depth());
    }

    #[test]
    fn pure_node_stays_leaf() {
        // Constant target → zero gain everywhere → single leaf.
        let n = 32;
        let bins: Vec<u16> = (0..n as u16).collect();
        let g = vec![-1.0f32; n];
        let h = vec![1.0f32; n];
        let m = matrix(bins, 1, 32);
        let p = GrowParams { lambda: 0.0, leaf_scale: 1.0, ..Default::default() };
        let mut rng = Rng::new(2);
        let mut scratch = GrowScratch::new(1, 32);
        let t = grow_tree(&m, (0..n as u32).collect(), &g, &h, &p, &mut rng, &mut scratch);
        assert_eq!(t.n_leaves(), 1);
        assert!((t.predict_bins(&[0]) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn variation_aware_prefers_wide_margin_split() {
        // Feature 0 separates the classes perfectly but only at t = 8:
        // all mass sits on bins 7 and 8, so one bin of threshold drift
        // destroys the split entirely. Feature 1 separates *almost*
        // perfectly (a few noisy rows) with class mass spread over bins
        // 0..8 and 8..16, so one bin of drift misroutes only 1/8 of one
        // class. The plain scorer takes the razor-thin feature 0; the
        // variation-aware scorer must pay the drift penalty and take the
        // wide-margin feature 1.
        let n = 128usize;
        let mut bins: Vec<u16> = Vec::with_capacity(n * 2);
        let mut g: Vec<f32> = Vec::with_capacity(n);
        for i in 0..n {
            let y = (i % 2) as u16;
            let f0 = 7 + y;
            let noisy = i % 32 == 0; // 4 of 128 rows on f1's wrong side
            let side = if noisy { 1 - y } else { y };
            let f1 = side * 8 + ((i / 2) % 8) as u16;
            bins.push(f0);
            bins.push(f1);
            g.push(-(y as f32));
        }
        let h = vec![1.0f32; n];
        let m = matrix(bins, 2, 16);
        let rows: Vec<u32> = (0..n as u32).collect();
        let grow_with = |flip: f64| {
            let p = GrowParams {
                max_leaves: 2,
                leaf_scale: 1.0,
                variation_flip_prob: flip,
                ..Default::default()
            };
            let mut rng = Rng::new(21);
            let mut scratch = GrowScratch::new(m.n_features, m.n_bins);
            grow_tree(&m, rows.clone(), &g, &h, &p, &mut rng, &mut scratch)
        };
        let plain = grow_with(0.0);
        match plain.nodes[0] {
            Node::Split { feature, threshold_bin, .. } => {
                assert_eq!(feature, 0, "plain scorer should take the perfect separator");
                assert_eq!(threshold_bin, 8);
            }
            _ => panic!("plain root is not a split"),
        }
        let robust = grow_with(0.2);
        match robust.nodes[0] {
            Node::Split { feature, .. } => {
                assert_eq!(feature, 1, "variation-aware scorer should take the wide margin");
            }
            _ => panic!("variation-aware root is not a split"),
        }
    }

    #[test]
    fn zero_variation_prob_is_exactly_classic_scoring() {
        // The variation path must be a strict opt-in: flip prob 0.0 goes
        // through the untouched classic scorer, so trees are identical.
        let (m, g, h) = step_problem();
        let p = GrowParams { max_leaves: 4, lambda: 0.0, leaf_scale: 1.0, ..Default::default() };
        let mut rng_a = Rng::new(5);
        let mut rng_b = Rng::new(5);
        let mut sa = GrowScratch::new(m.n_features, m.n_bins);
        let mut sb = GrowScratch::new(m.n_features, m.n_bins);
        let rows: Vec<u32> = (0..m.n_rows as u32).collect();
        let a = grow_tree(&m, rows.clone(), &g, &h, &p, &mut rng_a, &mut sa);
        let pb = GrowParams { variation_flip_prob: 0.0, ..p };
        let b = grow_tree(&m, rows, &g, &h, &pb, &mut rng_b, &mut sb);
        assert_eq!(a, b);
    }

    #[test]
    fn variation_aware_rf_params_survive_zero_lambda() {
        // RF grows with λ = 0; the variation path must not leak NaNs from
        // empty-child thresholds (0/0) into the scores.
        let n = 64;
        let mut rng_data = Rng::new(31);
        let bins: Vec<u16> = (0..n * 3).map(|_| rng_data.below(8) as u16).collect();
        let g: Vec<f32> = (0..n).map(|_| rng_data.f32() - 0.5).collect();
        let h = vec![1.0f32; n];
        let m = matrix(bins, 3, 8);
        let p = GrowParams {
            lambda: 0.0,
            gamma: 1e-9,
            leaf_scale: 1.0,
            variation_flip_prob: 0.1,
            ..Default::default()
        };
        let mut rng = Rng::new(9);
        let mut scratch = GrowScratch::new(m.n_features, m.n_bins);
        let t = grow_tree(&m, (0..n as u32).collect(), &g, &h, &p, &mut rng, &mut scratch);
        for node in &t.nodes {
            if let Node::Leaf { value } = node {
                assert!(value.is_finite(), "NaN leaked into a leaf value");
            }
        }
    }

    #[test]
    fn gamma_blocks_weak_splits() {
        let (m, g, h) = step_problem();
        let p = GrowParams { gamma: 1e9, ..Default::default() };
        let mut rng = Rng::new(3);
        let mut scratch = GrowScratch::new(1, 16);
        let t = grow_tree(&m, (0..m.n_rows as u32).collect(), &g, &h, &p, &mut rng, &mut scratch);
        assert_eq!(t.n_leaves(), 1);
    }
}
