//! Tree-based ML substrate: data structures, trainers and metrics.
//!
//! The paper trains with XGBoost / CatBoost / LightGBM / scikit-learn;
//! those are unavailable offline, so [`gbdt`] and [`rf`] implement the same
//! algorithm families from scratch (DESIGN.md §2, substitution 4).

pub mod explain;
pub mod gbdt;
pub mod grow;
pub mod hat;
pub mod loss;
pub mod metrics;
pub mod rf;
pub mod tree;

pub use gbdt::GbdtParams;
pub use hat::{HatParams, RetrainReport, DEFAULT_VARIATION_FLIP_PROB};
pub use rf::RfParams;
pub use tree::{Ensemble, Node, Tree};

use crate::data::Dataset;

/// Which trainer a Table II dataset uses (the paper's "Model" column).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Gradient boosting (XGBoost / CatBoost / LightGBM equivalent).
    Gbdt,
    /// Random forest (scikit-learn equivalent).
    RandomForest,
}

impl ModelKind {
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Gbdt => "GBDT",
            ModelKind::RandomForest => "RandomForest",
        }
    }
}

/// Table II training configuration for one dataset: trainer family plus the
/// topology targets (N_trees, N_leaves,max) the paper reports.
#[derive(Clone, Debug)]
pub struct PaperModelSpec {
    pub dataset: &'static str,
    pub kind: ModelKind,
    /// Paper's total tree count (Table II `N_trees`).
    pub n_trees: usize,
    /// Paper's `N_leaves,max`.
    pub n_leaves_max: usize,
}

/// Table II "Model / N_trees / N_leaves,max" columns.
pub fn paper_models() -> Vec<PaperModelSpec> {
    vec![
        PaperModelSpec { dataset: "churn", kind: ModelKind::Gbdt, n_trees: 404, n_leaves_max: 256 },
        PaperModelSpec { dataset: "eye", kind: ModelKind::Gbdt, n_trees: 2352, n_leaves_max: 256 },
        PaperModelSpec { dataset: "covertype", kind: ModelKind::Gbdt, n_trees: 1351, n_leaves_max: 231 },
        PaperModelSpec { dataset: "gas", kind: ModelKind::RandomForest, n_trees: 1356, n_leaves_max: 217 },
        PaperModelSpec { dataset: "gesture", kind: ModelKind::Gbdt, n_trees: 1895, n_leaves_max: 256 },
        PaperModelSpec { dataset: "telco", kind: ModelKind::Gbdt, n_trees: 159, n_leaves_max: 4 },
        PaperModelSpec { dataset: "rossmann", kind: ModelKind::Gbdt, n_trees: 2017, n_leaves_max: 256 },
    ]
}

pub fn paper_model(dataset: &str) -> Option<PaperModelSpec> {
    paper_models().into_iter().find(|m| m.dataset == dataset)
}

/// Train a dataset with its Table II configuration, scaling the round count
/// so the produced ensemble hits the paper's `N_trees` exactly.
/// `n_bits` selects the precision regime of Fig. 9(a); `trees_override`
/// lets callers train smaller models (fast tests).
pub fn train_paper_model(
    data: &Dataset,
    spec: &PaperModelSpec,
    n_bits: u8,
    n_leaves_max: usize,
    trees_override: Option<usize>,
) -> Ensemble {
    let n_trees = trees_override.unwrap_or(spec.n_trees);
    let k = data.task.n_outputs();
    match spec.kind {
        ModelKind::Gbdt => {
            let rounds = (n_trees / k).max(1);
            let p = GbdtParams {
                n_rounds: rounds,
                max_leaves: n_leaves_max,
                max_depth: if n_leaves_max <= 4 { 2 } else { 10 },
                n_bits,
                ..Default::default()
            };
            gbdt::train(data, &p, None)
        }
        ModelKind::RandomForest => {
            let est = (n_trees / k).max(1);
            let p = RfParams {
                n_estimators: est,
                max_leaves: n_leaves_max,
                n_bits,
                ..Default::default()
            };
            rf::train(data, &p)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::by_name;

    #[test]
    fn paper_models_cover_table2() {
        let ms = paper_models();
        assert_eq!(ms.len(), 7);
        assert_eq!(paper_model("gas").unwrap().kind, ModelKind::RandomForest);
        assert_eq!(paper_model("telco").unwrap().n_leaves_max, 4);
        assert_eq!(paper_model("eye").unwrap().n_trees, 2352);
    }

    #[test]
    fn train_paper_model_hits_topology() {
        let d = by_name("telco").unwrap().generate_n(1000);
        let spec = paper_model("telco").unwrap();
        let m = train_paper_model(&d, &spec, 8, spec.n_leaves_max, Some(20));
        assert_eq!(m.n_trees(), 20);
        assert!(m.max_leaves() <= 4);
    }

    #[test]
    fn multiclass_tree_count_divisible() {
        let d = by_name("eye").unwrap().generate_n(900);
        let spec = paper_model("eye").unwrap();
        let m = train_paper_model(&d, &spec, 8, 16, Some(12));
        // 12 requested → 4 rounds × 3 classes.
        assert_eq!(m.n_trees(), 12);
    }
}
