//! Hardware-aware training (HAT): GBDT/RF training that targets the CAM
//! deployment grid *during* learning instead of snapping afterwards.
//!
//! The paper's headline accuracy claim ("thanks to hardware-aware
//! training, X-TIME reaches state-of-the-art accuracy") rests on three
//! mechanisms, all implemented here and in [`crate::trees::grow`]:
//!
//! 1. **Grid-aligned thresholds** — the trainer quantizes features with
//!    the *same* `deploy_bits` grid the compiler programs into the CAM
//!    (`FeatureQuantizer` is shared between trainer and compiler, and
//!    [`crate::data::FeatureQuantizer::coarsen`] derives coarse grids as
//!    cut subsets of fine ones). Compile-time threshold snapping is then
//!    lossless by
//!    construction — `compiler::compile_for_deploy` asserts this via its
//!    `HatReport` (DESIGN.md §5, contract 5). Post-training quantization
//!    (`compiler::requantize` of a high-precision model) is the lossy
//!    baseline this recovers from — the Fig. 9a accuracy cliff.
//! 2. **Variation-aware split scoring** — candidate thresholds are scored
//!    by expected gain under ±1-bin threshold drift (the conductance
//!    programming-noise model of `cam::analog`), so chosen splits carry
//!    margin against analog variation
//!    (`GrowParams::variation_flip_prob`).
//! 3. **Defect-aware retraining** — given a known defect map (a
//!    `cam::DefectSpec` draw for a specific chip), trees whose CAM rows
//!    land on defective cells are re-fit against the residuals of the
//!    healthy trees, keeping the best-scoring pass
//!    ([`defect_aware_retrain`]). The compile/deploy oracles are injected
//!    as closures so this L1 module does not depend upward on the
//!    compiler; `compiler::hat_defect_retrain` provides the wiring.
//!
//! Prior art: Pedretti et al.'s analog-CAM decision-tree work
//! (arXiv:2103.08986) and RETENTION (arXiv:2506.05994) both show that
//! making the trainer aware of CAM precision/cell constraints is what
//! recovers accuracy at 4–6 bits.

use crate::data::{Dataset, Task};
use crate::trees::gbdt::{self, GbdtParams};
use crate::trees::grow::{grow_tree, BinnedMatrix, GrowScratch};
use crate::trees::loss::grad_hess;
use crate::trees::rf::{self, RfParams};
use crate::trees::tree::Ensemble;
use crate::trees::ModelKind;
use crate::util::Rng;
use std::collections::HashSet;

/// §V-A operating point of the analog programming-noise model: with
/// σ = 1 µS on the 1–100 µS window a stored level flips with ≈ 0.2%
/// probability (`cam::analog::analytic_flip_probability()`). Kept as a
/// literal so L1 does not depend upward on the device layer; callers with
/// a calibrated device model can pass the measured figure instead.
pub const DEFAULT_VARIATION_FLIP_PROB: f64 = 0.002;

/// Hardware-aware training configuration.
#[derive(Clone, Debug)]
pub struct HatParams {
    /// Deployment precision: the CAM grid the compiler will program
    /// (1..=8 bits; 8 = macro-cell, 4 = single-cell mode).
    pub deploy_bits: u8,
    /// Trainer family (Table II's "Model" column).
    pub kind: ModelKind,
    /// Base GBDT hyper-parameters. `n_bits` and `variation_flip_prob`
    /// are overridden by `deploy_bits` / `variation_flip_prob` below.
    pub gbdt: GbdtParams,
    /// Base RF hyper-parameters (same overrides).
    pub rf: RfParams,
    /// ±1-bin threshold-drift probability used for variation-aware split
    /// scoring. 0.0 disables.
    pub variation_flip_prob: f64,
    /// Maximum defect-aware retrain passes ([`defect_aware_retrain`]).
    pub retrain_passes: usize,
}

impl Default for HatParams {
    fn default() -> Self {
        HatParams {
            deploy_bits: 8,
            kind: ModelKind::Gbdt,
            gbdt: GbdtParams::default(),
            rf: RfParams::default(),
            variation_flip_prob: DEFAULT_VARIATION_FLIP_PROB,
            retrain_passes: 2,
        }
    }
}

impl HatParams {
    /// Effective GBDT params: deploy grid + variation scoring applied.
    fn effective_gbdt(&self) -> GbdtParams {
        GbdtParams {
            n_bits: self.deploy_bits,
            variation_flip_prob: self.variation_flip_prob,
            ..self.gbdt.clone()
        }
    }

    /// Effective RF params: deploy grid + variation scoring applied.
    fn effective_rf(&self) -> RfParams {
        RfParams {
            n_bits: self.deploy_bits,
            variation_flip_prob: self.variation_flip_prob,
            ..self.rf.clone()
        }
    }
}

/// Train a hardware-aware ensemble: split thresholds are restricted to
/// the exact `deploy_bits` quantizer grid the compiler deploys (so
/// threshold snapping is lossless by construction) and splits are scored
/// variation-aware. The returned model's `quantizer` *is* the deployment
/// grid.
pub fn train(data: &Dataset, params: &HatParams, val: Option<&Dataset>) -> Ensemble {
    assert!(
        (1..=8).contains(&params.deploy_bits),
        "deploy grid is 1..=8 bits (got {})",
        params.deploy_bits
    );
    match params.kind {
        ModelKind::Gbdt => gbdt::train(data, &params.effective_gbdt(), val),
        ModelKind::RandomForest => rf::train(data, &params.effective_rf()),
    }
}

/// Re-fit the given trees in place — same slot, same class, same deploy
/// grid (the model's own quantizer is reused, so the result stays
/// grid-aligned by construction):
///
/// * GBDT: each affected tree is regrown against the boosting residuals
///   of the kept trees (predictions of unaffected trees are frozen,
///   gradients recomputed before each replacement tree);
/// * RF: each affected tree is regrown on a fresh bootstrap draw with
///   the forest's usual one-vs-rest targets.
pub fn refit_trees(
    data: &Dataset,
    model: &Ensemble,
    affected: &[u32],
    params: &HatParams,
    seed: u64,
) -> Ensemble {
    if affected.is_empty() {
        return model.clone();
    }
    let n = data.n_rows();
    let k = model.task.n_outputs();
    let m = BinnedMatrix {
        bins: model.quantizer.transform(data),
        n_rows: n,
        n_features: data.n_features,
        n_bins: model.quantizer.n_bins(),
    };
    let affected: HashSet<u32> = affected.iter().copied().collect();
    let mut out = model.clone();
    let mut rng = Rng::new(seed ^ 0x4A77_EA17);
    let mut scratch = GrowScratch::new(m.n_features, m.n_bins);

    match params.kind {
        ModelKind::Gbdt => {
            let gp = params.effective_gbdt();
            // Same grower regime as `gbdt::train` (shared mapping).
            let grow = gp.grow_params();
            // Frozen predictions of base score + kept trees.
            let mut preds = vec![0f32; n * k];
            for i in 0..n {
                preds[i * k..(i + 1) * k].copy_from_slice(&model.base_score);
            }
            for (ti, tree) in model.trees.iter().enumerate() {
                if affected.contains(&(ti as u32)) {
                    continue;
                }
                let c = model.tree_class[ti] as usize;
                for i in 0..n {
                    preds[i * k + c] += tree.predict_bins(m.row(i));
                }
            }
            let mut gk = vec![0f32; n];
            let mut hk = vec![0f32; n];
            for ti in 0..model.trees.len() {
                if !affected.contains(&(ti as u32)) {
                    continue;
                }
                let class = model.tree_class[ti] as usize;
                let gh = grad_hess(model.task, &preds, &data.y);
                for i in 0..n {
                    gk[i] = gh.g[i * k + class];
                    hk[i] = gh.h[i * k + class];
                }
                let rows: Vec<u32> = if gp.subsample < 1.0 {
                    let take = ((n as f64 * gp.subsample) as usize).max(2);
                    rng.sample_indices(n, take).into_iter().map(|i| i as u32).collect()
                } else {
                    (0..n as u32).collect()
                };
                // Defect-aware bin jitter, exactly as `gbdt::train`: grow
                // on a jittered view, update predictions on clean bins.
                let jittered: Option<BinnedMatrix> = if gp.bin_jitter > 0.0 {
                    let max_bin = (m.n_bins - 1) as u16;
                    let mut bins = m.bins.clone();
                    for b in bins.iter_mut() {
                        if rng.chance(gp.bin_jitter) {
                            *b = if rng.chance(0.5) {
                                (*b).saturating_sub(1)
                            } else {
                                (*b + 1).min(max_bin)
                            };
                        }
                    }
                    Some(BinnedMatrix {
                        bins,
                        n_rows: m.n_rows,
                        n_features: m.n_features,
                        n_bins: m.n_bins,
                    })
                } else {
                    None
                };
                let grow_m = jittered.as_ref().unwrap_or(&m);
                let tree = grow_tree(grow_m, rows, &gk, &hk, &grow, &mut rng, &mut scratch);
                for i in 0..n {
                    preds[i * k + class] += tree.predict_bins(m.row(i));
                }
                out.trees[ti] = tree;
            }
        }
        ModelKind::RandomForest => {
            let rp = params.effective_rf();
            let n_estimators = (model.n_trees() / k).max(1);
            // Same grower regime as `rf::train` (shared mapping).
            let grow = rp.grow_params(data.n_features, n_estimators);
            let hk = vec![1f32; n];
            let mut gk = vec![0f32; n];
            for ti in 0..model.trees.len() {
                if !affected.contains(&(ti as u32)) {
                    continue;
                }
                let class = model.tree_class[ti] as usize;
                let mut erng = rng.fork(ti as u64);
                let rows: Vec<u32> = (0..n).map(|_| erng.below(n) as u32).collect();
                match model.task {
                    Task::Regression | Task::Binary => {
                        for i in 0..n {
                            gk[i] = -data.y[i];
                        }
                    }
                    Task::MultiClass(_) => {
                        for i in 0..n {
                            gk[i] = -f32::from(data.y[i] as usize == class);
                        }
                    }
                }
                out.trees[ti] = grow_tree(&m, rows, &gk, &hk, &grow, &mut erng, &mut scratch);
            }
        }
    }
    out
}

/// Outcome of one [`defect_aware_retrain`] run.
#[derive(Clone, Debug, Default)]
pub struct RetrainReport {
    /// Refit passes actually executed (≤ `HatParams::retrain_passes`).
    pub passes: usize,
    /// Trees whose rows landed on defective cells before retraining.
    pub initial_affected: usize,
    /// Same count for the returned model.
    pub final_affected: usize,
    /// Deployed (defective) score before retraining.
    pub initial_score: f64,
    /// Deployed score of the returned model (≥ `initial_score` — the
    /// best pass is kept, falling back to the input model).
    pub final_score: f64,
}

/// Defect-aware retrain loop (paper §V-A outlook; RETENTION-style): given
/// the known defect map of a specific chip, repeatedly re-fit the trees
/// whose CAM rows land on defective cells and keep the best pass by
/// deployed score.
///
/// The deployment oracle is injected so this module stays below the
/// compiler in the layer map: `probe` compiles the model **once** and
/// returns `(affected_tree_ids, deployed_score)` — the tree ids whose
/// rows land on defective cells under the chip's defect draw
/// (`compiler::defect_affected_trees`) and the task score served through
/// the *defective* engine (`compiler::defective_score`). One probe per
/// pass is the loop's entire compile cost.
///
/// Use [`crate::compiler::hat_defect_retrain`] for the pre-wired version.
/// The returned model never scores below the input model under the probe
/// (the input is the fallback best).
pub fn defect_aware_retrain(
    data: &Dataset,
    model: Ensemble,
    params: &HatParams,
    probe: &dyn Fn(&Ensemble) -> (Vec<u32>, f64),
) -> (Ensemble, RetrainReport) {
    let (mut cur_affected, initial_score) = probe(&model);
    let initial_affected = cur_affected.len();
    let mut report = RetrainReport {
        passes: 0,
        initial_affected,
        final_affected: initial_affected,
        initial_score,
        final_score: initial_score,
    };
    let mut best = model.clone();
    let mut best_score = initial_score;
    let mut best_affected = initial_affected;
    let mut cur = model;
    for pass in 0..params.retrain_passes {
        if cur_affected.is_empty() {
            break;
        }
        cur = refit_trees(data, &cur, &cur_affected, params, 0x9E77_0000 + pass as u64);
        report.passes = pass + 1;
        let (affected, s) = probe(&cur);
        if s > best_score {
            best_score = s;
            best = cur.clone();
            best_affected = affected.len();
        }
        cur_affected = affected;
    }
    report.final_affected = best_affected;
    report.final_score = best_score;
    (best, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::by_name;
    use crate::trees::metrics::score;

    fn small_hat(bits: u8) -> HatParams {
        HatParams {
            deploy_bits: bits,
            gbdt: GbdtParams {
                n_rounds: 20,
                max_leaves: 16,
                max_depth: 4,
                learning_rate: 0.3,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn hat_model_lives_on_the_deploy_grid() {
        let d = by_name("churn").unwrap().generate_n(1500);
        for bits in [4u8, 6, 8] {
            let m = train(&d, &small_hat(bits), None);
            assert_eq!(m.quantizer.n_bits, bits);
            // Every threshold is a bin index on that grid (< 2^bits).
            let nb = 1u16 << bits;
            for t in &m.trees {
                for node in &t.nodes {
                    if let crate::trees::Node::Split { threshold_bin, .. } = node {
                        assert!(*threshold_bin >= 1 && *threshold_bin < nb);
                    }
                }
            }
        }
    }

    #[test]
    fn hat_still_learns_at_four_bits() {
        let d = by_name("churn").unwrap().generate_n(2000);
        let s = d.split(0.7, 0.0, 5);
        let m = train(&s.train, &small_hat(4), None);
        let acc = score(&m, &s.test);
        assert!(acc > 0.72, "4-bit HAT accuracy {acc}");
    }

    #[test]
    fn hat_rf_trains_on_the_deploy_grid() {
        let d = by_name("gas").unwrap().generate_n(1500);
        let p = HatParams {
            deploy_bits: 4,
            kind: ModelKind::RandomForest,
            rf: RfParams { n_estimators: 10, max_leaves: 32, ..Default::default() },
            ..Default::default()
        };
        let m = train(&d, &p, None);
        assert_eq!(m.quantizer.n_bits, 4);
        assert!(score(&m, &d) > 0.4, "in-sample RF score too low");
    }

    #[test]
    fn refit_replaces_only_affected_trees() {
        let d = by_name("telco").unwrap().generate_n(1000);
        let p = small_hat(6);
        let m = train(&d, &p, None);
        let affected = vec![1u32, 3];
        let r = refit_trees(&d, &m, &affected, &p, 99);
        assert_eq!(r.n_trees(), m.n_trees());
        assert_eq!(r.tree_class, m.tree_class);
        assert_eq!(r.base_score, m.base_score);
        assert_eq!(r.quantizer.edges, m.quantizer.edges, "deploy grid must be reused");
        for ti in 0..m.n_trees() {
            if affected.contains(&(ti as u32)) {
                continue;
            }
            assert_eq!(r.trees[ti], m.trees[ti], "unaffected tree {ti} changed");
        }
        // Refit keeps the model functional.
        let before = score(&m, &d);
        let after = score(&r, &d);
        assert!(after > before - 0.1, "refit collapsed: {before} → {after}");
    }

    #[test]
    fn refit_with_empty_set_is_identity() {
        let d = by_name("telco").unwrap().generate_n(600);
        let p = small_hat(8);
        let m = train(&d, &p, None);
        let r = refit_trees(&d, &m, &[], &p, 1);
        assert_eq!(r.trees, m.trees);
    }

    #[test]
    fn refit_rf_trees() {
        let d = by_name("gas").unwrap().generate_n(1000);
        let p = HatParams {
            deploy_bits: 6,
            kind: ModelKind::RandomForest,
            rf: RfParams { n_estimators: 6, max_leaves: 16, ..Default::default() },
            ..Default::default()
        };
        let m = train(&d, &p, None);
        let k = m.task.n_outputs();
        let affected = vec![0u32, (k as u32) + 1];
        let r = refit_trees(&d, &m, &affected, &p, 7);
        assert_eq!(r.n_trees(), m.n_trees());
        for ti in 0..m.n_trees() {
            if !affected.contains(&(ti as u32)) {
                assert_eq!(r.trees[ti], m.trees[ti]);
            }
        }
        assert!(score(&r, &d) > 0.3);
    }

    #[test]
    fn retrain_loop_never_returns_a_worse_model() {
        // Synthetic probe: tree 0 is "always on a defective cell"; the
        // score is plain in-sample accuracy. The loop must keep whichever
        // pass scores best — never below the input model.
        let d = by_name("churn").unwrap().generate_n(1200);
        let mut p = small_hat(6);
        p.retrain_passes = 2;
        let m = train(&d, &p, None);
        let probe = |m: &Ensemble| (vec![0u32], score(m, &d));
        let (best, report) = defect_aware_retrain(&d, m.clone(), &p, &probe);
        assert_eq!(report.initial_affected, 1);
        assert_eq!(report.passes, 2);
        assert!(report.final_score >= report.initial_score, "{report:?}");
        assert!(score(&best, &d) >= score(&m, &d) - 1e-12);
    }

    #[test]
    fn retrain_loop_stops_when_nothing_is_affected() {
        let d = by_name("telco").unwrap().generate_n(600);
        let p = small_hat(8);
        let m = train(&d, &p, None);
        let probe = |m: &Ensemble| (Vec::new(), score(m, &d));
        let (best, report) = defect_aware_retrain(&d, m.clone(), &p, &probe);
        assert_eq!(report.passes, 0);
        assert_eq!(report.initial_affected, 0);
        assert_eq!(report.final_affected, 0);
        assert_eq!(best.trees, m.trees);
    }
}
