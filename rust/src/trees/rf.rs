//! Random forests (scikit-learn stand-in).
//!
//! Each estimator is a bagged tree grown with per-split feature subsampling
//! (√F by default). For multi-class tasks each estimator contributes one
//! one-vs-rest tree **per class** whose leaves store class-probability
//! votes — the exact layout Fig. 7(b) maps onto cores ("N_estimators
//! estimators each made of N_trees, one for each class"), so the ensemble
//! reduction is the paper's class-wise sum + CP argmax (= soft majority
//! voting). Leaf votes are pre-scaled by 1/N_estimators so the hardware's
//! *sum* reduction directly yields mean probabilities.

use crate::data::{Dataset, FeatureQuantizer, Task};
use crate::trees::grow::{grow_tree, BinnedMatrix, GrowParams, GrowScratch};
use crate::trees::tree::{Ensemble, Tree};
use crate::util::Rng;

/// Random-forest hyper-parameters.
#[derive(Clone, Debug)]
pub struct RfParams {
    /// Number of bagged estimators (total trees = estimators × n_outputs).
    pub n_estimators: usize,
    pub max_leaves: usize,
    pub max_depth: usize,
    /// Per-split feature fraction; `None` = √F heuristic.
    pub colsample: Option<f64>,
    pub min_child_weight: f64,
    pub n_bits: u8,
    pub seed: u64,
    /// Variation-aware split scoring (hardware-aware training): see
    /// [`crate::trees::gbdt::GbdtParams::variation_flip_prob`].
    pub variation_flip_prob: f64,
}

impl Default for RfParams {
    fn default() -> Self {
        RfParams {
            n_estimators: 100,
            max_leaves: 256,
            max_depth: 14,
            colsample: None,
            min_child_weight: 2.0,
            n_bits: 8,
            seed: 13,
            variation_flip_prob: 0.0,
        }
    }
}

impl RfParams {
    /// Effective per-split feature fraction (√F heuristic by default).
    pub(crate) fn effective_colsample(&self, n_features: usize) -> f64 {
        self.colsample.unwrap_or_else(|| (n_features as f64).sqrt() / n_features as f64)
    }

    /// The grower-facing subset of these params — the single source of
    /// truth shared by [`train`] and `hat::refit_trees`.
    pub(crate) fn grow_params(&self, n_features: usize, n_estimators: usize) -> GrowParams {
        GrowParams {
            max_leaves: self.max_leaves,
            max_depth: self.max_depth,
            lambda: 0.0,
            gamma: 1e-9,
            min_child_weight: self.min_child_weight,
            // Mean-target leaves, scaled so the ensemble SUM is the mean
            // vote.
            leaf_scale: 1.0 / n_estimators as f32,
            colsample: self.effective_colsample(n_features),
            col_per_split: true,
            variation_flip_prob: self.variation_flip_prob,
        }
    }
}

/// Train a random forest.
pub fn train(data: &Dataset, params: &RfParams) -> Ensemble {
    let task = data.task;
    let n = data.n_rows();
    assert!(n > 1, "empty training set");
    let k = task.n_outputs();

    let quantizer = FeatureQuantizer::fit(data, params.n_bits);
    let m = BinnedMatrix {
        bins: quantizer.transform(data),
        n_rows: n,
        n_features: data.n_features,
        n_bins: quantizer.n_bins(),
    };

    let grow = params.grow_params(data.n_features, params.n_estimators);

    let mut rng = Rng::new(params.seed);
    let mut scratch = GrowScratch::new(m.n_features, m.n_bins);
    let mut trees: Vec<Tree> = Vec::new();
    let mut tree_class: Vec<u16> = Vec::new();

    // Per-class regression targets: variance reduction on one-vs-rest
    // indicators == gini-style impurity reduction, and the fitted leaf
    // value (mean of indicator) is the class probability.
    let mut gk = vec![0f32; n];
    let hk = vec![1f32; n];

    for est in 0..params.n_estimators {
        // Bootstrap sample (with replacement), shared across the per-class
        // trees of this estimator so they see the same data view.
        let mut erng = rng.fork(est as u64);
        let rows: Vec<u32> = (0..n).map(|_| erng.below(n) as u32).collect();
        for class in 0..k {
            match task {
                Task::Regression => {
                    for i in 0..n {
                        gk[i] = -data.y[i];
                    }
                }
                Task::Binary => {
                    for i in 0..n {
                        gk[i] = -(data.y[i]);
                    }
                }
                Task::MultiClass(_) => {
                    for i in 0..n {
                        gk[i] = -f32::from(data.y[i] as usize == class);
                    }
                }
            }
            let tree = grow_tree(&m, rows.clone(), &gk, &hk, &grow, &mut erng, &mut scratch);
            trees.push(tree);
            tree_class.push(class as u16);
        }
    }

    // Base scores: regression sums mean-of-means (already folded into
    // leaves), binary needs the -0.5 decision offset so `logit > 0`
    // implements `mean vote > 0.5`.
    let base = match task {
        Task::Regression => vec![0.0],
        Task::Binary => vec![-0.5],
        Task::MultiClass(k) => vec![0.0; k],
    };

    Ensemble {
        name: data.name.clone(),
        task,
        n_features: data.n_features,
        trees,
        tree_class,
        base_score: base,
        quantizer,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::by_name;
    use crate::trees::metrics::score;

    fn small_params(n_estimators: usize) -> RfParams {
        RfParams { n_estimators, max_leaves: 32, max_depth: 8, ..Default::default() }
    }

    #[test]
    fn learns_binary_task() {
        let d = by_name("churn").unwrap().generate_n(2000);
        let s = d.split(0.7, 0.0, 1);
        let model = train(&s.train, &small_params(30));
        let acc = score(&model, &s.test);
        assert!(acc > 0.75, "accuracy {acc}");
    }

    #[test]
    fn learns_multiclass_task() {
        let d = by_name("gesture").unwrap().generate_n(2500);
        let s = d.split(0.7, 0.0, 2);
        let model = train(&s.train, &small_params(25));
        let acc = score(&model, &s.test);
        assert!(acc > 0.45, "accuracy {acc} (chance = 0.2)");
        assert_eq!(model.n_trees(), 25 * 5);
    }

    #[test]
    fn learns_regression_task() {
        let d = by_name("rossmann").unwrap().generate_n(1500);
        let s = d.split(0.7, 0.0, 3);
        let model = train(&s.train, &small_params(30));
        let r2 = score(&model, &s.test);
        assert!(r2 > 0.3, "R² {r2}");
    }

    #[test]
    fn binary_votes_bounded() {
        // Sum of per-tree probability votes must lie in [0, 1] before the
        // -0.5 offset, i.e. logits in [-0.5, 0.5].
        let d = by_name("telco").unwrap().generate_n(800);
        let model = train(&d, &small_params(10));
        for i in 0..50 {
            let l = model.logits(d.row(i))[0];
            assert!((-0.5 - 1e-4..=0.5 + 1e-4).contains(&l), "logit {l}");
        }
    }

    #[test]
    fn deterministic() {
        let d = by_name("telco").unwrap().generate_n(600);
        let a = train(&d, &small_params(5));
        let b = train(&d, &small_params(5));
        for (ta, tb) in a.trees.iter().zip(&b.trees) {
            assert_eq!(ta, tb);
        }
    }

    #[test]
    fn bagging_diversifies_trees() {
        let d = by_name("churn").unwrap().generate_n(800);
        let model = train(&d, &small_params(6));
        // At least two distinct trees (bootstrap + feature subsampling).
        assert!(model.trees.windows(2).any(|w| w[0] != w[1]));
    }
}
