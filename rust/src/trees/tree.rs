//! Decision-tree and ensemble data structures.
//!
//! Trees are trained and evaluated over *binned* features (`u16` bin
//! indices produced by [`crate::data::FeatureQuantizer`]); a split sends a
//! sample right iff `bin >= threshold_bin`. This is exactly the form the
//! X-TIME compiler needs: thresholds are already quantized to the CAM's
//! representable levels, so compilation to CAM rows is lossless.

use crate::data::{FeatureQuantizer, Task};
use crate::util::Json;

/// A tree node. Indices address the tree's `nodes` vector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Node {
    /// `bin >= threshold_bin` → right child, else left child.
    Split { feature: u32, threshold_bin: u16, left: u32, right: u32 },
    /// Prediction contribution (a logit for GBDT, a vote weight for RF).
    Leaf { value: f32 },
}

/// A single binary decision tree over binned features.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Tree {
    pub nodes: Vec<Node>,
}

impl Tree {
    pub fn leaf(value: f32) -> Tree {
        Tree { nodes: vec![Node::Leaf { value }] }
    }

    /// Evaluate on a binned row; returns the matched leaf's value.
    #[inline]
    pub fn predict_bins(&self, bins: &[u16]) -> f32 {
        let mut i = 0u32;
        loop {
            match self.nodes[i as usize] {
                Node::Leaf { value } => return value,
                Node::Split { feature, threshold_bin, left, right } => {
                    i = if bins[feature as usize] >= threshold_bin { right } else { left };
                }
            }
        }
    }

    /// Index of the matched leaf (used to cross-check CAM row matching).
    pub fn matched_leaf(&self, bins: &[u16]) -> u32 {
        let mut i = 0u32;
        loop {
            match self.nodes[i as usize] {
                Node::Leaf { .. } => return i,
                Node::Split { feature, threshold_bin, left, right } => {
                    i = if bins[feature as usize] >= threshold_bin { right } else { left };
                }
            }
        }
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Leaf { .. })).count()
    }

    /// Maximum root-to-leaf depth (leaf at root = depth 0).
    pub fn depth(&self) -> usize {
        fn walk(t: &Tree, i: u32) -> usize {
            match t.nodes[i as usize] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + walk(t, left).max(walk(t, right)),
            }
        }
        walk(self, 0)
    }

    /// All features referenced by split nodes.
    pub fn used_features(&self) -> Vec<u32> {
        let mut f: Vec<u32> = self
            .nodes
            .iter()
            .filter_map(|n| match n {
                Node::Split { feature, .. } => Some(*feature),
                _ => None,
            })
            .collect();
        f.sort_unstable();
        f.dedup();
        f
    }

    // ---- JSON (model files) -------------------------------------------
    pub fn to_json(&self) -> Json {
        // Flat encoding: kind 0 = split, 1 = leaf.
        let mut kind = Vec::new();
        let mut a = Vec::new(); // feature / value
        let mut b = Vec::new(); // threshold_bin
        let mut l = Vec::new();
        let mut r = Vec::new();
        for n in &self.nodes {
            match *n {
                Node::Split { feature, threshold_bin, left, right } => {
                    kind.push(Json::Num(0.0));
                    a.push(Json::Num(feature as f64));
                    b.push(Json::Num(threshold_bin as f64));
                    l.push(Json::Num(left as f64));
                    r.push(Json::Num(right as f64));
                }
                Node::Leaf { value } => {
                    kind.push(Json::Num(1.0));
                    a.push(Json::Num(value as f64));
                    b.push(Json::Num(0.0));
                    l.push(Json::Num(0.0));
                    r.push(Json::Num(0.0));
                }
            }
        }
        let mut o = Json::obj();
        o.set("kind", Json::Arr(kind))
            .set("a", Json::Arr(a))
            .set("b", Json::Arr(b))
            .set("l", Json::Arr(l))
            .set("r", Json::Arr(r));
        o
    }

    pub fn from_json(j: &Json) -> Result<Tree, String> {
        let kind = j.req("kind")?.f64_vec()?;
        let a = j.req("a")?.f64_vec()?;
        let b = j.req("b")?.f64_vec()?;
        let l = j.req("l")?.f64_vec()?;
        let r = j.req("r")?.f64_vec()?;
        let mut nodes = Vec::with_capacity(kind.len());
        for i in 0..kind.len() {
            nodes.push(if kind[i] == 0.0 {
                Node::Split {
                    feature: a[i] as u32,
                    threshold_bin: b[i] as u16,
                    left: l[i] as u32,
                    right: r[i] as u32,
                }
            } else {
                Node::Leaf { value: a[i] as f32 }
            });
        }
        Ok(Tree { nodes })
    }
}

/// A trained ensemble: trees plus the quantizer that maps raw features to
/// bins and metadata needed for reduction.
#[derive(Clone, Debug)]
pub struct Ensemble {
    pub name: String,
    pub task: Task,
    pub n_features: usize,
    pub trees: Vec<Tree>,
    /// Class each tree contributes to (always 0 for regression/binary).
    pub tree_class: Vec<u16>,
    /// Additive prior per output column.
    pub base_score: Vec<f32>,
    pub quantizer: FeatureQuantizer,
}

impl Ensemble {
    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    pub fn max_leaves(&self) -> usize {
        self.trees.iter().map(|t| t.n_leaves()).max().unwrap_or(0)
    }

    pub fn total_leaves(&self) -> usize {
        self.trees.iter().map(|t| t.n_leaves()).sum()
    }

    pub fn max_depth(&self) -> usize {
        self.trees.iter().map(|t| t.depth()).max().unwrap_or(0)
    }

    /// Raw logit accumulation: bins the row, sums each tree's matched leaf
    /// into its class column, adds the base score. This is the *reference
    /// semantics* every backend (CAM functional model, cycle simulator,
    /// XLA artifact) must agree with exactly.
    pub fn logits(&self, row: &[f32]) -> Vec<f32> {
        let bins = self.quantizer.bin_row(row);
        self.logits_bins(&bins)
    }

    pub fn logits_bins(&self, bins: &[u16]) -> Vec<f32> {
        let mut out = self.base_score.clone();
        for (t, tree) in self.trees.iter().enumerate() {
            out[self.tree_class[t] as usize] += tree.predict_bins(bins);
        }
        out
    }

    /// Base-free per-class leaf sums accumulated in f64 — the partial-sum
    /// form a sharded serving pool aggregates across shards (the host adds
    /// `base_score` once after summation).
    pub fn partial_sums_bins(&self, bins: &[u16]) -> Vec<f64> {
        let mut out = vec![0f64; self.base_score.len()];
        for (t, tree) in self.trees.iter().enumerate() {
            out[self.tree_class[t] as usize] += tree.predict_bins(bins) as f64;
        }
        out
    }

    /// Task-level prediction: regression value, or class index.
    pub fn predict(&self, row: &[f32]) -> f32 {
        let logits = self.logits(row);
        match self.task {
            Task::Regression => logits[0],
            Task::Binary => (logits[0] > 0.0) as usize as f32,
            Task::MultiClass(_) => {
                let mut best = 0usize;
                for c in 1..logits.len() {
                    if logits[c] > logits[best] {
                        best = c;
                    }
                }
                best as f32
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name.clone()))
            .set("task", Json::Str(self.task.name()))
            .set("n_classes", Json::Num(self.task.n_classes() as f64))
            .set("n_features", Json::Num(self.n_features as f64))
            .set("trees", Json::Arr(self.trees.iter().map(|t| t.to_json()).collect()))
            .set(
                "tree_class",
                Json::Arr(self.tree_class.iter().map(|&c| Json::Num(c as f64)).collect()),
            )
            .set("base_score", Json::from_f32_slice(&self.base_score))
            .set("quant_bits", Json::Num(self.quantizer.n_bits as f64))
            .set(
                "quant_edges",
                Json::Arr(self.quantizer.edges.iter().map(|e| Json::from_f32_slice(e)).collect()),
            );
        o
    }

    pub fn from_json(j: &Json) -> Result<Ensemble, String> {
        let task = match j.req_str("task")? {
            "regression" => Task::Regression,
            "binary" => Task::Binary,
            s if s.starts_with("multiclass") => Task::MultiClass(j.req_usize("n_classes")?),
            s => return Err(format!("unknown task `{s}`")),
        };
        let trees = j
            .req_arr("trees")?
            .iter()
            .map(Tree::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let tree_class = j
            .req_arr("tree_class")?
            .iter()
            .map(|v| v.as_f64().map(|x| x as u16).ok_or("bad tree_class".to_string()))
            .collect::<Result<Vec<_>, _>>()?;
        let edges = j
            .req_arr("quant_edges")?
            .iter()
            .map(|e| e.f32_vec())
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Ensemble {
            name: j.req_str("name")?.to_string(),
            task,
            n_features: j.req_usize("n_features")?,
            trees,
            tree_class,
            base_score: j.req("base_score")?.f32_vec()?,
            quantizer: FeatureQuantizer { n_bits: j.req_usize("quant_bits")? as u8, edges },
        })
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    pub fn load(path: &std::path::Path) -> Result<Ensemble, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        Ensemble::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// f0 >= 3 ? (f1 >= 7 ? 3.0 : 2.0) : 1.0
    fn sample_tree() -> Tree {
        Tree {
            nodes: vec![
                Node::Split { feature: 0, threshold_bin: 3, left: 1, right: 2 },
                Node::Leaf { value: 1.0 },
                Node::Split { feature: 1, threshold_bin: 7, left: 3, right: 4 },
                Node::Leaf { value: 2.0 },
                Node::Leaf { value: 3.0 },
            ],
        }
    }

    #[test]
    fn predict_routes_correctly() {
        let t = sample_tree();
        assert_eq!(t.predict_bins(&[0, 0]), 1.0);
        assert_eq!(t.predict_bins(&[3, 0]), 2.0);
        assert_eq!(t.predict_bins(&[5, 7]), 3.0);
        assert_eq!(t.predict_bins(&[2, 200]), 1.0);
    }

    #[test]
    fn structure_stats() {
        let t = sample_tree();
        assert_eq!(t.n_leaves(), 3);
        assert_eq!(t.depth(), 2);
        assert_eq!(t.used_features(), vec![0, 1]);
    }

    #[test]
    fn tree_json_roundtrip() {
        let t = sample_tree();
        let back = Tree::from_json(&t.to_json()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn matched_leaf_agrees_with_value() {
        let t = sample_tree();
        for bins in [[0u16, 0], [3, 0], [5, 9]] {
            let leaf = t.matched_leaf(&bins);
            match t.nodes[leaf as usize] {
                Node::Leaf { value } => assert_eq!(value, t.predict_bins(&bins)),
                _ => panic!("matched_leaf returned a split node"),
            }
        }
    }
}
