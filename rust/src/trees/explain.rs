//! Explainability: the "backtracking" the paper highlights as a key DT
//! advantage (§II-A: "backtracking operations to determine why an input
//! was placed in a given class are straightforward").
//!
//! On X-TIME hardware the explanation is *free*: the matched CAM row *is*
//! the root-to-leaf path, so its non-don't-care cells are exactly the
//! conditions that fired. This module provides:
//!
//! * [`explain_row`] — per-sample explanations from matched CAM rows
//!   (feature windows + leaf contributions, ranked by |logit|);
//! * [`gain_importance`] — global split-gain feature importance;
//! * [`permutation_importance`] — model-agnostic validation of the above.

use crate::compiler::{CamProgram, CamRow};
use crate::data::Dataset;
use crate::trees::tree::{Ensemble, Node};
use crate::trees::metrics;
use crate::util::Rng;

/// One fired condition of an explanation: feature f was inside `[lo, hi)`
/// (bin space), contributing `leaf` to class `class`.
#[derive(Clone, Debug, PartialEq)]
pub struct Condition {
    pub feature: usize,
    pub lo_bin: u16,
    pub hi_bin: u16,
    pub leaf: f32,
    pub class: u16,
    pub tree: u32,
}

/// Explanation of one prediction: every matched CAM row's constrained
/// cells, plus per-feature aggregate attribution.
#[derive(Clone, Debug)]
pub struct Explanation {
    pub prediction: f32,
    pub conditions: Vec<Condition>,
    /// Σ |leaf| of rows constraining each feature.
    pub feature_attribution: Vec<f32>,
}

/// Explain a prediction by backtracking matched CAM rows (§II-A).
pub fn explain_row(program: &CamProgram, row: &[f32]) -> Explanation {
    let bins = program.quantizer.bin_row(row);
    let mut conditions = Vec::new();
    let mut attribution = vec![0f32; program.n_features];
    let mut logits = program.base_score.clone();
    logits.resize(program.task.n_outputs().max(1), 0.0);
    for core in &program.cores {
        for r in &core.rows {
            if !r.matches(&bins) {
                continue;
            }
            logits[r.class as usize] += r.leaf;
            record_conditions(r, program.n_bins, &mut conditions, &mut attribution);
        }
    }
    // Strongest contributions first.
    conditions.sort_by(|a, b| b.leaf.abs().partial_cmp(&a.leaf.abs()).unwrap());
    Explanation {
        prediction: program.task.decide(&logits),
        conditions,
        feature_attribution: attribution,
    }
}

fn record_conditions(
    row: &CamRow,
    n_bins: u16,
    out: &mut Vec<Condition>,
    attribution: &mut [f32],
) {
    for f in 0..row.lo.len() {
        let (lo, hi) = (row.lo[f], row.hi[f]);
        if lo == 0 && hi >= n_bins {
            continue; // don't care
        }
        attribution[f] += row.leaf.abs();
        out.push(Condition {
            feature: f,
            lo_bin: lo,
            hi_bin: hi,
            leaf: row.leaf,
            class: row.class,
            tree: row.tree,
        });
    }
}

/// Global split-gain importance: Σ over split nodes of the hessian-
/// weighted gain proxy (XGBoost's `total_gain` analogue — here we use
/// split counts weighted by subtree leaf mass since raw gains are not
/// stored in the compiled model).
pub fn gain_importance(model: &Ensemble) -> Vec<f64> {
    let mut imp = vec![0f64; model.n_features];
    for tree in &model.trees {
        for node in &tree.nodes {
            if let Node::Split { feature, .. } = node {
                imp[*feature as usize] += 1.0;
            }
        }
    }
    let total: f64 = imp.iter().sum();
    if total > 0.0 {
        for v in imp.iter_mut() {
            *v /= total;
        }
    }
    imp
}

/// Permutation importance: score drop when one feature column is
/// shuffled (model-agnostic ground truth for the split-count proxy).
pub fn permutation_importance(model: &Ensemble, data: &Dataset, seed: u64) -> Vec<f64> {
    let base = metrics::score(model, data);
    let mut rng = Rng::new(seed);
    let mut out = vec![0f64; data.n_features];
    for f in 0..data.n_features {
        let mut shuffled = data.clone();
        let mut col: Vec<f32> = (0..data.n_rows()).map(|i| data.row(i)[f]).collect();
        rng.shuffle(&mut col);
        for i in 0..data.n_rows() {
            shuffled.x[i * data.n_features + f] = col[i];
        }
        out[f] = base - metrics::score(model, &shuffled);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    fn setup() -> (Dataset, Ensemble, CamProgram) {
        let d = by_name("churn").unwrap().generate_n(1500);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 12, max_leaves: 16, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        (d, m, p)
    }

    #[test]
    fn explanation_matches_prediction() {
        let (d, m, p) = setup();
        for i in 0..50 {
            let e = explain_row(&p, d.row(i));
            assert_eq!(e.prediction, m.predict(d.row(i)), "row {i}");
        }
    }

    #[test]
    fn one_condition_set_per_tree() {
        let (d, m, p) = setup();
        let e = explain_row(&p, d.row(0));
        // Each matched row contributes its constrained features; the
        // number of distinct trees in the conditions == n_trees (every
        // tree matches exactly one row, and trained trees always split).
        let mut trees: Vec<u32> = e.conditions.iter().map(|c| c.tree).collect();
        trees.sort_unstable();
        trees.dedup();
        assert_eq!(trees.len(), m.n_trees());
    }

    #[test]
    fn conditions_actually_hold() {
        let (d, _, p) = setup();
        let bins = p.quantizer.bin_row(d.row(3));
        for c in explain_row(&p, d.row(3)).conditions {
            let b = bins[c.feature];
            assert!(c.lo_bin <= b && b < c.hi_bin, "condition does not hold: {c:?} bin {b}");
        }
    }

    #[test]
    fn importance_finds_informative_features() {
        let (d, m, _) = setup();
        // churn: 10 features, first 8 informative (catalog). Split-count
        // importance should put most mass on informative features.
        let gain = gain_importance(&m);
        assert_eq!(gain.len(), 10);
        let informative: f64 = gain[..8].iter().sum();
        assert!(informative > 0.7, "informative mass {informative}");
        // Permutation importance agrees on the top feature's relevance.
        let perm = permutation_importance(&m, &d, 5);
        let top_gain = (0..10).max_by(|&a, &b| gain[a].partial_cmp(&gain[b]).unwrap()).unwrap();
        assert!(perm[top_gain] > 0.0, "top gain feature has no permutation impact");
    }

    #[test]
    fn attribution_covers_used_features_only() {
        let (d, m, p) = setup();
        let used: Vec<u32> =
            m.trees.iter().flat_map(|t| t.used_features()).collect();
        let e = explain_row(&p, d.row(1));
        for (f, &a) in e.feature_attribution.iter().enumerate() {
            if a > 0.0 {
                assert!(used.contains(&(f as u32)), "attribution on unused feature {f}");
            }
        }
    }
}
