//! Evaluation metrics: accuracy for classification, RMSE / R² for
//! regression, plus the paper's "relative accuracy" (Fig. 9b).

use crate::data::{Dataset, Task};
use crate::trees::tree::Ensemble;

/// Classification accuracy of task-level predictions against labels.
pub fn accuracy(preds: &[f32], y: &[f32]) -> f64 {
    assert_eq!(preds.len(), y.len());
    let hits = preds.iter().zip(y).filter(|(p, t)| p == t).count();
    hits as f64 / y.len() as f64
}

pub fn rmse(preds: &[f32], y: &[f32]) -> f64 {
    assert_eq!(preds.len(), y.len());
    let sse: f64 = preds.iter().zip(y).map(|(p, t)| ((p - t) as f64).powi(2)).sum();
    (sse / y.len() as f64).sqrt()
}

pub fn r2(preds: &[f32], y: &[f32]) -> f64 {
    let mean = y.iter().map(|&v| v as f64).sum::<f64>() / y.len() as f64;
    let ss_tot: f64 = y.iter().map(|&v| (v as f64 - mean).powi(2)).sum();
    let ss_res: f64 = preds.iter().zip(y).map(|(p, t)| ((p - t) as f64).powi(2)).sum();
    if ss_tot == 0.0 {
        0.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Task-appropriate score: accuracy (higher better) for classification,
/// R² (higher better) for regression — matching how Fig. 9(a) reports a
/// single "accuracy" number per dataset.
pub fn score(model: &Ensemble, data: &Dataset) -> f64 {
    let preds: Vec<f32> = (0..data.n_rows()).map(|i| model.predict(data.row(i))).collect();
    match data.task {
        Task::Regression => r2(&preds, &data.y),
        _ => accuracy(&preds, &data.y),
    }
}

/// Fig. 9(b) "relative accuracy": defect-compromised score over ideal score.
pub fn relative(ideal: f64, compromised: f64) -> f64 {
    if ideal == 0.0 {
        0.0
    } else {
        compromised / ideal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_hits() {
        assert_eq!(accuracy(&[1.0, 0.0, 1.0, 1.0], &[1.0, 1.0, 1.0, 0.0]), 0.5);
    }

    #[test]
    fn rmse_zero_on_exact() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn r2_perfect_is_one() {
        let y = [1.0, 2.0, 3.0, 4.0];
        assert!((r2(&y, &y) - 1.0).abs() < 1e-12);
        // Predicting the mean gives R² = 0.
        let mean = [2.5f32; 4];
        assert!(r2(&mean, &y).abs() < 1e-9);
    }

    #[test]
    fn relative_accuracy() {
        assert!((relative(0.8, 0.72) - 0.9).abs() < 1e-12);
    }
}
