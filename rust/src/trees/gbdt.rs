//! Gradient-boosted decision trees (XGBoost-style second-order boosting).
//!
//! Stands in for XGBoost / CatBoost / LightGBM (Table II trains one of the
//! three per dataset). Multi-class training grows one tree per class per
//! round against the softmax gradients — exactly the layout the X-TIME
//! compiler wants, since every tree then carries a single `class ID`
//! (§III-A: "class and tree ID are uniquely represented in the core
//! address").

use crate::data::{Dataset, FeatureQuantizer, Task};
use crate::trees::grow::{grow_tree, BinnedMatrix, GrowParams, GrowScratch};
use crate::trees::loss::{grad_hess, loss};
use crate::trees::tree::{Ensemble, Tree};
use crate::util::Rng;

/// GBDT hyper-parameters.
#[derive(Clone, Debug)]
pub struct GbdtParams {
    /// Boosting rounds (total trees = rounds × n_outputs).
    pub n_rounds: usize,
    pub learning_rate: f32,
    /// Hardware-facing cap: `N_leaves,max` (§III-C → 256 per core).
    pub max_leaves: usize,
    pub max_depth: usize,
    pub lambda: f32,
    pub gamma: f32,
    pub min_child_weight: f64,
    /// Row subsample fraction per tree.
    pub subsample: f64,
    /// Feature subsample fraction per tree.
    pub colsample_bytree: f64,
    /// Feature quantization bits (8 = X-TIME 8-bit, 4 = 4-bit ablation,
    /// 11 ≈ float-precision "unconstrained" baseline).
    pub n_bits: u8,
    pub seed: u64,
    /// Stop if validation loss fails to improve for this many rounds
    /// (0 disables early stopping).
    pub early_stop_rounds: usize,
    /// Defect-aware co-design training (paper §V-A outlook): per round,
    /// split finding sees feature bins jittered ±1 level with this
    /// probability, so the learner avoids razor-thin split margins that
    /// analog conductance variation would flip. 0.0 disables.
    pub bin_jitter: f64,
    /// Variation-aware split scoring (hardware-aware training, see
    /// [`crate::trees::hat`]): probability that a programmed threshold
    /// drifts ±1 bin; candidate splits are scored by expected gain under
    /// that drift so chosen splits carry margin against conductance
    /// noise. 0.0 disables (exact classic scoring).
    pub variation_flip_prob: f64,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams {
            n_rounds: 100,
            learning_rate: 0.15,
            max_leaves: 256,
            max_depth: 8,
            lambda: 1.0,
            gamma: 0.0,
            min_child_weight: 1.0,
            subsample: 0.8,
            colsample_bytree: 0.9,
            n_bits: 8,
            seed: 7,
            early_stop_rounds: 0,
            bin_jitter: 0.0,
            variation_flip_prob: 0.0,
        }
    }
}

impl GbdtParams {
    /// The grower-facing subset of these params — the single source of
    /// truth shared by [`train`] and `hat::refit_trees`, so replacement
    /// trees are grown under exactly the regime of the trees they
    /// replace.
    pub(crate) fn grow_params(&self) -> GrowParams {
        GrowParams {
            max_leaves: self.max_leaves,
            max_depth: self.max_depth,
            lambda: self.lambda,
            gamma: self.gamma,
            min_child_weight: self.min_child_weight,
            leaf_scale: self.learning_rate,
            colsample: self.colsample_bytree,
            col_per_split: false,
            variation_flip_prob: self.variation_flip_prob,
        }
    }
}

fn base_scores(task: Task, y: &[f32]) -> Vec<f32> {
    match task {
        Task::Regression => {
            vec![y.iter().sum::<f32>() / y.len() as f32]
        }
        Task::Binary => {
            let p = (y.iter().sum::<f32>() / y.len() as f32).clamp(1e-4, 1.0 - 1e-4);
            vec![(p / (1.0 - p)).ln()]
        }
        Task::MultiClass(k) => {
            let mut counts = vec![0f32; k];
            for &v in y {
                counts[v as usize] += 1.0;
            }
            counts.iter().map(|&c| (c.max(1.0) / y.len() as f32).ln()).collect()
        }
    }
}

/// Train a GBDT ensemble; if `val` is given it is used for early stopping.
pub fn train(data: &Dataset, params: &GbdtParams, val: Option<&Dataset>) -> Ensemble {
    let task = data.task;
    let k = task.n_outputs();
    let n = data.n_rows();
    assert!(n > 1, "empty training set");

    let quantizer = FeatureQuantizer::fit(data, params.n_bits);
    let m = BinnedMatrix {
        bins: quantizer.transform(data),
        n_rows: n,
        n_features: data.n_features,
        n_bins: quantizer.n_bins(),
    };
    let val_bins: Option<(Vec<u16>, usize)> =
        val.map(|v| (quantizer.transform(v), v.n_rows()));

    let base = base_scores(task, &data.y);
    let mut preds: Vec<f32> = Vec::with_capacity(n * k);
    for _ in 0..n {
        preds.extend_from_slice(&base);
    }
    let mut val_preds: Vec<f32> = val
        .map(|v| {
            let mut p = Vec::with_capacity(v.n_rows() * k);
            for _ in 0..v.n_rows() {
                p.extend_from_slice(&base);
            }
            p
        })
        .unwrap_or_default();

    let grow = params.grow_params();

    let mut rng = Rng::new(params.seed);
    let mut scratch = GrowScratch::new(m.n_features, m.n_bins);
    let mut trees: Vec<Tree> = Vec::new();
    let mut tree_class: Vec<u16> = Vec::new();
    let mut best_val = f64::INFINITY;
    let mut best_len = 0usize;
    let mut since_best = 0usize;

    // Per-output gradient views are strided; copy into dense buffers so the
    // grower indexes by plain row id.
    let mut gk = vec![0f32; n];
    let mut hk = vec![0f32; n];

    // Defect-aware training: a jittered view of the binned matrix is
    // re-drawn per round for split finding; prediction updates always use
    // the clean bins (the deployed chip quantizes exactly).
    let mut jittered: Option<BinnedMatrix> = None;

    'rounds: for _round in 0..params.n_rounds {
        if params.bin_jitter > 0.0 {
            let mut bins = m.bins.clone();
            let max_bin = (m.n_bins - 1) as u16;
            for b in bins.iter_mut() {
                if rng.chance(params.bin_jitter) {
                    *b = if rng.chance(0.5) { (*b).saturating_sub(1) } else { (*b + 1).min(max_bin) };
                }
            }
            jittered = Some(BinnedMatrix {
                bins,
                n_rows: m.n_rows,
                n_features: m.n_features,
                n_bins: m.n_bins,
            });
        }
        let grow_m = jittered.as_ref().unwrap_or(&m);
        let gh = grad_hess(task, &preds, &data.y);
        for class in 0..k {
            for i in 0..n {
                gk[i] = gh.g[i * k + class];
                hk[i] = gh.h[i * k + class];
            }
            let rows: Vec<u32> = if params.subsample < 1.0 {
                let take = ((n as f64 * params.subsample) as usize).max(2);
                rng.sample_indices(n, take).into_iter().map(|i| i as u32).collect()
            } else {
                (0..n as u32).collect()
            };
            let tree = grow_tree(grow_m, rows, &gk, &hk, &grow, &mut rng, &mut scratch);
            // Update train predictions for this class column.
            for i in 0..n {
                preds[i * k + class] += tree.predict_bins(m.row(i));
            }
            if let (Some((vb, vn)), true) = (&val_bins, val.is_some()) {
                for i in 0..*vn {
                    val_preds[i * k + class] +=
                        tree.predict_bins(&vb[i * data.n_features..(i + 1) * data.n_features]);
                }
            }
            trees.push(tree);
            tree_class.push(class as u16);
        }
        if params.early_stop_rounds > 0 {
            if let Some(v) = val {
                let l = loss(task, &val_preds, &v.y);
                if l < best_val - 1e-7 {
                    best_val = l;
                    best_len = trees.len();
                    since_best = 0;
                } else {
                    since_best += 1;
                    if since_best >= params.early_stop_rounds {
                        trees.truncate(best_len);
                        tree_class.truncate(best_len);
                        break 'rounds;
                    }
                }
            }
        }
    }

    Ensemble {
        name: data.name.clone(),
        task,
        n_features: data.n_features,
        trees,
        tree_class,
        base_score: base,
        quantizer,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::by_name;
    use crate::trees::metrics::score;

    fn small_params(rounds: usize) -> GbdtParams {
        GbdtParams {
            n_rounds: rounds,
            max_leaves: 16,
            max_depth: 4,
            learning_rate: 0.3,
            ..Default::default()
        }
    }

    #[test]
    fn learns_binary_task() {
        let d = by_name("churn").unwrap().generate_n(2000);
        let s = d.split(0.7, 0.0, 1);
        let model = train(&s.train, &small_params(30), None);
        let acc = score(&model, &s.test);
        // Teacher noise is ~6%; anything ≥ 0.8 proves real learning
        // (majority class is ~0.5).
        assert!(acc > 0.8, "accuracy {acc}");
    }

    #[test]
    fn learns_multiclass_task() {
        let d = by_name("eye").unwrap().generate_n(2500);
        let s = d.split(0.7, 0.0, 2);
        let model = train(&s.train, &small_params(25), None);
        let acc = score(&model, &s.test);
        assert!(acc > 0.55, "accuracy {acc} (chance ≈ 0.33)");
        // One tree per class per round.
        assert_eq!(model.n_trees(), 25 * 3);
        assert!(model.tree_class.iter().any(|&c| c == 2));
    }

    #[test]
    fn learns_regression_task() {
        let d = by_name("rossmann").unwrap().generate_n(2000);
        let s = d.split(0.7, 0.0, 3);
        let model = train(&s.train, &small_params(40), None);
        let r2 = score(&model, &s.test);
        assert!(r2 > 0.4, "R² {r2}");
    }

    #[test]
    fn respects_leaf_cap() {
        let d = by_name("churn").unwrap().generate_n(1500);
        let mut p = small_params(5);
        p.max_leaves = 8;
        let model = train(&d, &p, None);
        assert!(model.max_leaves() <= 8);
    }

    #[test]
    fn training_is_deterministic() {
        let d = by_name("telco").unwrap().generate_n(800);
        let a = train(&d, &small_params(5), None);
        let b = train(&d, &small_params(5), None);
        assert_eq!(a.trees.len(), b.trees.len());
        for (ta, tb) in a.trees.iter().zip(&b.trees) {
            assert_eq!(ta, tb);
        }
    }

    #[test]
    fn early_stopping_truncates() {
        let d = by_name("telco").unwrap().generate_n(1200);
        let s = d.split(0.6, 0.2, 4);
        let mut p = small_params(60);
        p.early_stop_rounds = 3;
        let model = train(&s.train, &p, Some(&s.val));
        assert!(model.n_trees() <= 60, "trees {}", model.n_trees());
    }

    #[test]
    fn defect_aware_training_still_learns() {
        let d = by_name("churn").unwrap().generate_n(1500);
        let s = d.split(0.7, 0.0, 8);
        let mut p = small_params(20);
        p.bin_jitter = 0.05;
        let robust = train(&s.train, &p, None);
        let acc = score(&robust, &s.test);
        assert!(acc > 0.78, "defect-aware accuracy {acc}");
        // And it actually changes the learned trees.
        let standard = train(&s.train, &small_params(20), None);
        assert!(robust.trees.iter().zip(&standard.trees).any(|(a, b)| a != b));
    }

    #[test]
    fn more_rounds_do_not_hurt_train_fit() {
        let d = by_name("churn").unwrap().generate_n(1000);
        let short = train(&d, &small_params(3), None);
        let long = train(&d, &small_params(20), None);
        assert!(score(&long, &d) >= score(&short, &d) - 0.02);
    }
}
