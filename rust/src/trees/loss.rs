//! Training losses: gradients/hessians for second-order boosting
//! (XGBoost's exact formulation) for squared error, logistic and softmax.

use crate::data::Task;

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// In-place softmax over a small logits slice.
pub fn softmax(logits: &mut [f32]) {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in logits.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    for v in logits.iter_mut() {
        *v /= sum;
    }
}

/// Per-sample gradient/hessian pairs, laid out `[n_samples × n_outputs]`.
pub struct GradHess {
    pub g: Vec<f32>,
    pub h: Vec<f32>,
    pub n_outputs: usize,
}

/// Compute gradients/hessians of the task loss at the current raw
/// predictions `preds` (`[n × n_outputs]`, logits) against labels `y`.
pub fn grad_hess(task: Task, preds: &[f32], y: &[f32]) -> GradHess {
    let k = task.n_outputs();
    let n = y.len();
    assert_eq!(preds.len(), n * k);
    let mut g = vec![0f32; n * k];
    let mut h = vec![0f32; n * k];
    match task {
        Task::Regression => {
            // L = 1/2 (pred - y)^2 → g = pred - y, h = 1.
            for i in 0..n {
                g[i] = preds[i] - y[i];
                h[i] = 1.0;
            }
        }
        Task::Binary => {
            // Logistic loss on logits: g = p - y, h = p (1 - p).
            for i in 0..n {
                let p = sigmoid(preds[i]);
                g[i] = p - y[i];
                h[i] = (p * (1.0 - p)).max(1e-6);
            }
        }
        Task::MultiClass(_) => {
            // Softmax cross-entropy: g_k = p_k - 1[y=k], h_k = p_k (1-p_k).
            let mut p = vec![0f32; k];
            for i in 0..n {
                p.copy_from_slice(&preds[i * k..(i + 1) * k]);
                softmax(&mut p);
                let label = y[i] as usize;
                for c in 0..k {
                    let target = (c == label) as u8 as f32;
                    g[i * k + c] = p[c] - target;
                    h[i * k + c] = (p[c] * (1.0 - p[c])).max(1e-6);
                }
            }
        }
    }
    GradHess { g, h, n_outputs: k }
}

/// Mean task loss at raw predictions (for early-stopping / reporting).
pub fn loss(task: Task, preds: &[f32], y: &[f32]) -> f64 {
    let k = task.n_outputs();
    let n = y.len();
    let mut total = 0f64;
    match task {
        Task::Regression => {
            for i in 0..n {
                let d = (preds[i] - y[i]) as f64;
                total += 0.5 * d * d;
            }
        }
        Task::Binary => {
            for i in 0..n {
                let p = sigmoid(preds[i]) as f64;
                let yy = y[i] as f64;
                total -= yy * p.max(1e-12).ln() + (1.0 - yy) * (1.0 - p).max(1e-12).ln();
            }
        }
        Task::MultiClass(_) => {
            let mut p = vec![0f32; k];
            for i in 0..n {
                p.copy_from_slice(&preds[i * k..(i + 1) * k]);
                softmax(&mut p);
                total -= (p[y[i] as usize] as f64).max(1e-12).ln();
            }
        }
    }
    total / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_bounds() {
        assert!(sigmoid(-100.0) >= 0.0 && sigmoid(-100.0) < 1e-6);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(100.0) <= 1.0 && sigmoid(100.0) > 1.0 - 1e-6);
    }

    #[test]
    fn softmax_normalizes() {
        let mut v = [1.0f32, 2.0, 3.0];
        softmax(&mut v);
        let sum: f32 = v.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(v[2] > v[1] && v[1] > v[0]);
    }

    #[test]
    fn regression_grad_is_residual() {
        let gh = grad_hess(Task::Regression, &[3.0, 1.0], &[1.0, 1.0]);
        assert_eq!(gh.g, vec![2.0, 0.0]);
        assert_eq!(gh.h, vec![1.0, 1.0]);
    }

    #[test]
    fn binary_grad_sign() {
        // Positive label with negative logit → negative gradient (move up).
        let gh = grad_hess(Task::Binary, &[-2.0], &[1.0]);
        assert!(gh.g[0] < 0.0);
        assert!(gh.h[0] > 0.0);
    }

    #[test]
    fn softmax_grads_sum_to_zero() {
        let gh = grad_hess(Task::MultiClass(3), &[0.3, -0.1, 0.5], &[2.0]);
        let s: f32 = gh.g.iter().sum();
        assert!(s.abs() < 1e-6);
        assert!(gh.g[2] < 0.0, "true-class gradient must be negative");
    }

    #[test]
    fn loss_decreases_toward_label() {
        let far = loss(Task::Binary, &[-3.0], &[1.0]);
        let near = loss(Task::Binary, &[3.0], &[1.0]);
        assert!(near < far);
    }

    #[test]
    fn numeric_gradient_check_binary() {
        // Finite-difference check of dL/dz at a few points.
        for &z in &[-1.5f32, 0.0, 0.7, 2.0] {
            let y = [1.0f32];
            let eps = 1e-3f32;
            let l_plus = loss(Task::Binary, &[z + eps], &y);
            let l_minus = loss(Task::Binary, &[z - eps], &y);
            let num = ((l_plus - l_minus) / (2.0 * eps as f64)) as f32;
            let gh = grad_hess(Task::Binary, &[z], &y);
            assert!((num - gh.g[0]).abs() < 1e-3, "z={z} num={num} ana={}", gh.g[0]);
        }
    }
}
