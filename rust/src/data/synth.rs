//! Synthetic tabular dataset generators standing in for the seven public
//! datasets of Table II (offline substitution — see DESIGN.md §2).
//!
//! Each generator plants a *teacher* forest of random axis-aligned trees and
//! labels samples from the teacher's (noisy) output, so that:
//!  * gradient-boosted / random-forest students can actually learn the task
//!    to a stable accuracy plateau (like real tabular data);
//!  * decision thresholds concentrate at informative feature values, so
//!    8-bit vs 4-bit quantization and defect injection show the same
//!    qualitative sensitivity the paper reports (Fig. 9);
//!  * dataset *dimensions* (samples, N_feat, N_classes, task) match
//!    Table II exactly.

use super::dataset::{Dataset, Task};
use crate::util::Rng;

/// A random axis-aligned teacher tree over `[0,1)^F` producing a score
/// vector of width `k` at each leaf.
struct TeacherTree {
    feat: Vec<usize>,
    thresh: Vec<f32>,
    /// Leaf scores, `[n_leaves × k]`.
    leaf: Vec<f32>,
    depth: usize,
    k: usize,
}

impl TeacherTree {
    fn random(rng: &mut Rng, n_feat: usize, n_informative: usize, depth: usize, k: usize) -> Self {
        let n_internal = (1 << depth) - 1;
        let n_leaves = 1 << depth;
        let feat = (0..n_internal).map(|_| rng.below(n_informative.min(n_feat))).collect();
        // Thresholds biased toward the middle so branches stay balanced and
        // populated (Beta(2,2)-ish via average of two uniforms).
        let thresh = (0..n_internal).map(|_| 0.5 * (rng.f32() + rng.f32())).collect();
        let leaf = (0..n_leaves * k).map(|_| rng.normal_f32()).collect();
        TeacherTree { feat, thresh, leaf, depth, k }
    }

    fn scores(&self, x: &[f32]) -> &[f32] {
        let mut node = 0usize;
        for _ in 0..self.depth {
            node = 2 * node + 1 + usize::from(x[self.feat[node]] >= self.thresh[node]);
        }
        let leaf_idx = node - ((1 << self.depth) - 1);
        &self.leaf[leaf_idx * self.k..(leaf_idx + 1) * self.k]
    }
}

/// Specification for one synthetic dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: &'static str,
    pub task: Task,
    /// Sample count reported by the paper (Table II).
    pub paper_samples: usize,
    /// Samples actually generated (capped for tractable offline training;
    /// model topology, which drives the architecture results, is unchanged).
    pub gen_samples: usize,
    pub n_features: usize,
    /// Features the teacher actually uses; the rest are uninformative noise
    /// (tree models' robustness to those is a paper motivation, §I).
    pub n_informative: usize,
    pub teacher_trees: usize,
    pub teacher_depth: usize,
    /// Label-noise / target-noise strength.
    pub noise: f32,
    pub seed: u64,
}

impl SynthSpec {
    /// Generate the dataset.
    pub fn generate(&self) -> Dataset {
        self.generate_n(self.gen_samples)
    }

    pub fn generate_n(&self, n: usize) -> Dataset {
        let k_out = match self.task {
            Task::Regression => 1,
            Task::Binary => 1,
            Task::MultiClass(k) => k,
        };
        let mut rng = Rng::new(self.seed);
        let teachers: Vec<TeacherTree> = (0..self.teacher_trees)
            .map(|t| {
                let mut tr = rng.fork(t as u64);
                TeacherTree::random(&mut tr, self.n_features, self.n_informative, self.teacher_depth, k_out)
            })
            .collect();

        // Per-feature marginal shapes: mix of uniform, bimodal and skewed
        // marginals so quantile binning is non-trivial (like real data).
        let marginal: Vec<u8> = (0..self.n_features).map(|_| (rng.below(3)) as u8).collect();

        let mut x = Vec::with_capacity(n * self.n_features);
        let mut y = Vec::with_capacity(n);
        let mut srng = rng.fork(0xDA7A);
        let scale = 1.0 / (self.teacher_trees as f32).sqrt();
        let mut scores = vec![0f32; k_out];
        for _ in 0..n {
            let base = x.len();
            for f in 0..self.n_features {
                let u = srng.f32();
                let v = match marginal[f] {
                    0 => u,
                    1 => {
                        // Bimodal: two humps at 0.25 / 0.75.
                        let c = if srng.chance(0.5) { 0.25 } else { 0.75 };
                        (c + 0.12 * srng.normal_f32()).clamp(0.0, 0.999_999)
                    }
                    _ => u * u, // right-skewed
                };
                x.push(v);
            }
            let row = &x[base..base + self.n_features];
            scores.iter_mut().for_each(|s| *s = 0.0);
            for t in &teachers {
                for (s, v) in scores.iter_mut().zip(t.scores(row)) {
                    *s += v * scale;
                }
            }
            let label = match self.task {
                Task::Regression => scores[0] + self.noise * srng.normal_f32(),
                Task::Binary => {
                    // Deterministic teacher decision + label-flip noise so
                    // the Bayes-optimal accuracy is ~(1 - noise), like the
                    // strong-signal tabular benchmarks the paper uses.
                    let cls = (scores[0] > 0.0) as usize;
                    let flip = srng.chance(self.noise as f64);
                    (if flip { 1 - cls } else { cls }) as f32
                }
                Task::MultiClass(k) => {
                    let mut best = 0usize;
                    for c in 1..k {
                        if scores[c] > scores[best] {
                            best = c;
                        }
                    }
                    if srng.chance(self.noise as f64) {
                        best = srng.below(k);
                    }
                    best as f32
                }
            };
            y.push(label);
        }
        Dataset::new(self.name, self.task, self.n_features, x, y)
    }
}

/// Table II catalog: dataset IDs 1-7 with the paper's dimensions.
/// `gen_samples` caps the two >500k-row datasets at 30k generated rows for
/// offline training tractability (documented substitution; architecture
/// benches depend on model topology, not on training-set size).
pub fn catalog() -> Vec<SynthSpec> {
    vec![
        SynthSpec {
            name: "churn",
            task: Task::Binary,
            paper_samples: 10_000,
            gen_samples: 10_000,
            n_features: 10,
            n_informative: 8,
            teacher_trees: 5,
            teacher_depth: 3,
            noise: 0.06,
            seed: 101,
        },
        SynthSpec {
            name: "eye",
            task: Task::MultiClass(3),
            paper_samples: 10_936,
            gen_samples: 10_936,
            n_features: 26,
            n_informative: 18,
            teacher_trees: 10,
            teacher_depth: 4,
            noise: 0.08,
            seed: 102,
        },
        SynthSpec {
            name: "covertype",
            task: Task::MultiClass(7),
            paper_samples: 581_012,
            gen_samples: 30_000,
            n_features: 54,
            n_informative: 30,
            teacher_trees: 14,
            teacher_depth: 5,
            noise: 0.05,
            seed: 103,
        },
        SynthSpec {
            name: "gas",
            task: Task::MultiClass(6),
            paper_samples: 13_910,
            gen_samples: 13_910,
            n_features: 129,
            n_informative: 48,
            teacher_trees: 12,
            teacher_depth: 4,
            noise: 0.04,
            seed: 104,
        },
        SynthSpec {
            name: "gesture",
            task: Task::MultiClass(5),
            paper_samples: 9_873,
            gen_samples: 9_873,
            n_features: 32,
            n_informative: 20,
            teacher_trees: 12,
            teacher_depth: 4,
            noise: 0.10,
            seed: 105,
        },
        SynthSpec {
            name: "telco",
            task: Task::Binary,
            paper_samples: 7_032,
            gen_samples: 7_032,
            n_features: 19,
            n_informative: 10,
            teacher_trees: 4,
            teacher_depth: 3,
            noise: 0.10,
            seed: 106,
        },
        SynthSpec {
            name: "rossmann",
            task: Task::Regression,
            paper_samples: 610_253,
            gen_samples: 30_000,
            n_features: 29,
            n_informative: 16,
            teacher_trees: 10,
            teacher_depth: 4,
            noise: 0.15,
            seed: 107,
        },
    ]
}

/// Look up a catalog entry by name.
pub fn by_name(name: &str) -> Option<SynthSpec> {
    catalog().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_table2_dims() {
        let c = catalog();
        assert_eq!(c.len(), 7);
        let gas = by_name("gas").unwrap();
        assert_eq!(gas.n_features, 129);
        assert_eq!(gas.task, Task::MultiClass(6));
        let covertype = by_name("covertype").unwrap();
        assert_eq!(covertype.paper_samples, 581_012);
        assert_eq!(covertype.task.n_classes(), 7);
        let rossmann = by_name("rossmann").unwrap();
        assert_eq!(rossmann.task, Task::Regression);
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = by_name("telco").unwrap();
        let a = spec.generate_n(500);
        let b = spec.generate_n(500);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn features_in_unit_interval() {
        let d = by_name("churn").unwrap().generate_n(2000);
        assert!(d.x.iter().all(|&v| (0.0..1.0).contains(&v)));
    }

    #[test]
    fn all_classes_present() {
        for spec in catalog() {
            if !spec.task.is_classification() {
                continue;
            }
            let d = spec.generate_n(3000);
            let h = d.class_histogram();
            assert!(
                h.iter().all(|&c| c > 0),
                "{}: empty class in histogram {:?}",
                spec.name,
                h
            );
        }
    }

    #[test]
    fn binary_labels_are_binary() {
        let d = by_name("churn").unwrap().generate_n(1000);
        assert!(d.y.iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn regression_targets_vary() {
        let d = by_name("rossmann").unwrap().generate_n(1000);
        let mean = d.y.iter().sum::<f32>() / d.y.len() as f32;
        let var = d.y.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d.y.len() as f32;
        assert!(var > 0.01, "var={var}");
    }

    #[test]
    fn teacher_signal_beats_chance() {
        // A 1-NN-style sanity check is heavy; instead verify the planted
        // teacher itself classifies its own labels far above chance on a
        // regenerated sample (i.e. labels are not pure noise).
        let spec = by_name("eye").unwrap();
        let d = spec.generate_n(4000);
        // Majority class frequency must be < 0.9 (not degenerate) and the
        // per-class histogram non-uniformity must be bounded.
        let h = d.class_histogram();
        let maxc = *h.iter().max().unwrap() as f64 / d.n_rows() as f64;
        assert!(maxc < 0.9, "degenerate labels: {h:?}");
    }
}
