//! Tabular data substrate: dataset container, Table II synthetic dataset
//! generators, splits and feature quantization.

pub mod dataset;
pub mod quantize;
pub mod synth;

pub use dataset::{Dataset, Split, Task};
pub use quantize::FeatureQuantizer;
pub use synth::{by_name, catalog, SynthSpec};
