//! Tabular dataset container and train/validation/test splitting.

use crate::util::Rng;

/// Learning task, mirroring the paper's three categories (§III-D).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Regression,
    Binary,
    /// Multi-class with `k` classes.
    MultiClass(usize),
}

impl Task {
    /// Number of logit columns an ensemble produces for this task.
    pub fn n_outputs(&self) -> usize {
        match self {
            Task::Regression | Task::Binary => 1,
            Task::MultiClass(k) => *k,
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            Task::Regression => 0,
            Task::Binary => 2,
            Task::MultiClass(k) => *k,
        }
    }

    pub fn is_classification(&self) -> bool {
        !matches!(self, Task::Regression)
    }

    pub fn name(&self) -> String {
        match self {
            Task::Regression => "regression".into(),
            Task::Binary => "binary".into(),
            Task::MultiClass(k) => format!("multiclass({k})"),
        }
    }

    /// Inverse of [`Task::name`], shared by every persisted-struct
    /// decoder (program, shard plan, artifact manifest). `n_classes` is
    /// consulted only for the multi-class arm (the encoders write it
    /// alongside the name).
    pub fn from_name(name: &str, n_classes: usize) -> Result<Task, String> {
        match name {
            "regression" => Ok(Task::Regression),
            "binary" => Ok(Task::Binary),
            s if s.starts_with("multiclass") => {
                if n_classes < 2 {
                    return Err(format!("multiclass task needs n_classes >= 2, got {n_classes}"));
                }
                Ok(Task::MultiClass(n_classes))
            }
            s => Err(format!("unknown task `{s}`")),
        }
    }

    /// Co-processor decision rule (§III-A): identity for regression,
    /// threshold at 0 for binary logits, argmax for multi-class.
    pub fn decide(&self, logits: &[f32]) -> f32 {
        match self {
            Task::Regression => logits[0],
            Task::Binary => (logits[0] > 0.0) as usize as f32,
            Task::MultiClass(_) => {
                let mut best = 0usize;
                for c in 1..logits.len() {
                    if logits[c] > logits[best] {
                        best = c;
                    }
                }
                best as f32
            }
        }
    }

    /// Distance of `logits` from the decision boundary of
    /// [`Task::decide`]: |logit| (the log-odds magnitude) for binary,
    /// top-1 minus top-2 logit for multi-class, and +∞ for regression
    /// (a point prediction has no boundary to be near). Empty logits —
    /// an errored reply — are on the boundary (margin 0).
    ///
    /// Feeds [`crate::cam::analog::soft_confidence`] so the serving
    /// layer can attach a per-row confidence to every reply.
    pub fn decision_margin(&self, logits: &[f32]) -> f32 {
        match self {
            Task::Regression => f32::INFINITY,
            Task::Binary => logits.first().map_or(0.0, |l| l.abs()),
            Task::MultiClass(_) => {
                if logits.len() < 2 {
                    return 0.0;
                }
                let (mut top1, mut top2) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
                for &l in logits {
                    if l > top1 {
                        top2 = top1;
                        top1 = l;
                    } else if l > top2 {
                        top2 = l;
                    }
                }
                top1 - top2
            }
        }
    }
}

/// Row-major dense tabular dataset. Labels are class indices for
/// classification (stored as f32) or targets for regression.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub task: Task,
    pub n_features: usize,
    /// Row-major `[n_rows × n_features]`.
    pub x: Vec<f32>,
    pub y: Vec<f32>,
}

impl Dataset {
    pub fn new(name: &str, task: Task, n_features: usize, x: Vec<f32>, y: Vec<f32>) -> Dataset {
        assert_eq!(x.len(), y.len() * n_features, "x/y shape mismatch");
        Dataset { name: name.to_string(), task, n_features, x, y }
    }

    pub fn n_rows(&self) -> usize {
        self.y.len()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.n_features..(i + 1) * self.n_features]
    }

    pub fn label(&self, i: usize) -> f32 {
        self.y[i]
    }

    pub fn class(&self, i: usize) -> usize {
        debug_assert!(self.task.is_classification());
        self.y[i] as usize
    }

    /// Subset by row indices (copies).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.n_features);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset { name: self.name.clone(), task: self.task, n_features: self.n_features, x, y }
    }

    /// Deterministic shuffled split into train/val/test by fractions.
    pub fn split(&self, frac_train: f64, frac_val: f64, seed: u64) -> Split {
        assert!(frac_train + frac_val < 1.0 + 1e-9);
        let mut idx: Vec<usize> = (0..self.n_rows()).collect();
        let mut rng = Rng::new(seed ^ 0x5EED_5417);
        rng.shuffle(&mut idx);
        let n_train = (self.n_rows() as f64 * frac_train) as usize;
        let n_val = (self.n_rows() as f64 * frac_val) as usize;
        Split {
            train: self.subset(&idx[..n_train]),
            val: self.subset(&idx[n_train..n_train + n_val]),
            test: self.subset(&idx[n_train + n_val..]),
        }
    }

    /// Per-class sample counts (classification only).
    pub fn class_histogram(&self) -> Vec<usize> {
        let k = self.task.n_classes();
        let mut h = vec![0usize; k];
        for i in 0..self.n_rows() {
            h[self.class(i)] += 1;
        }
        h
    }
}

/// Train/validation/test partition.
pub struct Split {
    pub train: Dataset,
    pub val: Dataset,
    pub test: Dataset,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let n = 100;
        let x: Vec<f32> = (0..n * 3).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        Dataset::new("toy", Task::Binary, 3, x, y)
    }

    #[test]
    fn row_access() {
        let d = toy();
        assert_eq!(d.n_rows(), 100);
        assert_eq!(d.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn split_partitions_everything() {
        let d = toy();
        let s = d.split(0.6, 0.2, 7);
        assert_eq!(s.train.n_rows() + s.val.n_rows() + s.test.n_rows(), 100);
        assert_eq!(s.train.n_rows(), 60);
        assert_eq!(s.val.n_rows(), 20);
    }

    #[test]
    fn split_deterministic() {
        let d = toy();
        let a = d.split(0.5, 0.25, 42);
        let b = d.split(0.5, 0.25, 42);
        assert_eq!(a.train.y, b.train.y);
        assert_eq!(a.test.x, b.test.x);
    }

    #[test]
    fn class_histogram_sums() {
        let d = toy();
        let h = d.class_histogram();
        assert_eq!(h, vec![50, 50]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        Dataset::new("bad", Task::Binary, 3, vec![0.0; 7], vec![0.0; 2]);
    }

    #[test]
    fn decision_margins() {
        assert_eq!(Task::Regression.decision_margin(&[3.2]), f32::INFINITY);
        assert_eq!(Task::Binary.decision_margin(&[-1.5]), 1.5);
        assert_eq!(Task::Binary.decision_margin(&[]), 0.0);
        let m = Task::MultiClass(3).decision_margin(&[0.1, 2.0, 1.25]);
        assert!((m - 0.75).abs() < 1e-6);
        // Tied top-2 → on the boundary.
        assert_eq!(Task::MultiClass(2).decision_margin(&[1.0, 1.0]), 0.0);
    }
}
