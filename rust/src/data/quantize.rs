//! Per-feature quantization to `2^N_bit` bins (paper §III-B, §V-A).
//!
//! The X-TIME chip stores thresholds as analog levels with effective 8-bit
//! (macro-cell) or 4-bit (single-cell) precision. The compiler quantizes
//! each feature to bin indices using quantile-based bin edges computed on
//! the training set — the same strategy XGBoost's `hist` method and the
//! paper's "256 bins per feature" description imply.

use super::dataset::Dataset;
use crate::util::Json;

/// Per-feature quantile bin edges mapping f32 features → small integer bins.
#[derive(Clone, Debug)]
pub struct FeatureQuantizer {
    pub n_bits: u8,
    /// `edges[f]` has `n_bins - 1` interior cut points for feature `f`.
    pub edges: Vec<Vec<f32>>,
}

impl FeatureQuantizer {
    /// Grid *capacity*: `2^n_bits` bins. Low-cardinality features use
    /// fewer — see [`FeatureQuantizer::n_bins_used`].
    pub fn n_bins(&self) -> usize {
        1usize << self.n_bits
    }

    /// Bins a feature actually resolves: distinct cut count + 1. For a
    /// constant feature this is 1, for a binary feature 2 — the honest
    /// resolution, as opposed to the `n_bins()` capacity.
    pub fn n_bins_used(&self, feature: usize) -> usize {
        self.edges[feature].len() + 1
    }

    /// Largest per-feature [`FeatureQuantizer::n_bins_used`].
    pub fn max_bins_used(&self) -> usize {
        self.edges.iter().map(|e| e.len() + 1).max().unwrap_or(1)
    }

    /// Fit quantile edges on a dataset.
    pub fn fit(data: &Dataset, n_bits: u8) -> FeatureQuantizer {
        assert!((1..=16).contains(&n_bits));
        let n_bins = 1usize << n_bits;
        let mut edges = Vec::with_capacity(data.n_features);
        let n = data.n_rows();
        for f in 0..data.n_features {
            let mut col: Vec<f32> = (0..n).map(|i| data.row(i)[f]).collect();
            // NaN carries no ordering information and must not poison the
            // edges (the `partial_cmp(..).unwrap()` below panicked on the
            // first NaN); missing values are routed at query time instead
            // — see [`FeatureQuantizer::bin`].
            col.retain(|v| !v.is_nan());
            col.sort_by(|a, b| a.partial_cmp(b).unwrap());
            col.dedup();
            let mut cuts = Vec::with_capacity(n_bins - 1);
            // An f32 midpoint of two near-adjacent values can round onto
            // the *lower* value, producing a cut that fails to separate
            // the pair (and, chained, duplicate cuts that silently
            // collapse bins: `n_bins()` then overstates the usable
            // resolution and `bin_center` maps distinct bins to the same
            // center). Every cut is therefore forced into the half-open
            // separating interval `(lo, hi]` and kept strictly increasing.
            let separating_cut = |lo: f32, hi: f32| {
                let mid = 0.5 * (lo + hi);
                if mid > lo {
                    mid
                } else {
                    hi
                }
            };
            if col.len() <= n_bins {
                // Few unique values: cut between consecutive uniques.
                for w in col.windows(2) {
                    let cut = separating_cut(w[0], w[1]);
                    if cuts.last().map(|&c| cut > c).unwrap_or(true) {
                        cuts.push(cut);
                    }
                }
            } else {
                for b in 1..n_bins {
                    let idx = (b * (col.len() - 1)) / n_bins;
                    let cut = separating_cut(col[idx], col[idx + 1]);
                    if cuts.last().map(|&c| cut > c).unwrap_or(true) {
                        cuts.push(cut);
                    }
                }
            }
            debug_assert!(cuts.windows(2).all(|w| w[0] < w[1]), "cuts must strictly increase");
            edges.push(cuts);
        }
        FeatureQuantizer { n_bits, edges }
    }

    /// Derive the deployment grid for a coarser bit width: a
    /// quantile-spaced *subset* of this quantizer's cut points. Because
    /// every coarse cut is exactly one of the fine cuts, a threshold that
    /// lies on the coarse grid is representable in both — the shared-grid
    /// contract that hardware-aware training (`trees::hat`) and the
    /// compiler's deployment snapping (`compiler::requantize`) rely on.
    /// Coarsening to `self.n_bits` is the identity.
    pub fn coarsen(&self, n_bits: u8) -> FeatureQuantizer {
        assert!(
            (1..=self.n_bits).contains(&n_bits),
            "coarsen target {n_bits} bits must not exceed the source {} bits",
            self.n_bits
        );
        let nb = 1usize << n_bits;
        let edges: Vec<Vec<f32>> = self
            .edges
            .iter()
            .map(|cuts| {
                if cuts.len() < nb {
                    // Already at or below the coarse resolution.
                    cuts.clone()
                } else {
                    let mut picked = Vec::with_capacity(nb - 1);
                    for b in 1..nb {
                        let c = cuts[b * cuts.len() / nb];
                        if picked.last().map(|&p| c > p).unwrap_or(true) {
                            picked.push(c);
                        }
                    }
                    picked
                }
            })
            .collect();
        FeatureQuantizer { n_bits, edges }
    }

    /// Bin index of a raw feature value (binary search over edges).
    /// NaN routes to bin 0 — the XGBoost-hist missing-value convention
    /// (a default direction rather than an arbitrary comparison result).
    #[inline]
    pub fn bin(&self, feature: usize, value: f32) -> u16 {
        if value.is_nan() {
            return 0;
        }
        let cuts = &self.edges[feature];
        // partition_point: number of cuts <= value.
        cuts.partition_point(|&c| c <= value) as u16
    }

    /// Quantize a full row into bin indices.
    pub fn bin_row(&self, row: &[f32]) -> Vec<u16> {
        row.iter().enumerate().map(|(f, &v)| self.bin(f, v)).collect()
    }

    /// Quantize a threshold into the bin whose *lower edge* is the smallest
    /// representable value ≥ comparisons against `thresh` (used when
    /// compiling trained float thresholds into CAM bounds).
    #[inline]
    pub fn bin_threshold(&self, feature: usize, thresh: f32) -> u16 {
        // A sample `v` goes right iff v >= thresh iff bin(v) >= bin_threshold.
        let cuts = &self.edges[feature];
        cuts.partition_point(|&c| c < thresh) as u16
    }

    /// Representative (midpoint) value of a bin, for de-quantization.
    pub fn bin_center(&self, feature: usize, bin: u16) -> f32 {
        let cuts = &self.edges[feature];
        if cuts.is_empty() {
            return 0.5;
        }
        let b = bin as usize;
        if b == 0 {
            cuts[0] - 0.5 * (cuts.get(1).copied().unwrap_or(cuts[0] + 1.0) - cuts[0]).abs()
        } else if b >= cuts.len() {
            let last = *cuts.last().unwrap();
            let prev = cuts[cuts.len().saturating_sub(2)];
            last + 0.5 * (last - prev).abs()
        } else {
            0.5 * (cuts[b - 1] + cuts[b])
        }
    }

    // ---- serialization ---------------------------------------------------

    /// Canonical encoding: cut points use [`Json::canon_f32`], so
    /// encode→decode→encode is byte-identical — the digest-stability
    /// contract of the artifact store (`crate::artifact`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("n_bits", Json::Num(self.n_bits as f64)).set(
            "edges",
            Json::Arr(self.edges.iter().map(|e| Json::from_canon_f32_slice(e)).collect()),
        );
        o
    }

    /// Bit-exact inverse of [`FeatureQuantizer::to_json`].
    pub fn from_json(j: &Json) -> Result<FeatureQuantizer, String> {
        let n_bits = j.req_usize("n_bits")?;
        if !(1..=16).contains(&n_bits) {
            return Err(format!("quantizer n_bits {n_bits} outside 1..=16"));
        }
        let edges = j
            .req_arr("edges")?
            .iter()
            .map(Json::canon_f32_vec)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(FeatureQuantizer { n_bits: n_bits as u8, edges })
    }

    /// Quantize an entire dataset into a row-major u16 bin matrix.
    pub fn transform(&self, data: &Dataset) -> Vec<u16> {
        let mut out = Vec::with_capacity(data.n_rows() * data.n_features);
        for i in 0..data.n_rows() {
            for (f, &v) in data.row(i).iter().enumerate() {
                out.push(self.bin(f, v));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Task;
    use crate::data::synth::by_name;
    use crate::util::prop;

    fn fitted(bits: u8) -> (Dataset, FeatureQuantizer) {
        let d = by_name("churn").unwrap().generate_n(4000);
        let q = FeatureQuantizer::fit(&d, bits);
        (d, q)
    }

    #[test]
    fn bins_within_range() {
        let (d, q) = fitted(8);
        for i in 0..d.n_rows() {
            for (f, &v) in d.row(i).iter().enumerate() {
                assert!((q.bin(f, v) as usize) < q.n_bins());
            }
        }
    }

    #[test]
    fn bins_are_monotone_in_value() {
        let (_, q) = fitted(8);
        prop::check(512, 0xB125, |g| {
            let f = g.usize_in(0, q.edges.len());
            let a = g.f32_in(0.0, 1.0);
            let b = g.f32_in(0.0, 1.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            prop::require(q.bin(f, lo) <= q.bin(f, hi), format!("f={f} lo={lo} hi={hi}"))
        });
    }

    #[test]
    fn threshold_consistency() {
        // v >= t  ⟺  bin(v) >= bin_threshold(t) must hold whenever v and t
        // do not fall inside the same bin (quantization can't distinguish
        // values within a bin — that is the 8-bit accuracy loss of Fig. 9a).
        let (_, q) = fitted(8);
        prop::check(2048, 0x7123, |g| {
            let f = g.usize_in(0, q.edges.len());
            let v = g.f32_in(0.0, 1.0);
            let t = g.f32_in(0.0, 1.0);
            let vb = q.bin(f, v);
            let tb = q.bin_threshold(f, t);
            let exact = v >= t;
            let quant = vb >= tb;
            if vb == q.bin(f, t) {
                // v and t share a bin: quantization legitimately can't
                // distinguish them (that's the Fig. 9a precision loss).
                return Ok(());
            }
            prop::require(exact == quant, format!("f={f} v={v} t={t} vb={vb} tb={tb}"))
        });
    }

    #[test]
    fn few_unique_values_get_exact_cuts() {
        // A binary feature must quantize losslessly even at 2 bits.
        let x: Vec<f32> = (0..100).flat_map(|i| vec![(i % 2) as f32]).collect();
        let y: Vec<f32> = (0..100).map(|i| (i % 2) as f32).collect();
        let d = Dataset::new("bin", Task::Binary, 1, x, y);
        let q = FeatureQuantizer::fit(&d, 2);
        assert_ne!(q.bin(0, 0.0), q.bin(0, 1.0));
    }

    #[test]
    fn fit_survives_nan_features() {
        // Regression: a single NaN in a training column used to panic
        // `fit` via `partial_cmp(..).unwrap()`. NaNs must be dropped
        // before sorting and the resulting edges stay finite.
        let n = 200;
        let x: Vec<f32> = (0..n)
            .flat_map(|i| {
                let a = if i % 7 == 0 { f32::NAN } else { i as f32 / n as f32 };
                let b = (i % 13) as f32;
                vec![a, b]
            })
            .collect();
        let y: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        let d = Dataset::new("nan", Task::Binary, 2, x, y);
        let q = FeatureQuantizer::fit(&d, 4);
        assert!(q.edges.iter().flatten().all(|c| c.is_finite()), "NaN leaked into edges");
        // The non-NaN values of the poisoned column still quantize
        // monotonically.
        assert!(q.bin(0, 0.1) <= q.bin(0, 0.9));
    }

    #[test]
    fn all_nan_column_fits_with_no_cuts() {
        let n = 50;
        let x: Vec<f32> = (0..n).flat_map(|i| vec![f32::NAN, i as f32]).collect();
        let y: Vec<f32> = vec![0.0; n];
        let d = Dataset::new("allnan", Task::Binary, 2, x, y);
        let q = FeatureQuantizer::fit(&d, 4);
        assert!(q.edges[0].is_empty(), "an all-NaN column has no information to cut on");
        assert_eq!(q.bin(0, 0.5), 0);
    }

    #[test]
    fn nan_routes_to_bin_zero_at_query_time() {
        let (_, q) = fitted(8);
        for f in 0..q.edges.len() {
            assert_eq!(q.bin(f, f32::NAN), 0, "feature {f}");
        }
        // Through the row path too (serving uses `bin_row`).
        let n_features = q.edges.len();
        let mut row = vec![0.7f32; n_features];
        row[0] = f32::NAN;
        let bins = q.bin_row(&row);
        assert_eq!(bins[0], 0);
        assert!(bins[1..].iter().all(|&b| (b as usize) < q.n_bins()));
    }

    #[test]
    fn constant_feature_reports_one_usable_bin() {
        // Regression (ISSUE 3 satellite): a constant feature has nothing
        // to cut on; the reported usable resolution must say so instead
        // of pretending to 2^n_bits bins.
        let n = 120;
        let x: Vec<f32> = (0..n).flat_map(|i| vec![3.25f32, i as f32]).collect();
        let y: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        let d = Dataset::new("const", Task::Binary, 2, x, y);
        let q = FeatureQuantizer::fit(&d, 4);
        assert!(q.edges[0].is_empty(), "constant feature grew cuts: {:?}", q.edges[0]);
        assert_eq!(q.n_bins_used(0), 1);
        assert_eq!(q.bin(0, 3.25), 0);
        assert_eq!(q.bin(0, -100.0), 0);
        assert!(q.n_bins_used(1) > 1);
        assert_eq!(q.max_bins_used(), q.n_bins_used(1));
    }

    #[test]
    fn two_valued_feature_reports_two_usable_bins() {
        let n = 100;
        let x: Vec<f32> = (0..n).map(|i| (i % 2) as f32 * 7.0).collect();
        let y: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        let d = Dataset::new("twoval", Task::Binary, 1, x, y);
        let q = FeatureQuantizer::fit(&d, 8);
        assert_eq!(q.edges[0].len(), 1, "two values need exactly one cut");
        assert_eq!(q.n_bins_used(0), 2);
        assert_ne!(q.bin(0, 0.0), q.bin(0, 7.0));
        // Distinct usable bins must have distinct centers.
        assert_ne!(q.bin_center(0, 0), q.bin_center(0, 1));
    }

    #[test]
    fn adjacent_float_values_do_not_collapse_cuts() {
        // Regression: midpoints of consecutive f32 values at large
        // magnitude round back onto the lower value (ulp(2^23) = 1, so
        // 0.5·(8388608 + 8388609) rounds to 8388608.0). The old fit
        // emitted that collapsed cut, silently merging two bins.
        let vals = [8388608.0f32, 8388609.0, 8388610.0, 8388611.0];
        let x: Vec<f32> = (0..200).map(|i| vals[i % vals.len()]).collect();
        let y: Vec<f32> = (0..200).map(|i| (i % 2) as f32).collect();
        let d = Dataset::new("ulp", Task::Binary, 1, x, y);
        let q = FeatureQuantizer::fit(&d, 4);
        assert!(
            q.edges[0].windows(2).all(|w| w[0] < w[1]),
            "duplicate cuts survived: {:?}",
            q.edges[0]
        );
        assert_eq!(q.n_bins_used(0), vals.len(), "cuts: {:?}", q.edges[0]);
        // Every distinct value lands in its own bin.
        let bins: Vec<u16> = vals.iter().map(|&v| q.bin(0, v)).collect();
        let mut uniq = bins.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), vals.len(), "bins collapsed: {bins:?}");
    }

    #[test]
    fn coarsen_cuts_are_a_subset_of_fine_cuts() {
        let (_, q) = fitted(8);
        let c = q.coarsen(4);
        assert_eq!(c.n_bits, 4);
        for f in 0..q.edges.len() {
            assert!(c.edges[f].len() < c.n_bins());
            assert!(
                c.edges[f].iter().all(|cut| q.edges[f].contains(cut)),
                "feature {f}: coarse cut not on the fine grid"
            );
            assert!(c.edges[f].windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn coarsen_to_same_bits_is_identity() {
        let (_, q) = fitted(6);
        let c = q.coarsen(6);
        assert_eq!(c.edges, q.edges);
        assert_eq!(c.n_bits, q.n_bits);
    }

    #[test]
    fn coarsen_preserves_bin_monotonicity() {
        let (_, q) = fitted(8);
        let c = q.coarsen(3);
        prop::check(512, 0xC0A5, |g| {
            let f = g.usize_in(0, c.edges.len());
            let a = g.f32_in(0.0, 1.0);
            let b = g.f32_in(0.0, 1.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            prop::require(c.bin(f, lo) <= c.bin(f, hi), format!("f={f} lo={lo} hi={hi}"))
        });
    }

    #[test]
    fn transform_shape() {
        let (d, q) = fitted(4);
        let m = q.transform(&d);
        assert_eq!(m.len(), d.n_rows() * d.n_features);
        assert!(m.iter().all(|&b| (b as usize) < q.n_bins()));
    }

    #[test]
    fn json_codec_is_bit_exact_and_canonical() {
        let (_, q) = fitted(8);
        let text = q.to_json().to_string();
        let back = FeatureQuantizer::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.n_bits, q.n_bits);
        assert_eq!(back.edges.len(), q.edges.len());
        for (f, (a, b)) in q.edges.iter().zip(&back.edges).enumerate() {
            assert_eq!(a.len(), b.len(), "feature {f}");
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "feature {f}");
            }
        }
        // Canonical: re-encoding the decoded value emits identical bytes.
        assert_eq!(back.to_json().to_string(), text);
        // Degenerate inputs are structured errors, not panics.
        assert!(FeatureQuantizer::from_json(
            &Json::parse(r#"{"n_bits":0,"edges":[]}"#).unwrap()
        )
        .is_err());
        assert!(FeatureQuantizer::from_json(&Json::parse(r#"{"edges":[]}"#).unwrap()).is_err());
    }

    #[test]
    fn bin_center_roundtrip() {
        let (_, q) = fitted(8);
        for f in 0..q.edges.len() {
            for b in [0u16, 5, 100, 255] {
                let c = q.bin_center(f, b);
                let back = q.bin(f, c);
                // Midpoint of a bin must land back in that bin (clamped at
                // the extremes where the bin is a half-open ray).
                let b_clamped = (b as usize).min(q.edges[f].len()) as u16;
                assert_eq!(back, b_clamped, "f={f} b={b} c={c}");
            }
        }
    }
}
