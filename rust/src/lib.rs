//! # X-TIME — an in-memory engine for tree-based ML on tabular data
//!
//! Reproduction of Pedretti et al., *"X-TIME: An in-memory engine for
//! accelerating machine learning on tabular data with CAMs"* (2023).
//!
//! The crate implements the complete stack described in DESIGN.md:
//!
//! * [`data`] — tabular dataset substrate + Table II synthetic generators;
//! * [`trees`] — from-scratch GBDT (XGBoost-style) and random-forest
//!   trainers with exact CPU inference (the software baseline);
//! * [`compiler`] — the X-TIME compiler: trained ensembles → quantized CAM
//!   threshold maps, core placement and NoC router configuration, plus the
//!   shard partitioner that splits a compiled program across cards;
//! * [`cam`] — functional analog-CAM model, including the paper's novel
//!   two-cycle 8-bit-on-4-bit macro-cell (Eq. 3) and defect injection;
//! * [`sim`] — SST-equivalent cycle-detailed simulator of the 4096-core
//!   H-tree chip, plus the area/power/energy cost model (Fig. 8);
//! * [`baselines`] — analytical V100/FIL GPU model and the Booster ASIC
//!   model used as comparison points in Fig. 10/11;
//! * [`analysis`] — deploy-time static verifier: rule-based lints (V1–V6)
//!   over compiled programs, plans and shard splits, surfaced through
//!   `xtime verify` and the fleet registration gate (contract 8);
//! * [`artifact`] — content-addressed model artifact store: canonical
//!   serialization of compiled programs/shard plans, SHA-256 blob store
//!   with ref-counted GC, and digest-verified hot loading into the
//!   fleet (contract 9);
//! * [`runtime`] — PJRT (XLA) runtime loading AOT-compiled HLO artifacts
//!   produced by the JAX/Pallas build pipeline under `python/`;
//! * [`coordinator`] — the serving engine: request router, dynamic batcher,
//!   sharded multi-card worker pool and pluggable inference backends;
//! * [`serve`] — the framed-TCP wire front end over the fleet, its
//!   client, and the open-loop multi-tenant load generator;
//! * [`util`] — offline substrates (PRNG, JSON, CLI, stats, prop tests).

pub mod analysis;
pub mod artifact;
pub mod baselines;
pub mod bench_support;
pub mod cam;
pub mod compiler;
pub mod coordinator;
pub mod data;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod trees;
pub mod util;
