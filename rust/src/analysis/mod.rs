//! Deploy-time static verifier for compiled CAM programs.
//!
//! The whole X-TIME chain rests on compiled artifacts being
//! structurally sound: the CAM mapping only works if every root-to-leaf
//! path is one row of valid `[lo, hi)` windows, and the planned
//! execution path (ADR-002) additionally trusts that each core's
//! LUT/arena faithfully tabulate the elementary-interval structure of
//! its programmed cells. This module lints all of that **without
//! executing a query** — a corrupt plan, a shard split that drops a
//! tree, or a never-match row becomes a pre-deploy diagnostic instead
//! of silently wrong logits under live traffic.
//!
//! Six rules, each with a stable [`RuleId`], a [`Severity`], and a
//! precise [`Location`] (core/feature/interval/row/tree/shard):
//!
//! | rule | checks | severity |
//! |---|---|---|
//! | V1 | per-feature elementary intervals partition the DAC space; every 256-entry LUT equals the tabulated `partition_point` | deny |
//! | V2 | arena offsets/lengths in-bounds, row-bitset width matches the core, padding bits zero | deny |
//! | V3 | shard plans partition the tree set exactly; per-shard leaf rows reconcile with the unsharded program | deny |
//! | V4 | quantizer cuts strictly increasing; every compiled threshold on the deploy grid (static face of contract 5) | deny |
//! | V5 | dead-leaf lint: unsatisfiable rows (never-match / inverted after defect injection) | warn |
//! | V6 | sparsity census: wildcard density per core/feature, shared-prefix counts | info |
//!
//! The verifier surfaces three ways: the `xtime verify` CLI subcommand
//! (human table + `--json`), the fleet registration gate
//! ([`crate::coordinator::Fleet::register_program`] refuses programs
//! per the route's [`VerifyPolicy`] — DESIGN.md §5 contract 8), and the
//! mutation suite in `rust/tests/analysis.rs` proving each rule fires
//! on a deliberate corruption.

pub mod report;
pub mod verify;

pub use report::{
    AnalysisReport, CoreCensus, Finding, Location, RuleId, Severity, SparsityCensus, VerifyPolicy,
};
pub use verify::{
    verify, verify_deployment, verify_engine, verify_program, verify_shard_plan,
    verify_with_defects,
};
