//! Data model of the static verifier: rule identities, severities,
//! locations, findings and the machine-readable [`AnalysisReport`]
//! (DESIGN.md §7). The report is what every surface shares — the
//! `xtime verify` CLI renders it, the fleet's contract-8 registration
//! gate filters it through a [`VerifyPolicy`], and CI archives its JSON.

use crate::util::Json;
use std::fmt;

/// Stable identity of one verifier rule. Codes (`V1`–`V6`) are part of
/// the report schema: tests, CI artifact consumers and fleet refusal
/// diagnostics all match on them, so variants may be added but existing
/// codes never renumbered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleId {
    /// Per-feature elementary intervals exactly partition DAC space and
    /// every LUT entry equals the tabulated `partition_point`.
    V1IntervalPartition,
    /// Bitset-arena offsets/lengths in-bounds, row-bitset width matches
    /// the core's row count, padding bits zero.
    V2ArenaBounds,
    /// Shard plans partition the tree set exactly; per-shard row sums
    /// reconcile with the unsharded program.
    V3ShardPartition,
    /// Quantizer cuts strictly increasing; every compiled threshold lies
    /// on the deploy grid (the static face of contract 5).
    V4QuantizerGrid,
    /// Dead-leaf lint: rows whose interval conjunction is unsatisfiable
    /// (never-match after defect injection) are flagged.
    V5DeadLeaf,
    /// Sparsity census: wildcard density and shared-prefix counts.
    V6SparsityCensus,
    /// Compressed-row match-set equivalence (contract 11): every
    /// physical layout unit covers its logical rows exactly — merged
    /// pairs are adjacent complementary siblings, packed units own
    /// pairwise-disjoint constrained features, and word-image union
    /// bounds reproduce the owners' windows.
    V7CompressedEquivalence,
}

impl RuleId {
    /// Short stable code used in reports and refusal diagnostics.
    pub fn code(self) -> &'static str {
        match self {
            RuleId::V1IntervalPartition => "V1",
            RuleId::V2ArenaBounds => "V2",
            RuleId::V3ShardPartition => "V3",
            RuleId::V4QuantizerGrid => "V4",
            RuleId::V5DeadLeaf => "V5",
            RuleId::V6SparsityCensus => "V6",
            RuleId::V7CompressedEquivalence => "V7",
        }
    }

    /// Human rule name for the report table.
    pub fn name(self) -> &'static str {
        match self {
            RuleId::V1IntervalPartition => "interval-partition",
            RuleId::V2ArenaBounds => "arena-bounds",
            RuleId::V3ShardPartition => "shard-partition",
            RuleId::V4QuantizerGrid => "quantizer-grid",
            RuleId::V5DeadLeaf => "dead-leaf",
            RuleId::V6SparsityCensus => "sparsity-census",
            RuleId::V7CompressedEquivalence => "compressed-equivalence",
        }
    }
}

/// Severity ladder; ordering is meaningful (`Info < Warn < Deny`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Informational (the census, structural observations).
    Info,
    /// Suspicious but serveable (a dead leaf wastes a CAM row but
    /// cannot corrupt a result).
    Warn,
    /// Structurally unsound: serving this program can return wrong
    /// logits. Refused at registration under the default policy.
    Deny,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        }
    }
}

/// Precise location of a finding inside the compiled artifact. All
/// coordinates are optional: a program-level finding (e.g. a lost tree)
/// carries none, a LUT mismatch carries core + feature + interval.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Location {
    /// Shard index inside a [`crate::compiler::ShardPlan`].
    pub shard: Option<usize>,
    /// Core index inside the program.
    pub core: Option<usize>,
    /// Feature column.
    pub feature: Option<usize>,
    /// Elementary-interval index (V1) or DAC level (LUT findings).
    pub interval: Option<usize>,
    /// CAM row within the core.
    pub row: Option<usize>,
    /// Source-ensemble tree id.
    pub tree: Option<u32>,
}

impl Location {
    /// Program-level location (no coordinates).
    pub fn program() -> Location {
        Location::default()
    }

    pub fn core(core: usize) -> Location {
        Location { core: Some(core), ..Location::default() }
    }

    pub fn shard(shard: usize) -> Location {
        Location { shard: Some(shard), ..Location::default() }
    }

    pub fn feature(mut self, f: usize) -> Location {
        self.feature = Some(f);
        self
    }

    pub fn interval(mut self, i: usize) -> Location {
        self.interval = Some(i);
        self
    }

    pub fn row(mut self, r: usize) -> Location {
        self.row = Some(r);
        self
    }

    pub fn tree(mut self, t: u32) -> Location {
        self.tree = Some(t);
        self
    }

    fn parts(&self) -> Vec<String> {
        let mut p = Vec::new();
        if let Some(s) = self.shard {
            p.push(format!("shard {s}"));
        }
        if let Some(c) = self.core {
            p.push(format!("core {c}"));
        }
        if let Some(f) = self.feature {
            p.push(format!("feature {f}"));
        }
        if let Some(i) = self.interval {
            p.push(format!("interval {i}"));
        }
        if let Some(r) = self.row {
            p.push(format!("row {r}"));
        }
        if let Some(t) = self.tree {
            p.push(format!("tree {t}"));
        }
        p
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        if let Some(s) = self.shard {
            j.set("shard", Json::Num(s as f64));
        }
        if let Some(c) = self.core {
            j.set("core", Json::Num(c as f64));
        }
        if let Some(f) = self.feature {
            j.set("feature", Json::Num(f as f64));
        }
        if let Some(i) = self.interval {
            j.set("interval", Json::Num(i as f64));
        }
        if let Some(r) = self.row {
            j.set("row", Json::Num(r as f64));
        }
        if let Some(t) = self.tree {
            j.set("tree", Json::Num(t as f64));
        }
        j
    }
}

impl fmt::Display for Location {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let parts = self.parts();
        if parts.is_empty() {
            write!(f, "program")
        } else {
            write!(f, "{}", parts.join(" / "))
        }
    }
}

/// One verifier finding: which rule fired, how bad, where, and why.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: RuleId,
    pub severity: Severity,
    pub location: Location,
    pub message: String,
}

impl Finding {
    pub fn deny(rule: RuleId, location: Location, message: String) -> Finding {
        Finding { rule, severity: Severity::Deny, location, message }
    }

    pub fn warn(rule: RuleId, location: Location, message: String) -> Finding {
        Finding { rule, severity: Severity::Warn, location, message }
    }

    pub fn info(rule: RuleId, location: Location, message: String) -> Finding {
        Finding { rule, severity: Severity::Info, location, message }
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("rule", Json::Str(self.rule.code().to_string()))
            .set("name", Json::Str(self.rule.name().to_string()))
            .set("severity", Json::Str(self.severity.label().to_string()))
            .set("location", self.location.to_json())
            .set("message", Json::Str(self.message.clone()));
        j
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{} {}] {}: {}",
            self.rule.code(),
            self.severity.label(),
            self.location,
            self.message
        )
    }
}

/// Per-core slice of the sparsity census (rule V6).
#[derive(Clone, Debug)]
pub struct CoreCensus {
    pub core: usize,
    pub n_rows: usize,
    /// `n_rows × n_features` programmed cells.
    pub n_cells: usize,
    /// Cells spanning the full DAC range (`is_dont_care`).
    pub wildcard_cells: usize,
    /// Per-feature wildcard counts (MonoSparse-style column density).
    pub per_feature_wildcards: Vec<usize>,
    /// Rows whose interval conjunction is unsatisfiable (V5 hits).
    pub never_match_rows: usize,
    /// Σ over adjacent row pairs of their longest common cell prefix —
    /// the compressibility signal prefix-sharing schemes exploit.
    pub shared_prefix_cells: usize,
    /// Physical CAM words after capacity compression (= `n_rows` for
    /// uncompressed programs; contract 11).
    pub phys_rows: usize,
}

impl CoreCensus {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("core", Json::Num(self.core as f64))
            .set("n_rows", Json::Num(self.n_rows as f64))
            .set("n_cells", Json::Num(self.n_cells as f64))
            .set("wildcard_cells", Json::Num(self.wildcard_cells as f64))
            .set("per_feature_wildcards", Json::from_usize_slice(&self.per_feature_wildcards))
            .set("never_match_rows", Json::Num(self.never_match_rows as f64))
            .set("shared_prefix_cells", Json::Num(self.shared_prefix_cells as f64))
            .set("phys_rows", Json::Num(self.phys_rows as f64));
        j
    }
}

/// Whole-program sparsity census: the measurement substrate for CAM
/// compression work (most rows are mostly don't-care — this makes that
/// visible before anything tries to exploit it).
#[derive(Clone, Debug, Default)]
pub struct SparsityCensus {
    pub n_cores: usize,
    pub n_rows: usize,
    pub n_cells: usize,
    pub wildcard_cells: usize,
    pub never_match_rows: usize,
    pub shared_prefix_cells: usize,
    /// Total physical CAM words (= `n_rows` for uncompressed programs).
    pub phys_rows: usize,
    pub cores: Vec<CoreCensus>,
}

impl SparsityCensus {
    /// Fraction of programmed cells that are full-range wildcards.
    pub fn wildcard_density(&self) -> f64 {
        if self.n_cells == 0 {
            0.0
        } else {
            self.wildcard_cells as f64 / self.n_cells as f64
        }
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("n_cores", Json::Num(self.n_cores as f64))
            .set("n_rows", Json::Num(self.n_rows as f64))
            .set("n_cells", Json::Num(self.n_cells as f64))
            .set("wildcard_cells", Json::Num(self.wildcard_cells as f64))
            .set("wildcard_density", Json::Num(self.wildcard_density()))
            .set("never_match_rows", Json::Num(self.never_match_rows as f64))
            .set("shared_prefix_cells", Json::Num(self.shared_prefix_cells as f64))
            .set("phys_rows", Json::Num(self.phys_rows as f64))
            .set("cores", Json::Arr(self.cores.iter().map(CoreCensus::to_json).collect()));
        j
    }
}

/// Machine-readable result of one verification run.
#[derive(Clone, Debug, Default)]
pub struct AnalysisReport {
    /// Program name the run was against.
    pub program: String,
    pub findings: Vec<Finding>,
    /// Present whenever the program-level rules ran (absent for a
    /// shard-plan-only report).
    pub census: Option<SparsityCensus>,
}

impl AnalysisReport {
    pub fn new(program: &str) -> AnalysisReport {
        AnalysisReport { program: program.to_string(), ..AnalysisReport::default() }
    }

    pub fn push(&mut self, finding: Finding) {
        self.findings.push(finding);
    }

    /// Absorb another report's findings (census kept from `self` unless
    /// absent). Used to combine program-level and shard-plan runs.
    pub fn merge(&mut self, other: AnalysisReport) {
        self.findings.extend(other.findings);
        if self.census.is_none() {
            self.census = other.census;
        }
    }

    pub fn count(&self, severity: Severity) -> usize {
        self.findings.iter().filter(|f| f.severity == severity).count()
    }

    pub fn deny_count(&self) -> usize {
        self.count(Severity::Deny)
    }

    pub fn warn_count(&self) -> usize {
        self.count(Severity::Warn)
    }

    /// No deny-level findings: the program is structurally sound (warn
    /// and info findings may still be present).
    pub fn is_clean(&self) -> bool {
        self.deny_count() == 0
    }

    /// Findings of one rule, for mutation tests asserting that exactly
    /// one rule fired.
    pub fn findings_for(&self, rule: RuleId) -> Vec<&Finding> {
        self.findings.iter().filter(|f| f.rule == rule).collect()
    }

    /// Serialize (schema: DESIGN.md §7; consumed by CI artifacts).
    pub fn to_json(&self) -> Json {
        let mut counts = Json::obj();
        counts
            .set("deny", Json::Num(self.deny_count() as f64))
            .set("warn", Json::Num(self.warn_count() as f64))
            .set("info", Json::Num(self.count(Severity::Info) as f64));
        let mut j = Json::obj();
        j.set("report", Json::Str("xtime-verify".to_string()))
            .set("program", Json::Str(self.program.clone()))
            .set("counts", counts)
            .set("clean", Json::Bool(self.is_clean()))
            .set("findings", Json::Arr(self.findings.iter().map(Finding::to_json).collect()));
        if let Some(c) = &self.census {
            j.set("census", c.to_json());
        }
        j
    }

    /// Human-readable table for the CLI.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "verify {}: {} finding(s) — {} deny, {} warn, {} info\n",
            self.program,
            self.findings.len(),
            self.deny_count(),
            self.warn_count(),
            self.count(Severity::Info),
        ));
        if !self.findings.is_empty() {
            out.push_str(&format!(
                "{:<4} {:<5} {:<28} {}\n",
                "RULE", "SEV", "LOCATION", "MESSAGE"
            ));
            // Deny first, then warn, then info; stable within a tier.
            let mut ordered: Vec<&Finding> = self.findings.iter().collect();
            ordered.sort_by(|a, b| b.severity.cmp(&a.severity));
            for f in ordered {
                out.push_str(&format!(
                    "{:<4} {:<5} {:<28} {}\n",
                    f.rule.code(),
                    f.severity.label(),
                    f.location.to_string(),
                    f.message
                ));
            }
        }
        if let Some(c) = &self.census {
            out.push_str(&format!(
                "census: {} core(s), {} row(s), {} cell(s), wildcard density {:.1}%, \
                 {} never-match row(s), {} shared-prefix cell(s)\n",
                c.n_cores,
                c.n_rows,
                c.n_cells,
                100.0 * c.wildcard_density(),
                c.never_match_rows,
                c.shared_prefix_cells,
            ));
        }
        out.push_str(if self.is_clean() { "verdict: CLEAN\n" } else { "verdict: DENY\n" });
        out
    }
}

/// Registration-gate policy (contract 8, DESIGN.md §5): which findings
/// block [`crate::coordinator::Fleet::register_program`] /
/// `swap_program`. Configured per model via
/// [`crate::coordinator::ModelConfig::with_verify`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum VerifyPolicy {
    /// Do not run the verifier (trusted artifact, or latency-critical
    /// registration of an already-verified program).
    Skip,
    /// Refuse deny-level findings; warnings serve. The default.
    #[default]
    DenyErrors,
    /// Refuse warnings too (strictest: a dead leaf blocks deploy).
    DenyWarnings,
}

impl VerifyPolicy {
    /// First finding that blocks registration under this policy, if any.
    pub fn blocks<'r>(&self, report: &'r AnalysisReport) -> Option<&'r Finding> {
        let floor = match self {
            VerifyPolicy::Skip => return None,
            VerifyPolicy::DenyErrors => Severity::Deny,
            VerifyPolicy::DenyWarnings => Severity::Warn,
        };
        // Report the worst finding first so the diagnostic names the
        // most damning rule even when warnings also block.
        report
            .findings
            .iter()
            .filter(|f| f.severity >= floor)
            .max_by_key(|f| f.severity)
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]
    use super::*;

    fn sample() -> AnalysisReport {
        let mut r = AnalysisReport::new("m");
        r.push(Finding::info(
            RuleId::V6SparsityCensus,
            Location::program(),
            "census".to_string(),
        ));
        r.push(Finding::warn(
            RuleId::V5DeadLeaf,
            Location::core(1).row(3).tree(7),
            "row can never match".to_string(),
        ));
        r.push(Finding::deny(
            RuleId::V2ArenaBounds,
            Location::core(0).feature(2),
            "offset out of bounds".to_string(),
        ));
        r
    }

    #[test]
    fn severity_ordering_and_counts() {
        assert!(Severity::Info < Severity::Warn && Severity::Warn < Severity::Deny);
        let r = sample();
        assert_eq!(r.deny_count(), 1);
        assert_eq!(r.warn_count(), 1);
        assert_eq!(r.count(Severity::Info), 1);
        assert!(!r.is_clean());
        assert_eq!(r.findings_for(RuleId::V2ArenaBounds).len(), 1);
    }

    #[test]
    fn policy_floors() {
        let r = sample();
        assert!(VerifyPolicy::Skip.blocks(&r).is_none());
        assert_eq!(VerifyPolicy::DenyErrors.blocks(&r).unwrap().rule, RuleId::V2ArenaBounds);
        // DenyWarnings still reports the deny finding first (worst wins).
        assert_eq!(VerifyPolicy::DenyWarnings.blocks(&r).unwrap().rule, RuleId::V2ArenaBounds);
        let mut warn_only = AnalysisReport::new("m");
        warn_only.push(Finding::warn(
            RuleId::V5DeadLeaf,
            Location::program(),
            "w".to_string(),
        ));
        assert!(VerifyPolicy::DenyErrors.blocks(&warn_only).is_none());
        assert_eq!(VerifyPolicy::DenyWarnings.blocks(&warn_only).unwrap().rule, RuleId::V5DeadLeaf);
    }

    #[test]
    fn location_and_finding_display() {
        assert_eq!(Location::program().to_string(), "program");
        let loc = Location::core(3).feature(1).interval(9);
        assert_eq!(loc.to_string(), "core 3 / feature 1 / interval 9");
        let f = Finding::deny(RuleId::V1IntervalPartition, loc, "lut mismatch".to_string());
        let s = f.to_string();
        assert!(s.contains("V1") && s.contains("deny") && s.contains("core 3"), "{s}");
    }

    #[test]
    fn json_roundtrips_and_carries_counts() {
        let r = sample();
        let j = crate::util::Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.req_str("program").unwrap(), "m");
        assert_eq!(j.req("counts").unwrap().req_f64("deny").unwrap(), 1.0);
        let findings = match j.req("findings").unwrap() {
            crate::util::Json::Arr(v) => v.clone(),
            other => panic!("findings not an array: {other:?}"),
        };
        assert_eq!(findings.len(), 3);
    }

    #[test]
    fn render_orders_deny_first() {
        let s = sample().render();
        let deny_at = s.find("V2").unwrap();
        let warn_at = s.find("V5").unwrap();
        assert!(deny_at < warn_at, "{s}");
        assert!(s.contains("verdict: DENY"));
    }
}
