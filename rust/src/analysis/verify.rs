//! The rule engine: checks V1–V7 over a compiled [`CamProgram`], its
//! per-core execution plans, and (optionally) a [`ShardPlan`].
//!
//! Every check is *static*: the verifier reads the compiled artifact —
//! programmed cells, plan bounds, LUTs, arena bitsets, shard
//! assignments — and cross-checks them against independently recomputed
//! references. No query is ever executed. The checks are deliberately
//! redundant with the compiler: each rule re-derives what the compiler
//! *should* have produced from first principles (cells → bounds,
//! bounds → `partition_point` LUT, rows → bitset width) so that a
//! corruption anywhere between compile and deploy surfaces as a
//! localized diagnostic rather than silently wrong logits
//! (DESIGN.md §5, contract 8).
//!
//! Entry points:
//!
//! * [`verify_program`] — V1/V2/V4/V5/V6 (+V7 when compressed) on a
//!   defect-free engine build;
//! * [`verify_with_defects`] — same rules on a defect-perturbed build
//!   (V5 dead-leaf warnings carry the defect draw);
//! * [`verify_shard_plan`] — V3 on an explicit [`ShardPlan`];
//! * [`verify`] — the one-call form the CLI and fleet gate use:
//!   program rules plus, for `n_shards > 1`, a partition + V3.

use std::collections::BTreeMap;

use super::report::{AnalysisReport, CoreCensus, Finding, Location, RuleId, SparsityCensus};
use crate::cam::{DefectSpec, MACRO_BINS};
use crate::compiler::{
    partition, CamEngine, CamProgram, CoreLayout, PartitionOptions, PlanView, ShardPlan,
};

/// Verify a program as compiled (defect-free engine build): rules V1,
/// V2, V4, V5, V6 — plus V7 when the program carries compression
/// layouts (contract 11).
pub fn verify_program(program: &CamProgram) -> AnalysisReport {
    let engine = CamEngine::new(program);
    verify_engine(program, &engine, None)
}

/// Verify a defect-perturbed deployment of `program`: the same rules as
/// [`verify_program`], but run over the engine built with `defects` and
/// `seed` — so V5 reports exactly the rows this particular draw killed,
/// with the draw recorded in the finding.
pub fn verify_with_defects(program: &CamProgram, defects: DefectSpec, seed: u64) -> AnalysisReport {
    let engine = CamEngine::with_defects(program, defects, seed);
    verify_engine(program, &engine, Some((defects, seed)))
}

/// One-call verification: program rules, plus — when `n_shards > 1` —
/// a fresh [`partition`] checked under V3.
pub fn verify(program: &CamProgram, n_shards: usize) -> AnalysisReport {
    verify_deployment(program, n_shards, DefectSpec::NONE, 0)
}

/// The full deployment form (`xtime verify`): program rules on the
/// engine as it would deploy — defect-perturbed when `defects` is
/// non-trivial — plus V3 over a fresh partition when `n_shards > 1`.
/// A partition *failure* is itself a V3 deny: the deployment the
/// caller asked for cannot exist.
pub fn verify_deployment(
    program: &CamProgram,
    n_shards: usize,
    defects: DefectSpec,
    seed: u64,
) -> AnalysisReport {
    let pristine = defects.memristor_pct == 0.0 && defects.dac_pct == 0.0;
    let mut report = if pristine {
        verify_program(program)
    } else {
        verify_with_defects(program, defects, seed)
    };
    if n_shards > 1 {
        match partition(program, n_shards, &PartitionOptions::default()) {
            Ok(plan) => report.merge(verify_shard_plan(program, &plan)),
            Err(e) => report.push(Finding::deny(
                RuleId::V3ShardPartition,
                Location::program(),
                format!("cannot partition into {n_shards} shards: {e}"),
            )),
        }
    }
    report
}

/// Program-level rules against an already-built engine. `defect_ctx`
/// carries the draw that produced the engine (None = defect-free), so
/// V5 findings can name the corruption source.
pub fn verify_engine(
    program: &CamProgram,
    engine: &CamEngine,
    defect_ctx: Option<(DefectSpec, u64)>,
) -> AnalysisReport {
    let mut report = AnalysisReport::new(&program.name);
    check_quantizer_grid(program, &mut report);

    if let Some(layouts) = &program.layouts {
        if layouts.len() != program.cores.len() {
            report.push(Finding::deny(
                RuleId::V7CompressedEquivalence,
                Location::program(),
                format!(
                    "{} compression layouts for {} cores",
                    layouts.len(),
                    program.cores.len()
                ),
            ));
        }
    }

    let n_cores = engine.n_cores().min(program.cores.len());
    let mut cores = Vec::with_capacity(n_cores);
    let mut total = CoreCensus {
        core: 0,
        n_rows: 0,
        n_cells: 0,
        wildcard_cells: 0,
        per_feature_wildcards: Vec::new(),
        never_match_rows: 0,
        shared_prefix_cells: 0,
        phys_rows: 0,
    };
    for ci in 0..n_cores {
        let view = engine.plan_view(ci);
        check_interval_partition(ci, &view, &mut report);
        check_arena(ci, &view, &mut report);
        check_dead_rows(program, ci, &view, defect_ctx, &mut report);
        let layout = program.layouts.as_ref().and_then(|l| l.get(ci));
        if let Some(layout) = layout {
            check_compression(program, ci, layout, &view, &mut report);
        }
        let phys_rows = layout.map_or(view.n_rows(), |l| l.n_phys_rows());
        let census = core_census(ci, &view, phys_rows);
        total.n_rows += census.n_rows;
        total.n_cells += census.n_cells;
        total.wildcard_cells += census.wildcard_cells;
        total.never_match_rows += census.never_match_rows;
        total.shared_prefix_cells += census.shared_prefix_cells;
        total.phys_rows += census.phys_rows;
        cores.push(census);
    }
    let census = SparsityCensus {
        n_cores,
        n_rows: total.n_rows,
        n_cells: total.n_cells,
        wildcard_cells: total.wildcard_cells,
        never_match_rows: total.never_match_rows,
        shared_prefix_cells: total.shared_prefix_cells,
        phys_rows: total.phys_rows,
        cores,
    };
    let compressed = if program.layouts.is_some() {
        format!(" ({} physical words after compression)", census.phys_rows)
    } else {
        String::new()
    };
    report.push(Finding::info(
        RuleId::V6SparsityCensus,
        Location::program(),
        format!(
            "{} cores, {} rows{}, {:.1}% wildcard cells, {} never-match rows, \
             {} shared-prefix cells",
            census.n_cores,
            census.n_rows,
            compressed,
            100.0 * census.wildcard_density(),
            census.never_match_rows,
            census.shared_prefix_cells
        ),
    ));
    report.census = Some(census);
    report
}

/// V4 — quantizer/grid coherence: cuts strictly increasing and finite,
/// bin count consistent with the declared precision, and every
/// *constrained* compiled window bound resolvable to a cut on the
/// deploy grid. The one degenerate allowance: a feature with **no**
/// cuts (constant feature) snaps every threshold to bin 1
/// ([`crate::compiler::snap_threshold`]), so bound 1 is on-grid there.
fn check_quantizer_grid(program: &CamProgram, report: &mut AnalysisReport) {
    let q = &program.quantizer;
    if q.edges.len() != program.n_features {
        report.push(Finding::deny(
            RuleId::V4QuantizerGrid,
            Location::program(),
            format!(
                "quantizer covers {} features but program declares {}",
                q.edges.len(),
                program.n_features
            ),
        ));
        return; // per-feature grid checks below would index out of bounds
    }
    if q.n_bits != program.n_bits {
        report.push(Finding::deny(
            RuleId::V4QuantizerGrid,
            Location::program(),
            format!("quantizer n_bits={} but program n_bits={}", q.n_bits, program.n_bits),
        ));
    }
    let want_bins = 1u32 << program.n_bits;
    if u32::from(program.n_bins) != want_bins {
        report.push(Finding::deny(
            RuleId::V4QuantizerGrid,
            Location::program(),
            format!("n_bins={} but 2^n_bits={want_bins}", program.n_bins),
        ));
    }
    for (f, cuts) in q.edges.iter().enumerate() {
        if cuts.len() >= want_bins as usize {
            report.push(Finding::deny(
                RuleId::V4QuantizerGrid,
                Location::program().feature(f),
                format!("{} cuts exceed the {want_bins}-bin grid capacity", cuts.len()),
            ));
        }
        if let Some(c) = cuts.iter().find(|c| !c.is_finite()) {
            report.push(Finding::deny(
                RuleId::V4QuantizerGrid,
                Location::program().feature(f),
                format!("non-finite cut {c}"),
            ));
            continue; // ordering against NaN is meaningless
        }
        if let Some(i) = cuts.windows(2).position(|w| w[0] >= w[1]) {
            report.push(Finding::deny(
                RuleId::V4QuantizerGrid,
                Location::program().feature(f),
                format!(
                    "cuts not strictly increasing: cuts[{i}]={} >= cuts[{}]={}",
                    cuts[i],
                    i + 1,
                    cuts[i + 1]
                ),
            ));
        }
    }
    // Every constrained window bound must be a real grid index: a lo > 0
    // or hi < n_bins window edge came from some training threshold, and
    // that threshold must still exist as cut `b-1` on the deploy grid.
    for (ci, core) in program.cores.iter().enumerate() {
        for (ri, row) in core.rows.iter().enumerate() {
            if row.lo.len() != program.n_features || row.hi.len() != program.n_features {
                report.push(Finding::deny(
                    RuleId::V4QuantizerGrid,
                    Location::core(ci).row(ri).tree(row.tree),
                    format!(
                        "row arity {}x{} does not match {} features",
                        row.lo.len(),
                        row.hi.len(),
                        program.n_features
                    ),
                ));
                continue;
            }
            for f in 0..program.n_features {
                let cuts = &q.edges[f];
                for (side, b) in [("lo", row.lo[f]), ("hi", row.hi[f])] {
                    let constrained =
                        if side == "lo" { b > 0 } else { b < program.n_bins };
                    if !constrained {
                        continue;
                    }
                    let on_grid = (1..=cuts.len() as u16).contains(&b)
                        || (cuts.is_empty() && b == 1);
                    if !on_grid {
                        report.push(Finding::deny(
                            RuleId::V4QuantizerGrid,
                            Location::core(ci).feature(f).row(ri).tree(row.tree),
                            format!(
                                "{side} bound {b} is off the deploy grid ({} cuts)",
                                cuts.len()
                            ),
                        ));
                    }
                }
            }
        }
    }
}

/// V1 — elementary intervals exactly partition DAC space and the LUT
/// tabulates them. Three sub-checks per feature: (a) stored bound
/// levels are strictly ascending inside `1..=MACRO_BINS` (a duplicate
/// is an overlapping zero-width interval, an out-of-range bound a gap);
/// (b) the stored bounds equal the set recomputed from the programmed
/// cells (sorted distinct non-zero window edges) — so plan and CAM
/// agree on where intervals begin; (c) all 256 LUT entries equal
/// `partition_point` of the stored bounds — so level→interval
/// resolution agrees with the binary-search (indexed) path.
fn check_interval_partition(ci: usize, view: &PlanView<'_>, report: &mut AnalysisReport) {
    let n_rows = view.n_rows();
    for f in 0..view.n_features() {
        let stored = view.bounds(f);
        if let Some(&b) = stored.first() {
            if b == 0 {
                report.push(Finding::deny(
                    RuleId::V1IntervalPartition,
                    Location::core(ci).feature(f),
                    "bound level 0 stored (interval 0 always starts at level 0)".to_string(),
                ));
            }
        }
        if let Some(&b) = stored.last() {
            if b > MACRO_BINS {
                report.push(Finding::deny(
                    RuleId::V1IntervalPartition,
                    Location::core(ci).feature(f),
                    format!("bound level {b} above the {MACRO_BINS}-level DAC range"),
                ));
            }
        }
        if let Some(i) = stored.windows(2).position(|w| w[0] >= w[1]) {
            report.push(Finding::deny(
                RuleId::V1IntervalPartition,
                Location::core(ci).feature(f).interval(i + 1),
                format!(
                    "bounds not strictly ascending: bounds[{i}]={} >= bounds[{}]={} \
                     (overlapping or empty elementary interval)",
                    stored[i],
                    i + 1,
                    stored[i + 1]
                ),
            ));
        }
        // (b) recompute the reference bound set from the programmed cells.
        let mut want: Vec<u16> = Vec::with_capacity(n_rows * 2);
        for r in 0..n_rows {
            let c = view.cell(r, f);
            want.push(c.lo);
            want.push(c.hi);
        }
        want.retain(|&b| b > 0);
        want.sort_unstable();
        want.dedup();
        if stored != want.as_slice() {
            report.push(Finding::deny(
                RuleId::V1IntervalPartition,
                Location::core(ci).feature(f),
                format!(
                    "stored interval boundaries diverge from programmed cells \
                     ({} stored vs {} recomputed)",
                    stored.len(),
                    want.len()
                ),
            ));
        }
        // (c) LUT tabulation against the stored bounds; report the first
        // bad level only — one corrupt write rarely stays alone, and one
        // precise location beats 256 copies of it.
        for level in 0..MACRO_BINS as usize {
            let want_iv = stored.partition_point(|&b| (b as usize) <= level) as u16;
            let got = view.lut(f, level);
            if got != want_iv {
                report.push(Finding::deny(
                    RuleId::V1IntervalPartition,
                    Location::core(ci).feature(f).interval(level),
                    format!("LUT[{level}]={got} but partition_point of bounds gives {want_iv}"),
                ));
                break;
            }
        }
    }
}

/// V2 — bitset-arena structural soundness: per-feature slices are
/// contiguous and in-bounds, the arena is exactly the sum of its
/// slices, the row-bitset width matches the core's row count, the
/// all-rows mask is correct, and no padding bit above `n_rows` is set
/// in any interval bitset (a stray padding bit would phantom-match a
/// nonexistent row on the planned path).
fn check_arena(ci: usize, view: &PlanView<'_>, report: &mut AnalysisReport) {
    let n_rows = view.n_rows();
    let n_words = view.n_words();
    let want_words = n_rows.div_ceil(64).max(1);
    if n_words != want_words {
        report.push(Finding::deny(
            RuleId::V2ArenaBounds,
            Location::core(ci),
            format!("row-bitset width {n_words} words, but {n_rows} rows need {want_words}"),
        ));
        return; // every later bound derives from n_words
    }
    // The bits that may legally be set in any row bitset.
    let mut legal = vec![u64::MAX; n_words];
    if n_rows == 0 {
        legal[0] = 0;
    } else {
        let spare = n_words * 64 - n_rows;
        legal[n_words - 1] = u64::MAX >> spare;
    }
    let full = view.full_mask();
    if full.len() != n_words {
        report.push(Finding::deny(
            RuleId::V2ArenaBounds,
            Location::core(ci),
            format!("all-rows mask is {} words, expected {n_words}", full.len()),
        ));
    } else if full != legal.as_slice() {
        report.push(Finding::deny(
            RuleId::V2ArenaBounds,
            Location::core(ci),
            format!("all-rows mask does not cover exactly rows 0..{n_rows}"),
        ));
    }
    let arena = view.arena();
    if let Some(slots) = view.slots() {
        // Deduplicated arena: offsets index the slot table, not the
        // arena itself; the arena holds one copy of each distinct slice.
        if arena.len() % n_words != 0 {
            report.push(Finding::deny(
                RuleId::V2ArenaBounds,
                Location::core(ci),
                format!(
                    "deduplicated arena holds {} words, not a multiple of the \
                     {n_words}-word slice width",
                    arena.len()
                ),
            ));
            return; // slice indexing below derives from n_words alignment
        }
        let n_slices = arena.len() / n_words;
        let mut expect_off = 0usize;
        for f in 0..view.n_features() {
            let n_intervals = view.bounds(f).len() + 1;
            let off = view.offset(f);
            if off != expect_off {
                report.push(Finding::deny(
                    RuleId::V2ArenaBounds,
                    Location::core(ci).feature(f),
                    format!(
                        "slot offset {off}, expected {expect_off} (slot bases must be contiguous)"
                    ),
                ));
            }
            expect_off += n_intervals;
        }
        if slots.len() != expect_off {
            report.push(Finding::deny(
                RuleId::V2ArenaBounds,
                Location::core(ci),
                format!("slot table holds {} entries, layout requires {expect_off}", slots.len()),
            ));
        }
        'slot: for f in 0..view.n_features() {
            let off = view.offset(f);
            for iv in 0..=view.bounds(f).len() {
                let Some(&slot) = slots.get(off + iv) else {
                    break 'slot; // length mismatch already denied above
                };
                if slot as usize >= n_slices {
                    report.push(Finding::deny(
                        RuleId::V2ArenaBounds,
                        Location::core(ci).feature(f).interval(iv),
                        format!("slot {slot} points past the {n_slices}-slice arena"),
                    ));
                    break 'slot; // one corrupt table rarely stays alone
                }
            }
        }
        for sl in 0..n_slices {
            let slice = &arena[sl * n_words..(sl + 1) * n_words];
            if let Some((w, _)) =
                slice.iter().enumerate().find(|(w, &word)| word & !legal[*w] != 0)
            {
                report.push(Finding::deny(
                    RuleId::V2ArenaBounds,
                    Location::core(ci).interval(sl),
                    format!(
                        "padding bits set above row {n_rows} in word {w} of arena slice {sl} \
                         (would phantom-match a nonexistent row)"
                    ),
                ));
                break; // one location is enough
            }
        }
        return;
    }
    let mut expect_off = 0usize;
    let mut in_bounds = vec![true; view.n_features()];
    for f in 0..view.n_features() {
        let n_intervals = view.bounds(f).len() + 1;
        let off = view.offset(f);
        if off != expect_off {
            report.push(Finding::deny(
                RuleId::V2ArenaBounds,
                Location::core(ci).feature(f),
                format!("arena offset {off}, expected {expect_off} (slices must be contiguous)"),
            ));
        }
        let words = n_intervals * n_words;
        if off > arena.len() || words > arena.len() - off.min(arena.len()) {
            report.push(Finding::deny(
                RuleId::V2ArenaBounds,
                Location::core(ci).feature(f),
                format!(
                    "interval slices [{off}..{}) exceed the {}-word arena",
                    off.saturating_add(words),
                    arena.len()
                ),
            ));
            in_bounds[f] = false; // skip padding scan — it would index past the arena
        }
        expect_off += words;
    }
    if arena.len() != expect_off {
        report.push(Finding::deny(
            RuleId::V2ArenaBounds,
            Location::core(ci),
            format!("arena holds {} words, layout requires {expect_off}", arena.len()),
        ));
    }
    for f in 0..view.n_features() {
        if !in_bounds[f] {
            continue;
        }
        let off = view.offset(f);
        'feature: for iv in 0..=view.bounds(f).len() {
            let slice = &arena[off + iv * n_words..off + (iv + 1) * n_words];
            for (w, &word) in slice.iter().enumerate() {
                if word & !legal[w] != 0 {
                    report.push(Finding::deny(
                        RuleId::V2ArenaBounds,
                        Location::core(ci).feature(f).interval(iv),
                        format!(
                            "padding bits set above row {n_rows} in bitset word {w} \
                             (would phantom-match a nonexistent row)"
                        ),
                    ));
                    break 'feature; // one location per feature is enough
                }
            }
        }
    }
}

/// V5 — dead-leaf lint: a row whose programmed conjunction contains an
/// empty window (`hi <= lo` in DAC space) can never match any query;
/// its leaf silently drops out of every prediction. On a clean compile
/// this cannot happen (the path extractor only emits non-empty
/// windows), so these are warnings that usually point at a defect draw
/// — which is named in the finding when known.
fn check_dead_rows(
    program: &CamProgram,
    ci: usize,
    view: &PlanView<'_>,
    defect_ctx: Option<(DefectSpec, u64)>,
    report: &mut AnalysisReport,
) {
    let rows = &program.cores[ci].rows;
    for r in 0..view.n_rows() {
        let Some(f) = (0..view.n_features()).find(|&f| {
            let c = view.cell(r, f);
            c.hi <= c.lo
        }) else {
            continue;
        };
        let c = view.cell(r, f);
        let draw = match defect_ctx {
            Some((spec, seed)) => format!(
                " (defect draw: {:.2}% memristor, {:.2}% dac, seed {seed})",
                spec.memristor_pct, spec.dac_pct
            ),
            None => String::new(),
        };
        let mut loc = Location::core(ci).feature(f).row(r);
        if let Some(row) = rows.get(r) {
            loc = loc.tree(row.tree);
        }
        report.push(Finding::warn(
            RuleId::V5DeadLeaf,
            loc,
            format!("window [{}, {}) is empty — row can never match{draw}", c.lo, c.hi),
        ));
    }
}

/// V7 — compressed-row match-set equivalence (DESIGN.md §5,
/// contract 11): a program carrying compression layouts must describe a
/// physical image that matches *exactly* the logical rows it claims to
/// compress. Checks, in order: (a) unit/row coverage — every logical
/// row belongs to exactly one unit and the `unit_of_row` index agrees;
/// (b) merged-pair validity — the two rows are adjacent leaves of one
/// tree whose windows agree everywhere except the split feature, where
/// they are non-empty complementary halves (`hi_left == lo_right`);
/// (c) packing disjointness — no two units of one physical word own the
/// same cell (overlapping constrained features); (d) word-image
/// fidelity — each owned cell carries exactly the owning unit's union
/// window recomputed from the logical rows, each unowned cell is a full
/// don't-care; (e) dedup match-set equivalence — every elementary
/// interval's slot resolves to a bitset identical to the membership
/// recomputed from the programmed cells (rules V1/V2 check bounds and
/// structure but never arena *content*; this is the only check that
/// does).
fn check_compression(
    program: &CamProgram,
    ci: usize,
    layout: &CoreLayout,
    view: &PlanView<'_>,
    report: &mut AnalysisReport,
) {
    let rows = &program.cores[ci].rows;
    let n_features = program.n_features;
    let n_bins = program.n_bins;

    // (a) unit/row coverage.
    if layout.unit_of_row.len() != rows.len() {
        report.push(Finding::deny(
            RuleId::V7CompressedEquivalence,
            Location::core(ci),
            format!(
                "layout maps {} rows but the core holds {}",
                layout.unit_of_row.len(),
                rows.len()
            ),
        ));
        return; // every check below indexes rows through this map
    }
    let mut covered = vec![false; rows.len()];
    let mut units_ok = true;
    for (u, unit) in layout.units.iter().enumerate() {
        let members = [Some(unit.rows.0), unit.rows.1];
        for r in members.into_iter().flatten() {
            let r = r as usize;
            if r >= rows.len() {
                report.push(Finding::deny(
                    RuleId::V7CompressedEquivalence,
                    Location::core(ci).row(r),
                    format!("unit {u} references row {r} outside the {}-row core", rows.len()),
                ));
                units_ok = false;
                continue;
            }
            if covered[r] {
                report.push(Finding::deny(
                    RuleId::V7CompressedEquivalence,
                    Location::core(ci).row(r),
                    format!("row {r} covered by two units"),
                ));
            }
            covered[r] = true;
            if layout.unit_of_row[r] != u as u32 {
                report.push(Finding::deny(
                    RuleId::V7CompressedEquivalence,
                    Location::core(ci).row(r),
                    format!(
                        "unit_of_row[{r}] = {} but unit {u} claims the row",
                        layout.unit_of_row[r]
                    ),
                ));
            }
        }
    }
    if let Some(r) = covered.iter().position(|&c| !c) {
        report.push(Finding::deny(
            RuleId::V7CompressedEquivalence,
            Location::core(ci).row(r),
            format!("row {r} belongs to no unit — its leaf would vanish from the physical image"),
        ));
    }
    if !units_ok {
        return; // window recomputation below would index out of bounds
    }

    // (b) merged-pair validity.
    for (u, unit) in layout.units.iter().enumerate() {
        let Some(b) = unit.rows.1 else {
            if unit.split_feature.is_some() {
                report.push(Finding::deny(
                    RuleId::V7CompressedEquivalence,
                    Location::core(ci).row(unit.rows.0 as usize),
                    format!("single-row unit {u} carries a residual split feature"),
                ));
            }
            continue;
        };
        let (a, b) = (unit.rows.0 as usize, b as usize);
        let loc = Location::core(ci).row(a).tree(rows[a].tree);
        let Some(split) = unit.split_feature else {
            report.push(Finding::deny(
                RuleId::V7CompressedEquivalence,
                loc,
                format!("merged unit {u} has no residual split feature"),
            ));
            continue;
        };
        let split = split as usize;
        if b != a + 1 {
            report.push(Finding::deny(
                RuleId::V7CompressedEquivalence,
                loc,
                format!("merged rows {a} and {b} are not adjacent"),
            ));
        }
        if rows[a].tree != rows[b].tree {
            report.push(Finding::deny(
                RuleId::V7CompressedEquivalence,
                loc,
                format!("merged rows {a} and {b} belong to trees {} and {}", rows[a].tree, rows[b].tree),
            ));
            continue;
        }
        if split >= n_features {
            report.push(Finding::deny(
                RuleId::V7CompressedEquivalence,
                loc,
                format!("split feature {split} outside the {n_features}-feature space"),
            ));
            continue;
        }
        for f in 0..n_features {
            if f == split {
                let empty = rows[a].lo[f] >= rows[a].hi[f] || rows[b].lo[f] >= rows[b].hi[f];
                if empty || rows[a].hi[f] != rows[b].lo[f] {
                    report.push(Finding::deny(
                        RuleId::V7CompressedEquivalence,
                        Location::core(ci).feature(f).row(a).tree(rows[a].tree),
                        format!(
                            "rows {a} and {b} are not complementary halves at the split: \
                             [{}, {}) vs [{}, {})",
                            rows[a].lo[f], rows[a].hi[f], rows[b].lo[f], rows[b].hi[f]
                        ),
                    ));
                }
            } else if rows[a].lo[f] != rows[b].lo[f] || rows[a].hi[f] != rows[b].hi[f] {
                report.push(Finding::deny(
                    RuleId::V7CompressedEquivalence,
                    Location::core(ci).feature(f).row(a).tree(rows[a].tree),
                    format!(
                        "merged rows {a} and {b} disagree off the split feature: \
                         [{}, {}) vs [{}, {})",
                        rows[a].lo[f], rows[a].hi[f], rows[b].lo[f], rows[b].hi[f]
                    ),
                ));
            }
        }
    }

    // (c) packing disjointness + (d) word-image fidelity. Rebuild the
    // expected image of every physical word from the logical rows and
    // compare cell by cell.
    if layout.word_of_unit.len() != layout.units.len() {
        report.push(Finding::deny(
            RuleId::V7CompressedEquivalence,
            Location::core(ci),
            format!(
                "{} units but {} word assignments",
                layout.units.len(),
                layout.word_of_unit.len()
            ),
        ));
        return;
    }
    let n_phys = layout.words.len();
    let mut expect_owner = vec![vec![-1i32; n_features]; n_phys];
    for (u, &w) in layout.word_of_unit.iter().enumerate() {
        let w = w as usize;
        if w >= n_phys {
            report.push(Finding::deny(
                RuleId::V7CompressedEquivalence,
                Location::core(ci).row(layout.units[u].rows.0 as usize),
                format!("unit {u} mapped to word {w} ≥ {n_phys} words"),
            ));
            continue;
        }
        for f in layout.unit_constrained(u, rows, n_bins) {
            if f >= n_features {
                continue; // corrupt row arity — already a V4 deny
            }
            if expect_owner[w][f] >= 0 {
                report.push(Finding::deny(
                    RuleId::V7CompressedEquivalence,
                    Location::core(ci).feature(f).row(w),
                    format!(
                        "overlapping constrained features: units {} and {u} both \
                         need cell {f} of word {w}",
                        expect_owner[w][f]
                    ),
                ));
            } else {
                expect_owner[w][f] = u as i32;
            }
        }
    }
    for (w, word) in layout.words.iter().enumerate() {
        if word.lo.len() != n_features || word.hi.len() != n_features || word.owner.len() != n_features
        {
            report.push(Finding::deny(
                RuleId::V7CompressedEquivalence,
                Location::core(ci).row(w),
                format!(
                    "word {w} arity (lo {}, hi {}, owner {}) does not match {n_features} features",
                    word.lo.len(),
                    word.hi.len(),
                    word.owner.len()
                ),
            ));
            continue;
        }
        for f in 0..n_features {
            let u = expect_owner[w][f];
            if word.owner[f] != u {
                report.push(Finding::deny(
                    RuleId::V7CompressedEquivalence,
                    Location::core(ci).feature(f).row(w),
                    format!(
                        "word {w} cell {f} owned by unit {} but packing assigns {u}",
                        word.owner[f]
                    ),
                ));
                continue;
            }
            let want = if u >= 0 {
                layout.unit_window(u as usize, rows, f)
            } else {
                (0, n_bins) // unowned cells stay full don't-care
            };
            if (word.lo[f], word.hi[f]) != want {
                report.push(Finding::deny(
                    RuleId::V7CompressedEquivalence,
                    Location::core(ci).feature(f).row(w),
                    format!(
                        "wrong union bounds: word {w} cell {f} holds [{}, {}) but the \
                         owning rows give [{}, {})",
                        word.lo[f], word.hi[f], want.0, want.1
                    ),
                ));
            }
        }
    }

    // (e) dedup match-set equivalence. Recompute every elementary
    // interval's membership from the programmed (possibly
    // defect-perturbed) cells — exactly what `CorePlan::build` bitset —
    // and require the slot-resolved slice to be bit-identical.
    let Some(slots) = view.slots() else {
        return;
    };
    let n_rows = view.n_rows();
    let n_words = view.n_words();
    if view.arena().len() % n_words != 0 {
        return; // V2 already denied; slice addressing is meaningless
    }
    let n_slices = view.arena().len() / n_words;
    'feature: for f in 0..view.n_features() {
        let bounds = view.bounds(f);
        let off = view.offset(f);
        for iv in 0..=bounds.len() {
            match slots.get(off + iv) {
                Some(&s) if (s as usize) < n_slices => {}
                _ => continue 'feature, // V2 already denied the table
            }
            let rep = if iv == 0 { 0 } else { bounds[iv - 1] };
            let mut want = vec![0u64; n_words];
            for r in 0..n_rows {
                if view.cell(r, f).matches_ideal(rep) {
                    want[r / 64] |= 1u64 << (r % 64);
                }
            }
            if view.interval_slice(f, iv) != want.as_slice() {
                report.push(Finding::deny(
                    RuleId::V7CompressedEquivalence,
                    Location::core(ci).feature(f).interval(iv),
                    format!(
                        "deduplicated slice for interval {iv} diverges from the match set \
                         recomputed from the programmed cells (slot {})",
                        slots[off + iv]
                    ),
                ));
                continue 'feature; // one corrupt slot per feature is enough
            }
        }
    }
}

/// V6 — per-core sparsity census over the programmed cells: wildcard
/// density (fully-open windows — the compression target of ROADMAP
/// item 2), dead rows, and the shared-prefix count (cells equal to the
/// same column of the previous row — an upper bound on prefix-sharing
/// row compression). `phys_rows` is the physical word count after
/// capacity compression (equal to `n_rows` for uncompressed cores).
fn core_census(ci: usize, view: &PlanView<'_>, phys_rows: usize) -> CoreCensus {
    let n_rows = view.n_rows();
    let n_features = view.n_features();
    let mut per_feature = vec![0usize; n_features];
    let mut wildcards = 0usize;
    let mut dead = 0usize;
    let mut shared = 0usize;
    for r in 0..n_rows {
        let mut row_dead = false;
        let mut prefix_open = r > 0;
        for f in 0..n_features {
            let c = view.cell(r, f);
            if c.is_dont_care() {
                wildcards += 1;
                per_feature[f] += 1;
            }
            if c.hi <= c.lo {
                row_dead = true;
            }
            if prefix_open {
                if view.cell(r - 1, f) == c {
                    shared += 1;
                } else {
                    prefix_open = false;
                }
            }
        }
        if row_dead {
            dead += 1;
        }
    }
    CoreCensus {
        core: ci,
        n_rows,
        n_cells: n_rows * n_features,
        wildcard_cells: wildcards,
        per_feature_wildcards: per_feature,
        never_match_rows: dead,
        shared_prefix_cells: shared,
        phys_rows,
    }
}

/// V3 — shard plans partition the tree set exactly. Checks, in order:
/// plan/program metadata coherence; every assigned tree exists in the
/// program and belongs to exactly one shard (no duplicate, no loss);
/// each shard's per-tree leaf-row counts reconcile with the unsharded
/// program (no row dropped or forged in repacking); and the additive
/// prior rides on shard 0 alone (applying it per shard would add it
/// `n_shards` times — DESIGN.md §5 contract 6).
pub fn verify_shard_plan(program: &CamProgram, plan: &ShardPlan) -> AnalysisReport {
    let mut report = AnalysisReport::new(&program.name);
    if plan.task != program.task {
        report.push(Finding::deny(
            RuleId::V3ShardPartition,
            Location::program(),
            format!("plan task {:?} but program task {:?}", plan.task, program.task),
        ));
    }
    if plan.n_features != program.n_features {
        report.push(Finding::deny(
            RuleId::V3ShardPartition,
            Location::program(),
            format!("plan has {} features, program {}", plan.n_features, program.n_features),
        ));
    }
    if plan.shards.len() != plan.assignment.len() {
        report.push(Finding::deny(
            RuleId::V3ShardPartition,
            Location::program(),
            format!(
                "{} shard programs but {} assignment lists",
                plan.shards.len(),
                plan.assignment.len()
            ),
        ));
    }
    // Reference: leaf-row count per tree in the unsharded program.
    let mut program_rows: BTreeMap<u32, usize> = BTreeMap::new();
    for core in &program.cores {
        for row in &core.rows {
            *program_rows.entry(row.tree).or_insert(0) += 1;
        }
    }
    // Assignment exactness: each program tree on exactly one shard.
    let mut owner: BTreeMap<u32, usize> = BTreeMap::new();
    for (s, trees) in plan.assignment.iter().enumerate() {
        for &t in trees {
            if !program_rows.contains_key(&t) {
                report.push(Finding::deny(
                    RuleId::V3ShardPartition,
                    Location::shard(s).tree(t),
                    format!("assigned tree {t} does not exist in the program"),
                ));
            }
            if let Some(prev) = owner.insert(t, s) {
                report.push(Finding::deny(
                    RuleId::V3ShardPartition,
                    Location::shard(s).tree(t),
                    format!("tree {t} duplicated across shards {prev} and {s}"),
                ));
            }
        }
    }
    for &t in program_rows.keys() {
        if !owner.contains_key(&t) {
            report.push(Finding::deny(
                RuleId::V3ShardPartition,
                Location::program().tree(t),
                format!("tree {t} lost: assigned to no shard"),
            ));
        }
    }
    // Per-shard reconciliation: the repacked cores must carry exactly
    // the assigned trees with exactly the program's row counts.
    for (s, shard) in plan.shards.iter().enumerate() {
        if shard.task != program.task
            || shard.n_features != program.n_features
            || shard.n_bins != program.n_bins
        {
            report.push(Finding::deny(
                RuleId::V3ShardPartition,
                Location::shard(s),
                "shard program metadata (task/features/bins) diverges from source".to_string(),
            ));
        }
        let mut shard_rows: BTreeMap<u32, usize> = BTreeMap::new();
        for core in &shard.cores {
            for row in &core.rows {
                *shard_rows.entry(row.tree).or_insert(0) += 1;
            }
        }
        let assigned: &[u32] =
            plan.assignment.get(s).map(Vec::as_slice).unwrap_or_default();
        for &t in assigned {
            let want = program_rows.get(&t).copied().unwrap_or(0);
            let got = shard_rows.remove(&t).unwrap_or(0);
            if got != want {
                report.push(Finding::deny(
                    RuleId::V3ShardPartition,
                    Location::shard(s).tree(t),
                    format!("tree {t} carries {got} leaf rows on the shard, {want} in the program"),
                ));
            }
        }
        for (&t, &rows) in &shard_rows {
            report.push(Finding::deny(
                RuleId::V3ShardPartition,
                Location::shard(s).tree(t),
                format!("shard carries {rows} rows of tree {t} it was never assigned"),
            ));
        }
        if s == 0 {
            if shard.base_score != program.base_score {
                report.push(Finding::deny(
                    RuleId::V3ShardPartition,
                    Location::shard(0),
                    "shard 0 base score diverges from the program's".to_string(),
                ));
            }
        } else if shard.base_score.iter().any(|&b| b != 0.0) {
            report.push(Finding::deny(
                RuleId::V3ShardPartition,
                Location::shard(s),
                format!("non-zero base score on shard {s} (the prior must be applied once)"),
            ));
        }
    }
    if plan.base_score != program.base_score {
        report.push(Finding::deny(
            RuleId::V3ShardPartition,
            Location::program(),
            "plan base score diverges from the program's".to_string(),
        ));
    }
    report
}
