//! Multi-chip PCIe accelerator card (paper §III-D: "we envision a PCIe
//! card containing multiple X-TIME chips connected to a standard server,
//! that the CPU can use to offload the decision tree inference").
//!
//! The card model composes per-chip [`super::chip`] results with the host
//! link: samples cross PCIe (feature bytes down, logits up), a card-level
//! dispatcher round-robins chips, and throughput is the minimum of the
//! aggregated chip rate and the PCIe payload bound.

use super::chip::{simulate, SimReport, Workload};
use super::config::ChipConfig;
use crate::compiler::CamProgram;

/// PCIe card configuration.
#[derive(Clone, Copy, Debug)]
pub struct CardConfig {
    pub n_chips: usize,
    /// Host-link payload bandwidth (bytes/s). PCIe Gen4 ×16 ≈ 25 GB/s
    /// effective after framing.
    pub pcie_bytes_per_s: f64,
    /// One-way host→card DMA latency (s).
    pub dma_latency_s: f64,
}

impl Default for CardConfig {
    fn default() -> Self {
        CardConfig { n_chips: 4, pcie_bytes_per_s: 25e9, dma_latency_s: 500e-9 }
    }
}

/// Card-level simulation result.
#[derive(Clone, Debug)]
pub struct CardReport {
    pub per_chip: SimReport,
    /// End-to-end single-sample latency incl. PCIe round trip (s).
    pub latency_s: f64,
    /// Sustained card throughput (samples/s).
    pub throughput_sps: f64,
    /// Which resource bound the card: "pcie" or "chips".
    pub bottleneck: &'static str,
}

/// Bytes crossing PCIe per sample: 8-bit features down + f32 logits up.
pub fn bytes_per_sample(program: &CamProgram) -> f64 {
    (program.n_features + 4 * program.task.n_outputs()) as f64
}

/// Simulate the card serving a saturating stream.
pub fn simulate_card(
    program: &CamProgram,
    chip_cfg: &ChipConfig,
    card: &CardConfig,
    n_samples: usize,
) -> CardReport {
    assert!(card.n_chips >= 1);
    let per_chip = simulate(
        program,
        chip_cfg,
        &Workload::saturating(n_samples.div_ceil(card.n_chips)),
        0.05,
    );
    let chip_rate = per_chip.throughput_msps * 1e6 * card.n_chips as f64;
    let pcie_rate = card.pcie_bytes_per_s / bytes_per_sample(program);
    let (throughput, bottleneck) =
        if pcie_rate < chip_rate { (pcie_rate, "pcie") } else { (chip_rate, "chips") };
    let latency = 2.0 * card.dma_latency_s
        + bytes_per_sample(program) / card.pcie_bytes_per_s
        + per_chip.latency_ns.min * 1e-9;
    CardReport { per_chip, latency_s: latency, throughput_sps: throughput, bottleneck }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    fn program() -> CamProgram {
        let d = by_name("churn").unwrap().generate_n(800);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 8, max_leaves: 16, ..Default::default() },
            None,
        );
        compile(&m, &CompileOptions { replicas: 0, ..Default::default() }).unwrap()
    }

    #[test]
    fn chips_scale_until_pcie_binds() {
        let p = program();
        let chip = ChipConfig::default();
        let one = simulate_card(&p, &chip, &CardConfig { n_chips: 1, ..Default::default() }, 40_000);
        let four = simulate_card(&p, &chip, &CardConfig { n_chips: 4, ..Default::default() }, 40_000);
        assert!(four.throughput_sps > one.throughput_sps);
        // churn: 14 B/sample → PCIe carries ~1.8 GS/s; chips (≤500 MS/s
        // each) bind at 1 and 2 chips.
        assert_eq!(one.bottleneck, "chips");
        // A narrow link flips the bottleneck.
        let narrow = CardConfig { n_chips: 4, pcie_bytes_per_s: 1e9, ..Default::default() };
        let pinched = simulate_card(&p, &chip, &narrow, 40_000);
        assert_eq!(pinched.bottleneck, "pcie");
        assert!(pinched.throughput_sps < four.throughput_sps);
    }

    #[test]
    fn latency_includes_dma_round_trip() {
        let p = program();
        let chip = ChipConfig::default();
        let card = CardConfig::default();
        let rep = simulate_card(&p, &chip, &card, 10_000);
        assert!(rep.latency_s >= 2.0 * card.dma_latency_s);
        // Host-side offload latency sits in the ~1 µs decade — still far
        // below GPU kernel-launch latency (~10 µs).
        assert!(rep.latency_s < 5e-6, "{}", rep.latency_s);
    }

    #[test]
    fn bytes_per_sample_accounts_output() {
        let p = program();
        assert_eq!(bytes_per_sample(&p), (p.n_features + 4) as f64);
    }
}
