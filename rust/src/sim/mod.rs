//! Cycle-detailed simulator of the X-TIME chip (SST-equivalent, §IV-B):
//! discrete-event substrate, chip timing model, the Fig. 8
//! area/power/energy cost model, the PCIe card model, and
//! [`SimCardBackend`] — a simulated card usable as a serving backend
//! (one virtual card per shard of a fleet route).
//!
//! The cost model is pure arithmetic over [`ChipConfig`], so the Fig. 8
//! breakdown is available without running a simulation:
//!
//! ```
//! use xtime::sim::{chip_area, chip_peak_power, ChipConfig};
//!
//! let cfg = ChipConfig::default(); // the paper's 4096-core 16 nm chip
//! let area = chip_area(&cfg);
//! let power = chip_peak_power(&cfg);
//! assert!(area.total() > 0.0, "total die area (mm²)");
//! assert!(power.total() > 0.0, "peak power (W)");
//! // Every breakdown row contributes a non-negative share.
//! assert!(area.rows("mm²").iter().all(|(_, v)| *v >= 0.0));
//! ```

pub mod backend;
pub mod card;
pub mod chip;
pub mod config;
pub mod cost;
pub mod event;

pub use backend::{DefectInjector, SimCardBackend, SimCardCounters};
pub use card::{simulate_card, CardConfig, CardReport};
pub use chip::{ideal_latency_cycles, simulate, SimReport, Workload};
pub use config::ChipConfig;
pub use cost::{chip_area, chip_peak_power, Activity, Breakdown};
pub use event::{EventQueue, Resource};
