//! Cycle-detailed simulator of the X-TIME chip (SST-equivalent, §IV-B):
//! discrete-event substrate, chip timing model, and the Fig. 8
//! area/power/energy cost model.

pub mod backend;
pub mod card;
pub mod chip;
pub mod config;
pub mod cost;
pub mod event;

pub use backend::{SimCardBackend, SimCardCounters};
pub use card::{simulate_card, CardConfig, CardReport};
pub use chip::{ideal_latency_cycles, simulate, SimReport, Workload};
pub use config::ChipConfig;
pub use cost::{chip_area, chip_peak_power, Activity, Breakdown};
pub use event::{EventQueue, Resource};
