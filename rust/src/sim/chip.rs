//! Cycle-detailed chip simulator (paper §IV-B).
//!
//! Simulates a compiled [`CamProgram`] running a stream of samples through
//! the full datapath:
//!
//! ```text
//! input port ──(flit-serialized broadcast, H-tree down)──► replica cores
//!     cores ──(λ_CAM-pipelined search, MMR/SRAM/ACC)──► upstream H-tree
//!     upstream (config-bit reduction, shared root link) ──► co-processor
//! ```
//!
//! Stages are modelled as serially-occupied [`Resource`]s at replica
//! granularity (cores within a replica operate in lock-step on the same
//! broadcast sample; the slowest core gates the replica — the paper's
//! load-balance argument in §III-C). Queuing between stages is exact
//! FIFO, so per-sample latencies include back-pressure effects.

use super::config::ChipConfig;
use super::cost::Activity;
use super::event::Resource;
use crate::cam::ARRAY_COLS;
use crate::compiler::CamProgram;
use crate::util::stats::Summary;

/// Workload description.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub n_samples: usize,
    /// Cycles between sample arrivals at the chip input (0 = back-to-back
    /// saturation, for peak-throughput measurement).
    pub inject_interval: u64,
}

impl Workload {
    pub fn saturating(n_samples: usize) -> Workload {
        Workload { n_samples, inject_interval: 0 }
    }
}

/// Simulation result.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub n_samples: usize,
    /// Total cycles until the last decision.
    pub makespan_cycles: u64,
    /// Per-sample end-to-end latency statistics, in nanoseconds.
    pub latency_ns: Summary,
    /// Sustained throughput in MSamples/s.
    pub throughput_msps: f64,
    /// Dynamic energy per decision, nJ.
    pub energy_nj_per_decision: f64,
    /// Which resource bound the run: "input-bw", "core", "output-bw", "cp".
    pub bottleneck: &'static str,
    /// Utilization of each stage over the makespan.
    pub util_input: f64,
    pub util_core: f64,
    pub util_output: f64,
    pub util_cp: f64,
    /// Replicas active (batch parallelism).
    pub n_replicas: usize,
}

/// Simulate `workload` on `program` under `cfg`.
///
/// `avg_charged_frac` is the mean fraction of rows that stay charged after
/// the first queued segment (from [`crate::compiler::CamEngine`] stats);
/// it only affects the energy estimate, not timing.
pub fn simulate(
    program: &CamProgram,
    cfg: &ChipConfig,
    workload: &Workload,
    avg_charged_frac: f64,
) -> SimReport {
    let n = workload.n_samples;
    assert!(n > 0);
    let levels = cfg.noc_levels();
    let hop = cfg.hop_cycles;
    let in_flits = cfg.input_flits(program.n_features);
    // In-network reduction merges each replica's logits to n_outputs
    // flits; without it (ablation) every core ships its own flit.
    let n_outputs = if cfg.in_network_reduction {
        program.task.n_outputs() as u64
    } else {
        (program.task.n_outputs() * program.cores_per_replica()) as u64
    };
    let n_segments = program.n_features.div_ceil(ARRAY_COLS).max(1);

    // Replica pipeline parameters gated by the slowest core (§III-C).
    let max_trees = program.max_trees_per_core().max(1);
    let ii = cfg.core_interval(program.n_bits, max_trees);
    let lambda_c = cfg.core_latency(program.n_bits, n_segments, max_trees);

    let mut input = Resource::new();
    let mut replicas: Vec<Resource> = vec![Resource::new(); program.n_replicas];
    let mut output = Resource::new();
    let mut cp = Resource::new();

    let cp_time = cfg.cp_cycles.max(n_outputs);
    let mut latencies = Vec::with_capacity(n);
    let mut done_last = 0u64;

    for s in 0..n {
        let arrive = workload.inject_interval * s as u64;
        // Downstream broadcast: serialize flits on the root input port,
        // then traverse the H-tree.
        let bcast_start = input.acquire(arrive, in_flits);
        let at_core = bcast_start + in_flits + levels * hop;
        // Dynamic dispatch: pick the replica that frees earliest (the
        // router's input batching, Fig. 7c).
        let r = (0..replicas.len())
            .min_by_key(|&r| replicas[r].free_at().max(at_core))
            .unwrap();
        let issue = replicas[r].acquire(at_core, ii);
        let core_out = issue + lambda_c;
        // Upstream: private subtree links inside the replica are conflict-
        // free (one flit stream per class); the shared root link serializes
        // n_outputs flits per sample.
        let at_root = core_out + levels * hop;
        let out_start = output.acquire(at_root, n_outputs);
        // The CP is pipelined: it *occupies* one slot per output flit but
        // adds `cp_time` of decision latency.
        let cp_start = cp.acquire(out_start + n_outputs, n_outputs);
        let done = cp_start + cp_time;
        latencies.push((done - arrive) as f64 * cfg.cycle_ns());
        done_last = done_last.max(done);
    }

    let makespan = done_last;
    let throughput_samples_per_cycle = n as f64 / makespan as f64;
    let throughput_msps = throughput_samples_per_cycle * cfg.clock_ghz * 1e3;

    // Bottleneck attribution by utilization.
    let util_input = input.utilization(makespan);
    let util_core = replicas.iter().map(|r| r.utilization(makespan)).fold(0.0, f64::max);
    let util_output = output.utilization(makespan);
    let util_cp = cp.utilization(makespan);
    let bottleneck = attribute_bottleneck(&[
        ("input-bw", util_input),
        ("core", util_core),
        ("output-bw", util_output),
        ("cp", util_cp),
    ]);

    let energy = Activity::estimate(program, cfg, avg_charged_frac).energy_nj();

    SimReport {
        n_samples: n,
        makespan_cycles: makespan,
        latency_ns: Summary::of(&latencies),
        throughput_msps,
        energy_nj_per_decision: energy,
        bottleneck,
        util_input,
        util_core,
        util_output,
        util_cp,
        n_replicas: program.n_replicas,
    }
}

/// Deterministic bottleneck attribution: the stage with the highest
/// utilization wins; exact ties resolve to the *earliest* stage in
/// pipeline order (stable across runs — the previous
/// `max_by(partial_cmp().unwrap())` panicked on NaN and flipped between
/// equally-utilized stages because `max_by` keeps the *last* maximum).
/// Comparison uses `f64::total_cmp`; NaN utilizations (degenerate
/// workloads) are measurement artifacts, never a bottleneck, and are
/// skipped — unless every stage is NaN, in which case the first stage is
/// reported.
pub fn attribute_bottleneck(stages: &[(&'static str, f64)]) -> &'static str {
    assert!(!stages.is_empty(), "no stages to attribute");
    let mut best: Option<(&'static str, f64)> = None;
    for &(name, util) in stages {
        if util.is_nan() {
            continue;
        }
        let better = match best {
            None => true,
            Some((_, b)) => util.total_cmp(&b) == std::cmp::Ordering::Greater,
        };
        if better {
            best = Some((name, util));
        }
    }
    best.map(|(name, _)| name).unwrap_or(stages[0].0)
}

/// Analytic single-sample latency in cycles (no queuing): broadcast +
/// core pipeline + reduction + CP. Used as a cross-check invariant.
pub fn ideal_latency_cycles(program: &CamProgram, cfg: &ChipConfig) -> u64 {
    let levels = cfg.noc_levels();
    let n_segments = program.n_features.div_ceil(ARRAY_COLS).max(1);
    let max_trees = program.max_trees_per_core().max(1);
    let n_outputs = program.task.n_outputs() as u64;
    cfg.input_flits(program.n_features)
        + levels * cfg.hop_cycles
        + cfg.core_latency(program.n_bits, n_segments, max_trees)
        + levels * cfg.hop_cycles
        + n_outputs
        + cfg.cp_cycles.max(n_outputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    fn small_program(replicas: usize) -> CamProgram {
        let d = by_name("churn").unwrap().generate_n(1000);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 8, max_leaves: 16, ..Default::default() },
            None,
        );
        compile(&m, &CompileOptions { replicas, ..Default::default() }).unwrap()
    }

    #[test]
    fn single_sample_latency_matches_ideal() {
        let p = small_program(1);
        let cfg = ChipConfig::default();
        let rep = simulate(&p, &cfg, &Workload::saturating(1), 0.05);
        let ideal = ideal_latency_cycles(&p, &cfg) as f64 * cfg.cycle_ns();
        assert!((rep.latency_ns.mean - ideal).abs() < 1e-9, "{} vs {ideal}", rep.latency_ns.mean);
        // Paper: ~100 ns decade for single-chip inference.
        assert!(rep.latency_ns.mean < 200.0, "latency {} ns", rep.latency_ns.mean);
    }

    #[test]
    fn throughput_approaches_eq4_bound() {
        // One replica, 8 trees in one core → II = max(4, 8) = 8 → the
        // core bound is 125 MS/s; churn's 10 features need 2 input flits
        // → input bound 500 MS/s. Core should bind.
        let p = small_program(1);
        assert_eq!(p.cores_per_replica(), 1);
        let cfg = ChipConfig::default();
        let rep = simulate(&p, &cfg, &Workload::saturating(20_000), 0.05);
        let ii = cfg.core_interval(p.n_bits, p.max_trees_per_core()) as f64;
        let bound = cfg.clock_ghz * 1e3 / ii;
        assert!(rep.throughput_msps <= bound * 1.001);
        assert!(rep.throughput_msps > bound * 0.98, "{} vs {bound}", rep.throughput_msps);
        assert_eq!(rep.bottleneck, "core");
    }

    #[test]
    fn replication_lifts_core_bound_until_input_bound() {
        let p1 = small_program(1);
        let p8 = small_program(8);
        let cfg = ChipConfig::default();
        let r1 = simulate(&p1, &cfg, &Workload::saturating(20_000), 0.05);
        let r8 = simulate(&p8, &cfg, &Workload::saturating(20_000), 0.05);
        assert!(r8.throughput_msps > 3.0 * r1.throughput_msps, "{} vs {}", r8.throughput_msps, r1.throughput_msps);
        // With 8 replicas the 2-flit input serialization (500 MS/s) binds
        // (the active replicas saturate jointly with the input port).
        assert!(r8.util_input > 0.95, "input util {}", r8.util_input);
        let input_bound = cfg.clock_ghz * 1e3 / cfg.input_flits(p8.n_features) as f64;
        assert!(r8.throughput_msps <= input_bound * 1.001);
        assert!(r8.throughput_msps > input_bound * 0.95, "{}", r8.throughput_msps);
    }

    #[test]
    fn latency_constant_in_ntrees_throughput_constant_too() {
        // Fig. 11a claim: X-TIME latency/throughput do not depend on
        // N_trees (until cores run out) — trees run in parallel cores.
        let d = by_name("churn").unwrap().generate_n(800);
        let cfg = ChipConfig::default();
        let mut last: Option<SimReport> = None;
        for rounds in [4usize, 16, 64] {
            let m = gbdt::train(
                &d,
                &GbdtParams { n_rounds: rounds, max_leaves: 64, ..Default::default() },
                None,
            );
            // One tree per core (64 leaves each, capacity 256 → pack 4/core;
            // force 1/core with core_rows=64 for the parallel-tree layout).
            let p = compile(&m, &CompileOptions { core_rows: 64, replicas: 1, ..Default::default() })
                .unwrap();
            let rep = simulate(&p, &cfg, &Workload::saturating(5_000), 0.05);
            if let Some(prev) = &last {
                // Packing may co-locate a couple of small trees, shifting
                // λ_C by a cycle or two; the Fig. 11a claim is that latency
                // and throughput are *flat* in N_trees, not bit-identical.
                let dl = (rep.latency_ns.mean - prev.latency_ns.mean).abs();
                assert!(dl <= 4.0, "latency changed with N_trees: {dl} ns");
                let dt = (rep.throughput_msps - prev.throughput_msps).abs()
                    / prev.throughput_msps;
                assert!(dt < 0.05, "throughput changed with N_trees: {dt}");
            }
            last = Some(rep);
        }
    }

    #[test]
    fn more_features_lower_throughput() {
        // Fig. 11b claim: broadcast serialization makes throughput fall
        // with N_feat once the input port saturates.
        let cfg = ChipConfig::default();
        let mut prev = f64::INFINITY;
        for name in ["churn", "gesture", "gas"] {
            // 10 → 32 → 129 features.
            let d = by_name(name).unwrap().generate_n(600);
            let m = gbdt::train(
                &d,
                &GbdtParams { n_rounds: 4, max_leaves: 8, ..Default::default() },
                None,
            );
            let p = compile(&m, &CompileOptions { replicas: 0, ..Default::default() }).unwrap();
            let rep = simulate(&p, &cfg, &Workload::saturating(10_000), 0.05);
            assert!(
                rep.throughput_msps <= prev * 1.001,
                "{name}: {} > previous {prev}",
                rep.throughput_msps
            );
            prev = rep.throughput_msps;
        }
    }

    #[test]
    fn multiclass_output_serialization_binds() {
        // Fig. 7b: n_class flits per sample on the root link limits
        // throughput to 1/N_classes samples per clock.
        let d = by_name("covertype").unwrap().generate_n(1500);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 4, max_leaves: 8, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions { replicas: 0, ..Default::default() }).unwrap();
        let cfg = ChipConfig::default();
        let rep = simulate(&p, &cfg, &Workload::saturating(10_000), 0.05);
        let class_bound = cfg.clock_ghz * 1e3 / 7.0; // 7 classes
        assert!(rep.throughput_msps <= class_bound * 1.001, "{}", rep.throughput_msps);
    }

    #[test]
    fn bottleneck_ties_resolve_to_first_stage() {
        // Regression (ISSUE 3 satellite): `max_by` kept the *last*
        // maximum, so attribution flipped between equally-utilized
        // stages. Ties must deterministically name the earliest stage.
        assert_eq!(
            attribute_bottleneck(&[("input-bw", 0.5), ("core", 0.5), ("output-bw", 0.5)]),
            "input-bw"
        );
        assert_eq!(
            attribute_bottleneck(&[("input-bw", 0.2), ("core", 0.9), ("output-bw", 0.9)]),
            "core"
        );
        assert_eq!(attribute_bottleneck(&[("input-bw", 0.0), ("core", 0.0)]), "input-bw");
    }

    #[test]
    fn bottleneck_survives_nan_utilization() {
        // Regression: `partial_cmp().unwrap()` panicked on NaN. NaN is a
        // degenerate measurement, never a bottleneck.
        assert_eq!(attribute_bottleneck(&[("input-bw", f64::NAN), ("core", 0.1)]), "core");
        assert_eq!(attribute_bottleneck(&[("input-bw", 0.1), ("core", f64::NAN)]), "input-bw");
        // All-NaN degenerates to the first stage instead of panicking.
        assert_eq!(
            attribute_bottleneck(&[("input-bw", f64::NAN), ("core", f64::NAN)]),
            "input-bw"
        );
        // Negative-zero / zero ties stay deterministic under total_cmp.
        assert_eq!(attribute_bottleneck(&[("input-bw", -0.0), ("core", 0.0)]), "core");
    }

    #[test]
    fn degenerate_single_sample_workload_attributes_cleanly() {
        // The smallest possible workload must simulate and attribute one
        // of the four pipeline stages without panicking.
        let p = small_program(1);
        let cfg = ChipConfig::default();
        let rep = simulate(&p, &cfg, &Workload::saturating(1), 0.0);
        assert!(["input-bw", "core", "output-bw", "cp"].contains(&rep.bottleneck));
        assert!(rep.util_input >= 0.0 && rep.util_input <= 1.0);
    }

    #[test]
    fn slow_injection_is_not_bound_by_chip() {
        let p = small_program(1);
        let cfg = ChipConfig::default();
        let rep = simulate(&p, &cfg, &Workload { n_samples: 1000, inject_interval: 100 }, 0.05);
        // 1 sample / 100 cycles = 10 MS/s.
        assert!((rep.throughput_msps - 10.0).abs() / 10.0 < 0.05, "{}", rep.throughput_msps);
        // Latency equals the unloaded ideal (no queuing).
        let ideal = ideal_latency_cycles(&p, &cfg) as f64 * cfg.cycle_ns();
        assert!((rep.latency_ns.max - ideal).abs() < 1e-9);
    }
}
