//! Simulated-card serving backend: one virtual X-TIME PCIe card.
//!
//! Bridges the cycle-detailed card model (§III-D / §IV-B) into the
//! serving engine: each [`SimCardBackend`] owns a functional engine for
//! *numerics* (bit-accurate logits) and the card cost model for *timing*
//! (projected service rate and unloaded latency). A sharded server built
//! from N of these models an N-card host — the scale-out deployment the
//! paper sketches — while staying runnable on any dev machine.

use super::card::{simulate_card, CardConfig};
use super::config::ChipConfig;
use crate::compiler::{CamEngine, CamProgram};
use crate::coordinator::Backend;
use crate::data::Task;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Simulated-device counters, shared out via [`SimCardBackend::counters`]
/// so they stay readable after the backend moves into a worker thread.
#[derive(Default)]
pub struct SimCardCounters {
    samples: AtomicU64,
    /// Simulated device-busy time, picoseconds (integer for atomics).
    busy_ps: AtomicU64,
}

impl SimCardCounters {
    pub fn samples(&self) -> u64 {
        self.samples.load(Ordering::Relaxed)
    }

    /// Simulated seconds the card spent serving.
    pub fn busy_s(&self) -> f64 {
        self.busy_ps.load(Ordering::Relaxed) as f64 * 1e-12
    }

    fn accrue(&self, n: usize, service_s: f64) {
        self.samples.fetch_add(n as u64, Ordering::Relaxed);
        self.busy_ps.fetch_add((service_s * n as f64 * 1e12) as u64, Ordering::Relaxed);
    }
}

/// A serving [`Backend`] over one simulated PCIe card.
pub struct SimCardBackend {
    engine: CamEngine,
    /// Simulated per-sample service time (s) at saturation.
    service_s: f64,
    /// Simulated unloaded end-to-end latency (s), incl. PCIe round trip.
    latency_s: f64,
    /// Planned-path worker threads (0 = auto; default 1).
    threads: usize,
    counters: Arc<SimCardCounters>,
}

impl SimCardBackend {
    /// Build a card for `program` (typically one shard of a
    /// [`crate::compiler::ShardPlan`]): runs the cycle-detailed card
    /// simulation once to calibrate timing, then serves numerics through
    /// the functional engine (single planned worker).
    pub fn new(program: &CamProgram, chip: &ChipConfig, card: &CardConfig) -> SimCardBackend {
        Self::with_threads(program, chip, card, 1)
    }

    /// Like [`SimCardBackend::new`] but serving numerics over `threads`
    /// planned-path workers (0 = one per available CPU). Simulated
    /// timing is unaffected: the calibrated card model, not the host,
    /// owns the projected rates.
    pub fn with_threads(
        program: &CamProgram,
        chip: &ChipConfig,
        card: &CardConfig,
        threads: usize,
    ) -> SimCardBackend {
        let rep = simulate_card(program, chip, card, 20_000);
        SimCardBackend {
            engine: CamEngine::new(program),
            service_s: 1.0 / rep.throughput_sps.max(1.0),
            latency_s: rep.latency_s,
            threads,
            counters: Arc::new(SimCardCounters::default()),
        }
    }

    /// Handle to the simulated-device counters.
    pub fn counters(&self) -> Arc<SimCardCounters> {
        self.counters.clone()
    }

    /// Calibrated card throughput (samples/s) at saturation.
    pub fn projected_throughput_sps(&self) -> f64 {
        1.0 / self.service_s
    }

    /// Calibrated unloaded latency (s).
    pub fn projected_latency_s(&self) -> f64 {
        self.latency_s
    }
}

impl Backend for SimCardBackend {
    fn name(&self) -> &'static str {
        "sim-card"
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn task(&self) -> Task {
        self.engine.task
    }

    /// Numerics through the planned execution engine (bit-identical to
    /// the scalar path at every thread count); timing through the
    /// calibrated card model.
    fn infer(&mut self, batch: &[Vec<u16>]) -> Result<Vec<Vec<f32>>> {
        self.counters.accrue(batch.len(), self.service_s);
        Ok(self.engine.infer_planned(batch, self.threads))
    }

    fn infer_partials(&mut self, batch: &[Vec<u16>]) -> Result<Vec<Vec<f64>>> {
        self.counters.accrue(batch.len(), self.service_s);
        Ok(self.engine.partials_planned(batch, self.threads))
    }

    fn set_threads(&mut self, threads: usize) {
        self.threads = threads;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, partition, CompileOptions, PartitionOptions};
    use crate::coordinator::{BatchPolicy, Server};
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    fn program() -> (crate::data::Dataset, CamProgram) {
        let d = by_name("churn").unwrap().generate_n(800);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 8, max_leaves: 16, ..Default::default() },
            None,
        );
        (d, compile(&m, &CompileOptions::default()).unwrap())
    }

    #[test]
    fn card_backend_serves_and_accrues_sim_time() {
        let (d, p) = program();
        let mut backend = SimCardBackend::new(&p, &ChipConfig::default(), &CardConfig::default());
        assert!(backend.projected_throughput_sps() > 0.0);
        assert!(backend.projected_latency_s() > 0.0);
        let counters = backend.counters();
        let bins: Vec<Vec<u16>> = (0..16).map(|i| p.quantizer.bin_row(d.row(i))).collect();
        let logits = backend.infer(&bins).unwrap();
        assert_eq!(logits.len(), 16);
        assert_eq!(counters.samples(), 16);
        assert!(counters.busy_s() > 0.0);
    }

    #[test]
    fn per_shard_cards_serve_through_the_pool() {
        let (d, p) = program();
        let plan = partition(&p, 2, &PartitionOptions::default()).unwrap();
        let cards: Vec<SimCardBackend> = plan
            .shards
            .iter()
            .map(|s| SimCardBackend::new(s, &ChipConfig::default(), &CardConfig::default()))
            .collect();
        let counters: Vec<_> = cards.iter().map(|c| c.counters()).collect();
        let backends: Vec<Box<dyn Backend>> =
            cards.into_iter().map(|c| Box::new(c) as Box<dyn Backend>).collect();
        let server = Server::start_sharded(
            backends,
            plan.base_score.clone(),
            BatchPolicy::default(),
            p.n_features,
        );
        let unsharded = CamEngine::new(&p);
        for i in 0..12 {
            let bins = p.quantizer.bin_row(d.row(i));
            let reply = server.infer_blocking(bins.clone());
            assert_eq!(reply.logits, unsharded.infer_bins(&bins), "row {i}");
        }
        server.shutdown();
        for c in &counters {
            assert_eq!(c.samples(), 12);
        }
    }
}
