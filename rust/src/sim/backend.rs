//! Simulated-card serving backend: one virtual X-TIME PCIe card.
//!
//! Bridges the cycle-detailed card model (§III-D / §IV-B) into the
//! serving engine: each [`SimCardBackend`] owns a functional engine for
//! *numerics* (bit-accurate logits) and the card cost model for *timing*
//! (projected service rate and unloaded latency). A sharded server built
//! from N of these models an N-card host — the scale-out deployment the
//! paper sketches — while staying runnable on any dev machine.

use super::card::{simulate_card, CardConfig};
use super::config::ChipConfig;
use crate::cam::DefectSpec;
use crate::compiler::{CamEngine, CamProgram};
use crate::coordinator::Backend;
use crate::data::Task;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Simulated-device counters, shared out via [`SimCardBackend::counters`]
/// so they stay readable after the backend moves into a worker thread.
#[derive(Default)]
pub struct SimCardCounters {
    samples: AtomicU64,
    /// Simulated device-busy time, picoseconds (integer for atomics).
    busy_ps: AtomicU64,
}

impl SimCardCounters {
    pub fn samples(&self) -> u64 {
        self.samples.load(Ordering::Relaxed)
    }

    /// Simulated seconds the card spent serving.
    pub fn busy_s(&self) -> f64 {
        self.busy_ps.load(Ordering::Relaxed) as f64 * 1e-12
    }

    fn accrue(&self, n: usize, service_s: f64) {
        self.samples.fetch_add(n as u64, Ordering::Relaxed);
        self.busy_ps.fetch_add((service_s * n as f64 * 1e12) as u64, Ordering::Relaxed);
    }
}

/// Runtime defect-injection hook for a live [`SimCardBackend`]: lets a
/// test harness (or the self-healing example) strike a card with
/// memristor/DAC defects *mid-serve*, from outside the worker thread
/// that owns the backend.
///
/// A strike is queued here and applied by the card at the start of its
/// next batch: the engine is rebuilt as
/// [`CamEngine::with_defects`]`(program, spec, seed)` — the exact
/// deterministic defect draw the retrain probe
/// ([`crate::compiler::defect_affected_trees`] /
/// [`crate::compiler::defective_score`]) replays for the same
/// `(spec, seed)`, which is what lets the repair loop retrain against
/// precisely the defects the card is serving through. The live draw
/// stays readable via [`DefectInjector::live_draw`] after the backend
/// has moved into its worker.
#[derive(Default)]
pub struct DefectInjector {
    /// Strike queued by the operator side, not yet applied by the card.
    pending: Mutex<Option<(DefectSpec, u64)>>,
    /// Draw the card is currently serving through (`None` = pristine).
    live: Mutex<Option<(DefectSpec, u64)>>,
    strikes: AtomicU64,
}

impl DefectInjector {
    pub fn new() -> Arc<DefectInjector> {
        Arc::new(DefectInjector::default())
    }

    /// Queue a defect strike; the card applies it on its next batch.
    pub fn strike(&self, spec: DefectSpec, seed: u64) {
        *lock_clean(&self.pending) = Some((spec, seed));
    }

    /// The `(spec, seed)` draw the card last applied — the ground truth
    /// the healer hands to `hat_defect_retrain`. `None` until a strike
    /// has been applied (or after [`DefectInjector::clear`]).
    pub fn live_draw(&self) -> Option<(DefectSpec, u64)> {
        *lock_clean(&self.live)
    }

    /// Strikes applied by the card so far.
    pub fn strikes_applied(&self) -> u64 {
        self.strikes.load(Ordering::Relaxed)
    }

    /// Forget the live draw (used when a repaired card replaces this
    /// one and the injector handle is being retired).
    pub fn clear(&self) {
        *lock_clean(&self.pending) = None;
        *lock_clean(&self.live) = None;
    }

    /// Card side: take a queued strike, recording it as live.
    fn take_pending(&self) -> Option<(DefectSpec, u64)> {
        let taken = lock_clean(&self.pending).take();
        if let Some(draw) = taken {
            *lock_clean(&self.live) = Some(draw);
            self.strikes.fetch_add(1, Ordering::Relaxed);
        }
        taken
    }
}

/// Mutex access continuing through poisoning: both guarded values are
/// plain `Option` copies, valid at any point a panicking holder could
/// have stopped, and the healer must stay able to read the live draw
/// after a worker panic.
fn lock_clean<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// A serving [`Backend`] over one simulated PCIe card.
pub struct SimCardBackend {
    engine: CamEngine,
    /// The pristine program this card was built from — kept so a queued
    /// defect strike can rebuild the engine as
    /// `CamEngine::with_defects(&program, …)`.
    program: CamProgram,
    /// Simulated per-sample service time (s) at saturation.
    service_s: f64,
    /// Simulated unloaded end-to-end latency (s), incl. PCIe round trip.
    latency_s: f64,
    /// Planned-path worker threads (0 = auto; default 1).
    threads: usize,
    counters: Arc<SimCardCounters>,
    /// Runtime defect hook (`None` = defects can't strike this card).
    injector: Option<Arc<DefectInjector>>,
}

impl SimCardBackend {
    /// Build a card for `program` (typically one shard of a
    /// [`crate::compiler::ShardPlan`]): runs the cycle-detailed card
    /// simulation once to calibrate timing, then serves numerics through
    /// the functional engine (single planned worker).
    pub fn new(program: &CamProgram, chip: &ChipConfig, card: &CardConfig) -> SimCardBackend {
        Self::with_threads(program, chip, card, 1)
    }

    /// Like [`SimCardBackend::new`] but serving numerics over `threads`
    /// planned-path workers (0 = one per available CPU). Simulated
    /// timing is unaffected: the calibrated card model, not the host,
    /// owns the projected rates.
    pub fn with_threads(
        program: &CamProgram,
        chip: &ChipConfig,
        card: &CardConfig,
        threads: usize,
    ) -> SimCardBackend {
        let rep = simulate_card(program, chip, card, 20_000);
        SimCardBackend {
            engine: CamEngine::new(program),
            program: program.clone(),
            service_s: 1.0 / rep.throughput_sps.max(1.0),
            latency_s: rep.latency_s,
            threads,
            counters: Arc::new(SimCardCounters::default()),
            injector: None,
        }
    }

    /// Attach a runtime defect-injection hook (builder style, before the
    /// backend moves into its server). Keep a clone of the `Arc` to
    /// strike the card and read its live draw from outside the worker.
    pub fn with_injector(mut self, injector: Arc<DefectInjector>) -> SimCardBackend {
        self.injector = Some(injector);
        self
    }

    /// Apply a queued defect strike, if any, before serving a batch.
    /// The rebuilt engine's planned path stays bit-identical to the
    /// scalar `with_defects` engine for the same draw (contract 4), so
    /// post-strike replies are exactly `defective_score`'s view.
    fn apply_pending_strike(&mut self) {
        let Some(injector) = &self.injector else { return };
        if let Some((spec, seed)) = injector.take_pending() {
            self.engine = CamEngine::with_defects(&self.program, spec, seed);
        }
    }

    /// Handle to the simulated-device counters.
    pub fn counters(&self) -> Arc<SimCardCounters> {
        self.counters.clone()
    }

    /// Calibrated card throughput (samples/s) at saturation.
    pub fn projected_throughput_sps(&self) -> f64 {
        1.0 / self.service_s
    }

    /// Calibrated unloaded latency (s).
    pub fn projected_latency_s(&self) -> f64 {
        self.latency_s
    }
}

impl Backend for SimCardBackend {
    fn name(&self) -> &'static str {
        "sim-card"
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn task(&self) -> Task {
        self.engine.task
    }

    /// Numerics through the planned execution engine (bit-identical to
    /// the scalar path at every thread count); timing through the
    /// calibrated card model.
    fn infer(&mut self, batch: &[Vec<u16>]) -> Result<Vec<Vec<f32>>> {
        self.apply_pending_strike();
        self.counters.accrue(batch.len(), self.service_s);
        Ok(self.engine.infer_planned(batch, self.threads))
    }

    fn infer_partials(&mut self, batch: &[Vec<u16>]) -> Result<Vec<Vec<f64>>> {
        self.apply_pending_strike();
        self.counters.accrue(batch.len(), self.service_s);
        Ok(self.engine.partials_planned(batch, self.threads))
    }

    fn set_threads(&mut self, threads: usize) {
        self.threads = threads;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, partition, CompileOptions, PartitionOptions};
    use crate::coordinator::{BatchPolicy, Server};
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    fn program() -> (crate::data::Dataset, CamProgram) {
        let d = by_name("churn").unwrap().generate_n(800);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 8, max_leaves: 16, ..Default::default() },
            None,
        );
        (d, compile(&m, &CompileOptions::default()).unwrap())
    }

    #[test]
    fn card_backend_serves_and_accrues_sim_time() {
        let (d, p) = program();
        let mut backend = SimCardBackend::new(&p, &ChipConfig::default(), &CardConfig::default());
        assert!(backend.projected_throughput_sps() > 0.0);
        assert!(backend.projected_latency_s() > 0.0);
        let counters = backend.counters();
        let bins: Vec<Vec<u16>> = (0..16).map(|i| p.quantizer.bin_row(d.row(i))).collect();
        let logits = backend.infer(&bins).unwrap();
        assert_eq!(logits.len(), 16);
        assert_eq!(counters.samples(), 16);
        assert!(counters.busy_s() > 0.0);
    }

    #[test]
    fn per_shard_cards_serve_through_the_pool() {
        let (d, p) = program();
        let plan = partition(&p, 2, &PartitionOptions::default()).unwrap();
        let cards: Vec<SimCardBackend> = plan
            .shards
            .iter()
            .map(|s| SimCardBackend::new(s, &ChipConfig::default(), &CardConfig::default()))
            .collect();
        let counters: Vec<_> = cards.iter().map(|c| c.counters()).collect();
        let backends: Vec<Box<dyn Backend>> =
            cards.into_iter().map(|c| Box::new(c) as Box<dyn Backend>).collect();
        let server = Server::start_sharded(
            backends,
            plan.base_score.clone(),
            BatchPolicy::default(),
            p.n_features,
        );
        let unsharded = CamEngine::new(&p);
        for i in 0..12 {
            let bins = p.quantizer.bin_row(d.row(i));
            let reply = server.infer_blocking(bins.clone());
            assert_eq!(reply.logits, unsharded.infer_bins(&bins), "row {i}");
        }
        server.shutdown();
        for c in &counters {
            assert_eq!(c.samples(), 12);
        }
    }

    #[test]
    fn mid_serve_defect_strike_switches_to_the_tracked_defective_engine() {
        use crate::cam::DefectSpec;
        let (d, p) = program();
        let injector = DefectInjector::new();
        let mut backend =
            SimCardBackend::new(&p, &ChipConfig::default(), &CardConfig::default())
                .with_injector(injector.clone());
        let bins: Vec<Vec<u16>> = (0..32).map(|i| p.quantizer.bin_row(d.row(i))).collect();

        // Pristine serving == clean engine.
        let clean = CamEngine::new(&p);
        for (i, l) in backend.infer(&bins).unwrap().into_iter().enumerate() {
            assert_eq!(l, clean.infer_bins(&bins[i]), "pristine row {i}");
        }
        assert_eq!(injector.live_draw(), None);
        assert_eq!(injector.strikes_applied(), 0);

        // Strike mid-serve: the next batch must ride the deterministic
        // defective engine for the same (spec, seed) draw.
        let spec = DefectSpec::memristor(0.10);
        injector.strike(spec, 0xC0FE);
        let defective = CamEngine::with_defects(&p, spec, 0xC0FE);
        let logits = backend.infer(&bins).unwrap();
        for (i, l) in logits.iter().enumerate() {
            assert_eq!(*l, defective.infer_bins(&bins[i]), "defective row {i}");
        }
        // At 10% flips the defective card must actually disagree with
        // the clean engine somewhere — otherwise the test proves nothing.
        assert!(
            (0..bins.len()).any(|i| logits[i] != clean.infer_bins(&bins[i])),
            "10% defects produced no observable change"
        );
        assert_eq!(injector.live_draw(), Some((spec, 0xC0FE)));
        assert_eq!(injector.strikes_applied(), 1);
    }
}
