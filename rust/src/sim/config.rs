//! Chip timing configuration (paper §III-C, §IV-B).

/// Timing/geometry parameters of the X-TIME chip. Defaults reproduce the
/// paper's 16 nm design point: 1 GHz clock, 4096 cores, 64-bit flits,
/// λ_CAM = 4 cycles per queued analog CAM array (precharge, MSB search,
/// LSB search, latch) and single-cycle buffer/MMR/SRAM/ACC stages.
#[derive(Clone, Copy, Debug)]
pub struct ChipConfig {
    pub clock_ghz: f64,
    pub n_cores: usize,
    /// NoC flit width in bits (§III-D: S_flit = 64).
    pub flit_bits: usize,
    /// Feature precision in bits (8 for the macro-cell design).
    pub feature_bits: usize,
    /// Cycles per analog CAM array search at 8-bit (2-cycle macro-cell
    /// search + precharge + latch).
    pub lambda_cam_8bit: u64,
    /// Cycles per array search at 4-bit (single search cycle).
    pub lambda_cam_4bit: u64,
    /// Single-cycle pipeline stages after the CAM: buffer, MMR, SRAM, ACC.
    pub post_stages: u64,
    /// Cycles per NoC hop (router traversal).
    pub hop_cycles: u64,
    /// Co-processor decision cycles (threshold compare / per-class argmax
    /// step).
    pub cp_cycles: u64,
    /// Ablation switch (§III-D): when false, routers never accumulate and
    /// every core's logit flit travels to the CP individually — isolating
    /// the benefit of the paper's in-network computing structure.
    pub in_network_reduction: bool,
}

impl Default for ChipConfig {
    fn default() -> Self {
        ChipConfig {
            clock_ghz: 1.0,
            n_cores: 4096,
            flit_bits: 64,
            feature_bits: 8,
            lambda_cam_8bit: 4,
            lambda_cam_4bit: 3,
            post_stages: 4,
            hop_cycles: 1,
            cp_cycles: 2,
            in_network_reduction: true,
        }
    }
}

impl ChipConfig {
    /// λ_CAM for a given feature precision.
    pub fn lambda_cam(&self, n_bits: u8) -> u64 {
        if n_bits > 4 {
            self.lambda_cam_8bit
        } else {
            self.lambda_cam_4bit
        }
    }

    /// H-tree depth (radix-4 levels) for the core count.
    pub fn noc_levels(&self) -> u64 {
        let mut slots = 4usize;
        let mut levels = 1u64;
        while slots < self.n_cores {
            slots *= 4;
            levels += 1;
        }
        levels
    }

    /// Flits needed to broadcast one feature vector downstream.
    pub fn input_flits(&self, n_features: usize) -> u64 {
        ((n_features * self.feature_bits + self.flit_bits - 1) / self.flit_bits) as u64
    }

    /// Core pipeline latency λ_C for a mapped model (§III-C):
    /// queued arrays in series, then buffer/MMR/SRAM/ACC, plus one extra
    /// accumulation cycle per additional tree in the core.
    pub fn core_latency(&self, n_bits: u8, n_segments: usize, n_trees_core: usize) -> u64 {
        let cam = self.lambda_cam(n_bits) * n_segments.max(1) as u64;
        cam + self.post_stages + n_trees_core.saturating_sub(1) as u64
    }

    /// Core initiation interval (Eq. 4/5): a new sample can enter every
    /// `max(λ_CAM, N_trees,core)` cycles (MMR bubbles dominate past 4
    /// trees per core).
    pub fn core_interval(&self, n_bits: u8, n_trees_core: usize) -> u64 {
        self.lambda_cam(n_bits).max(n_trees_core as u64)
    }

    pub fn cycle_ns(&self) -> f64 {
        1.0 / self.clock_ghz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_design_point() {
        let c = ChipConfig::default();
        assert_eq!(c.noc_levels(), 6); // 4096 = 4^6
        // λ_C = 12 for 2 queued arrays, ≤ 4 trees (paper §III-C).
        assert_eq!(c.core_latency(8, 2, 1), 12);
        assert_eq!(c.core_latency(8, 2, 4), 15);
        // Eq. 4: II = 4 cycles → 250 MSamples/s at 1 GHz.
        assert_eq!(c.core_interval(8, 1), 4);
        // Eq. 5: 5 trees/core → II = 5 → 200 MSamples/s.
        assert_eq!(c.core_interval(8, 5), 5);
    }

    #[test]
    fn input_flit_counts() {
        let c = ChipConfig::default();
        assert_eq!(c.input_flits(8), 1); // 64 bits exactly
        assert_eq!(c.input_flits(10), 2);
        assert_eq!(c.input_flits(130), 17);
    }

    #[test]
    fn eq4_eq5_throughput() {
        // τ_C = N_s / (λ_C + II (N_s − 1)) → 250 / 200 MS/s asymptotically.
        let c = ChipConfig::default();
        let n_s = 1_000_000f64;
        let tau4 = n_s / (12.0 + 4.0 * (n_s - 1.0)); // samples per cycle
        assert!((tau4 * 1000.0 - 250.0).abs() < 1.0, "{}", tau4 * 1000.0);
        let tau5 = n_s / (12.0 + 5.0 * (n_s - 1.0));
        assert!((tau5 * 1000.0 - 200.0).abs() < 1.0, "{}", tau5 * 1000.0);
    }
}
