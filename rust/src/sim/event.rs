//! Discrete-event simulation core (SST-equivalent substrate, DESIGN.md S6).
//!
//! The Structural Simulation Toolkit the paper uses is a C++/MPI framework
//! of components connected by links with delays. This module provides the
//! same execution model in-process: a time-ordered event queue with stable
//! FIFO ordering for simultaneous events, and a [`Resource`] helper
//! modelling a serially-occupied unit (port, pipeline slot, link).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A scheduled event: fires at `time` carrying a payload `E`.
#[derive(Clone, Debug)]
struct Scheduled<E> {
    time: u64,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Time-ordered event queue with deterministic tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Scheduled<E>>>,
    seq: u64,
    now: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0 }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Schedule `payload` at absolute `time`. Events scheduled in the past
    /// are clamped to `now` (zero-delay links).
    pub fn schedule(&mut self, time: u64, payload: E) {
        let time = time.max(self.now);
        self.heap.push(Reverse(Scheduled { time, seq: self.seq, payload }));
        self.seq += 1;
    }

    pub fn schedule_in(&mut self, delay: u64, payload: E) {
        self.schedule(self.now + delay, payload);
    }

    /// Pop the next event, advancing time.
    pub fn pop(&mut self) -> Option<(u64, E)> {
        self.heap.pop().map(|Reverse(s)| {
            self.now = s.time;
            (s.time, s.payload)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// A serially-reusable resource (an input port, a NoC link, a pipeline
/// issue slot): requests occupy it for a duration, queuing FIFO.
#[derive(Clone, Copy, Debug, Default)]
pub struct Resource {
    free_at: u64,
    /// Total busy cycles (utilization accounting).
    pub busy: u64,
}

impl Resource {
    pub fn new() -> Resource {
        Resource::default()
    }

    /// Acquire at the earliest time ≥ `at`, holding for `duration`.
    /// Returns the time service *starts*.
    pub fn acquire(&mut self, at: u64, duration: u64) -> u64 {
        let start = at.max(self.free_at);
        self.free_at = start + duration;
        self.busy += duration;
        start
    }

    /// When the resource next becomes free.
    pub fn free_at(&self) -> u64 {
        self.free_at
    }

    /// Utilization over a horizon.
    pub fn utilization(&self, horizon: u64) -> f64 {
        if horizon == 0 {
            0.0
        } else {
            self.busy as f64 / horizon as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30, "c");
        q.schedule(10, "a");
        q.schedule(20, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(10, "a"), (20, "b"), (30, "c")]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        q.schedule(5, 1);
        q.schedule(5, 2);
        q.schedule(5, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut q = EventQueue::new();
        q.schedule(10, "x");
        q.pop();
        q.schedule(3, "late");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 10);
    }

    #[test]
    fn resource_serializes() {
        let mut r = Resource::new();
        assert_eq!(r.acquire(0, 4), 0);
        assert_eq!(r.acquire(1, 4), 4); // queued behind the first
        assert_eq!(r.acquire(100, 4), 100); // idle gap
        assert_eq!(r.busy, 12);
    }

    #[test]
    fn utilization_accounting() {
        let mut r = Resource::new();
        r.acquire(0, 50);
        assert!((r.utilization(100) - 0.5).abs() < 1e-12);
    }
}
