//! Area / power / energy model (paper Fig. 8, §IV-B).
//!
//! Per-component constants are calibrated at the TSMC 16 nm design point
//! so the full-chip totals reproduce the paper's reported envelope:
//! ~19 W peak power dominated by the analog CAM arrays, with peripheral
//! components contributing a small share, and an energy/decision that
//! reaches ~0.3 nJ for small-feature models (§V-B). The *breakdown shape*
//! (aCAM ≫ DAC > SA > digital logic) is the Fig. 8 claim this module
//! regenerates; absolute constants are documented estimates from the
//! paper's references [38][39][51] + PUMA-style logic costs [8].

use super::config::ChipConfig;
use crate::cam::{ARRAY_COLS, CORE_COLS, CORE_ROWS};
use crate::compiler::CamProgram;

// ---- per-device constants (16 nm) -----------------------------------------

/// Analog CAM sub-cell area (2 memristors + 2T compare stack), µm².
pub const SUBCELL_AREA_UM2: f64 = 0.20;
/// Search energy per active sub-cell per search cycle, fJ.
pub const SUBCELL_SEARCH_FJ: f64 = 0.10;
/// 4-bit DAC: area µm² and conversion energy fJ (per conversion) [43].
pub const DAC_AREA_UM2: f64 = 25.0;
pub const DAC_CONV_FJ: f64 = 10.0;
/// Sense amplifier per match line: area µm², latch energy fJ.
pub const SA_AREA_UM2: f64 = 10.0;
pub const SA_LATCH_FJ: f64 = 2.0;
/// SRAM: area per bit µm², read energy per bit fJ.
pub const SRAM_AREA_PER_BIT_UM2: f64 = 0.032;
pub const SRAM_READ_PER_BIT_FJ: f64 = 0.8;
/// Digital logic per core (buffer + MMR + ML-REG + ACC), µm² and fJ/op.
pub const CORE_LOGIC_AREA_UM2: f64 = 520.0;
pub const CORE_LOGIC_OP_FJ: f64 = 15.0;
/// NoC router: area µm², energy per flit-hop fJ (64-bit flit).
pub const ROUTER_AREA_UM2: f64 = 5_000.0;
pub const ROUTER_FLIT_FJ: f64 = 110.0;
/// Co-processor (reduction + argmax + control), mm² and W.
pub const CP_AREA_MM2: f64 = 1.0;
pub const CP_POWER_W: f64 = 0.10;
/// SRAM word width: leaf logit (32 b).
pub const SRAM_WORD_BITS: usize = 32;

/// Fig. 8 component axes.
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    pub acam: f64,
    pub dac: f64,
    pub sa: f64,
    pub sram: f64,
    pub logic: f64,
    pub router: f64,
    pub cp: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.acam + self.dac + self.sa + self.sram + self.logic + self.router + self.cp
    }

    pub fn rows(&self, unit: &str) -> Vec<(String, f64)> {
        vec![
            (format!("aCAM arrays ({unit})"), self.acam),
            (format!("DAC ({unit})"), self.dac),
            (format!("Sense amps ({unit})"), self.sa),
            (format!("SRAM ({unit})"), self.sram),
            (format!("Core logic ({unit})"), self.logic),
            (format!("NoC routers ({unit})"), self.router),
            (format!("Co-processor ({unit})"), self.cp),
        ]
    }
}

/// Routers in a radix-4 H-tree over `n_cores` slots: Σ_l slots/4^l.
fn n_routers(n_cores: usize) -> usize {
    let mut slots = 4usize;
    while slots < n_cores {
        slots *= 4;
    }
    let mut routers = 0usize;
    let mut width = slots;
    while width >= 4 {
        width /= 4;
        routers += width;
    }
    routers
}

/// Full-chip area breakdown, mm² (Fig. 8a).
pub fn chip_area(cfg: &ChipConfig) -> Breakdown {
    let cores = cfg.n_cores as f64;
    let subcells_per_core = (CORE_ROWS * CORE_COLS * 2) as f64;
    let um2_to_mm2 = 1e-6;
    Breakdown {
        acam: cores * subcells_per_core * SUBCELL_AREA_UM2 * um2_to_mm2,
        // One DAC pair (lo/hi line drivers) per column per queued array.
        dac: cores * (CORE_COLS * 2) as f64 * DAC_AREA_UM2 * um2_to_mm2,
        sa: cores * CORE_ROWS as f64 * SA_AREA_UM2 * um2_to_mm2,
        sram: cores * (CORE_ROWS * SRAM_WORD_BITS) as f64 * SRAM_AREA_PER_BIT_UM2 * um2_to_mm2,
        logic: cores * CORE_LOGIC_AREA_UM2 * um2_to_mm2,
        router: n_routers(cfg.n_cores) as f64 * ROUTER_AREA_UM2 * um2_to_mm2,
        cp: CP_AREA_MM2,
    }
}

/// Full-chip *peak* power breakdown, W (Fig. 8b): every core searching
/// every cycle with all match lines charged.
pub fn chip_peak_power(cfg: &ChipConfig) -> Breakdown {
    let hz = cfg.clock_ghz * 1e9;
    let cores = cfg.n_cores as f64;
    let fj_to_w = 1e-15 * hz;
    // At peak, each queued array completes a search every λ_CAM cycles;
    // both search cycles of the macro-cell burn sub-cell energy.
    let searches_per_cycle = 2.0 / cfg.lambda_cam_8bit as f64;
    let subcells_per_core = (CORE_ROWS * CORE_COLS * 2) as f64;
    Breakdown {
        acam: cores * subcells_per_core * SUBCELL_SEARCH_FJ * searches_per_cycle * fj_to_w,
        dac: cores * (CORE_COLS * 2) as f64 * DAC_CONV_FJ / cfg.lambda_cam_8bit as f64 * fj_to_w,
        sa: cores * CORE_ROWS as f64 * SA_LATCH_FJ / cfg.lambda_cam_8bit as f64 * fj_to_w,
        sram: cores * SRAM_WORD_BITS as f64 * SRAM_READ_PER_BIT_FJ / cfg.lambda_cam_8bit as f64
            * fj_to_w,
        logic: cores * CORE_LOGIC_OP_FJ * fj_to_w,
        router: n_routers(cfg.n_cores) as f64 * ROUTER_FLIT_FJ * fj_to_w,
        cp: CP_POWER_W,
    }
}

/// Dynamic activity counters for one inference, produced by the cycle
/// simulator / functional engine.
#[derive(Clone, Copy, Debug, Default)]
pub struct Activity {
    /// Sub-cell search events: Σ over segments of charged_rows × segment
    /// columns × 2 sub-cells × search cycles.
    pub subcell_searches: f64,
    /// DAC conversions (columns driven × cores).
    pub dac_conversions: f64,
    /// Match lines latched.
    pub sa_latches: f64,
    /// SRAM word reads (matched leaves).
    pub sram_reads: f64,
    /// Core logic ops (MMR iterations + accumulations).
    pub logic_ops: f64,
    /// NoC flit-hops (downstream broadcast + upstream reduction).
    pub flit_hops: f64,
}

impl Activity {
    /// Estimate activity for one sample of a compiled program, assuming
    /// first segments charge all mapped rows and later segments only the
    /// per-tree matched candidates (`avg_charged` from the functional
    /// engine when available, else a conservative all-rows estimate).
    /// Capacity-compressed programs (`CamProgram::layouts`) charge their
    /// *physical* word count — match lines and sub-cells exist per word,
    /// not per logical row — which is where the Fig. 8 compressed-energy
    /// delta comes from; leaf reads and MMR/accumulate ops stay per
    /// logical tree, since compression never changes what is computed
    /// (contract 11).
    pub fn estimate(program: &CamProgram, cfg: &ChipConfig, avg_charged_frac: f64) -> Activity {
        let search_cycles = if program.n_bits > 4 { 2.0 } else { 1.0 };
        let n_segments = program.n_features.div_ceil(ARRAY_COLS).max(1);
        let mut a = Activity::default();
        for (ci, core) in program.cores.iter().enumerate() {
            let rows = program.phys_rows(ci) as f64;
            // Segment 1 charges all rows; subsequent segments only the
            // surviving fraction.
            let mut charged = rows;
            for s in 0..n_segments {
                let cols = if s + 1 < n_segments {
                    ARRAY_COLS
                } else {
                    program.n_features - ARRAY_COLS * (n_segments - 1)
                } as f64;
                a.subcell_searches += charged * cols * 2.0 * search_cycles;
                charged = (rows * avg_charged_frac).max(core.trees.len() as f64);
            }
            a.dac_conversions += (program.n_features * 2) as f64;
            a.sa_latches += rows;
            a.sram_reads += core.trees.len() as f64;
            a.logic_ops += 2.0 * core.trees.len() as f64;
        }
        // Broadcast: input flits travel down all levels; reduction: one
        // flit per class per level per replica-subtree (upper bound:
        // levels × n_outputs × cores as merge traffic).
        let levels = cfg.noc_levels() as f64;
        a.flit_hops += cfg.input_flits(program.n_features) as f64 * levels;
        a.flit_hops += program.task.n_outputs() as f64 * levels;
        a
    }

    /// Dynamic energy in nJ for this activity.
    pub fn energy_nj(&self) -> f64 {
        let fj = self.subcell_searches * SUBCELL_SEARCH_FJ
            + self.dac_conversions * DAC_CONV_FJ
            + self.sa_latches * SA_LATCH_FJ
            + self.sram_reads * (SRAM_WORD_BITS as f64 * SRAM_READ_PER_BIT_FJ)
            + self.logic_ops * CORE_LOGIC_OP_FJ
            + self.flit_hops * ROUTER_FLIT_FJ;
        fj * 1e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    #[test]
    fn peak_power_matches_paper_envelope() {
        let p = chip_peak_power(&ChipConfig::default());
        let total = p.total();
        // Paper: 19 W peak, "comparable to GPU idle power (~25 W)".
        assert!((15.0..23.0).contains(&total), "peak power {total} W");
        // aCAM dominates (Fig. 8b): > 55% of total.
        assert!(p.acam / total > 0.55, "aCAM share {}", p.acam / total);
        // Every peripheral is individually smaller than the aCAM share.
        for (name, v) in p.rows("W") {
            if !name.starts_with("aCAM") {
                assert!(v < p.acam, "{name} = {v} ≥ aCAM {}", p.acam);
            }
        }
    }

    #[test]
    fn area_dominated_by_acam() {
        let a = chip_area(&ChipConfig::default());
        let total = a.total();
        assert!((40.0..120.0).contains(&total), "area {total} mm²");
        assert!(a.acam / total > 0.5, "aCAM share {}", a.acam / total);
    }

    #[test]
    fn energy_per_decision_small_model() {
        // Churn-like model: ~404 trees × 256 leaves... use a smaller
        // trained model and scale-check the order of magnitude per §V-B
        // (0.3 nJ/Dec reachable for small-feature models).
        let d = by_name("churn").unwrap().generate_n(1000);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 20, max_leaves: 16, ..Default::default() },
            None,
        );
        let prog = compile(&m, &CompileOptions::default()).unwrap();
        let act = Activity::estimate(&prog, &ChipConfig::default(), 0.05);
        let e = act.energy_nj();
        assert!((0.001..50.0).contains(&e), "energy {e} nJ");
    }

    #[test]
    fn energy_scales_with_model_size() {
        let d = by_name("churn").unwrap().generate_n(800);
        let small = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 4, max_leaves: 8, ..Default::default() },
            None,
        );
        let big = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 40, max_leaves: 32, ..Default::default() },
            None,
        );
        let cfg = ChipConfig::default();
        let e_small =
            Activity::estimate(&compile(&small, &CompileOptions::default()).unwrap(), &cfg, 0.05)
                .energy_nj();
        let e_big =
            Activity::estimate(&compile(&big, &CompileOptions::default()).unwrap(), &cfg, 0.05)
                .energy_nj();
        assert!(e_big > e_small, "{e_big} ≤ {e_small}");
    }

    #[test]
    fn compression_lowers_search_energy() {
        let d = by_name("churn").unwrap().generate_n(1000);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 20, max_leaves: 16, ..Default::default() },
            None,
        );
        let plain = compile(&m, &CompileOptions::default()).unwrap();
        let pressed = compile(
            &m,
            &CompileOptions { compress: true, ..Default::default() },
        )
        .unwrap();
        let cfg = ChipConfig::default();
        let e_plain = Activity::estimate(&plain, &cfg, 0.05);
        let e_pressed = Activity::estimate(&pressed, &cfg, 0.05);
        assert!(
            pressed.total_phys_rows() < plain.total_rows(),
            "compression should drop physical rows on a real model"
        );
        assert!(
            e_pressed.subcell_searches < e_plain.subcell_searches,
            "fewer physical words must charge fewer sub-cells: {} ≥ {}",
            e_pressed.subcell_searches,
            e_plain.subcell_searches
        );
        assert!(e_pressed.energy_nj() < e_plain.energy_nj());
        // The computed work is untouched: same leaf reads, same MMR ops.
        assert_eq!(e_pressed.sram_reads, e_plain.sram_reads);
        assert_eq!(e_pressed.logic_ops, e_plain.logic_ops);
    }

    #[test]
    fn router_count_formula() {
        assert_eq!(n_routers(4096), 1365);
        assert_eq!(n_routers(16), 5);
        assert_eq!(n_routers(4), 1);
    }
}
