//! L4 wire serving: the framed-TCP front end over the model
//! [`crate::coordinator::Fleet`], its blocking client, and the
//! open-loop load generator behind `xtime loadgen`.
//!
//! The paper's headline serving numbers (119× throughput, §IV) are
//! socket-to-socket figures; this module is the layer that turns the
//! in-process fleet into something those numbers can be measured
//! against. Design points (DESIGN.md §6, ADR-004):
//!
//! * **length-prefixed binary frames** ([`frame`]) — no heavy
//!   serialization dependency; f32 feature/logit bits cross the wire
//!   verbatim, which is what makes wire-vs-in-process bit-identity
//!   (contract 7) testable at all;
//! * **lazy request parse** ([`frame::RequestView`]) — header fields
//!   (tenant, row count, arity) are validated without reading payload
//!   bytes, so admission decisions happen *before* feature
//!   deserialization;
//! * **backpressure = admission** ([`listener`]) — the listener claims
//!   a fleet `QueueTicket` per row straight off the header; refused
//!   rows are answered `Shed` without their payload ever being decoded,
//!   so a stalled backend sheds wire load at header-scan cost;
//! * **open-loop load** ([`loadgen`]) — seeded Poisson arrivals,
//!   skewed tenant mix, connection churn, latency measured from
//!   scheduled arrival (no coordinated omission), reported as
//!   `BENCH_serving.json`.
//!
//! Loopback round trip:
//!
//! ```
//! use std::sync::Arc;
//! use xtime::bench_support::random_ensemble;
//! use xtime::compiler::{compile, CompileOptions};
//! use xtime::coordinator::{Fleet, ModelConfig};
//! use xtime::data::Task;
//! use xtime::serve::{RowOutcome, WireClient, WireServer};
//!
//! let model = random_ensemble(8, 3, 4, Task::Binary, 1);
//! let program = compile(&model, &CompileOptions::default()).unwrap();
//! let fleet = Arc::new(Fleet::new());
//! fleet.register_program("m", &program, ModelConfig::for_program(&program)).unwrap();
//!
//! let server = WireServer::start(fleet.clone(), "127.0.0.1:0").unwrap();
//! let mut client = WireClient::connect(&server.local_addr().to_string()).unwrap();
//! let reply = client.request("m", &[vec![0.1, 0.5, 0.9, 0.25]]).unwrap();
//! assert!(matches!(reply.rows[0], RowOutcome::Served { .. }));
//!
//! server.shutdown(); // joins the accept loop and every connection
//! Arc::try_unwrap(fleet).ok().unwrap().shutdown();
//! ```

// Panic-path lint spine: serving threads must not unwind on peer input
// or lock poisoning. Every surviving `unwrap`/`expect` in this module
// tree carries an `#[allow]` with the invariant that makes it
// infallible; fallible paths return typed errors or per-row `Failed`
// outcomes instead.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod client;
pub mod frame;
pub mod listener;
pub mod loadgen;

pub use client::{BatchReply, RetryPolicy, WireClient};
pub use frame::{
    decode_reply, encode_reply, encode_request, read_frame, write_frame, ReplyFrame,
    RequestView, RowOutcome, WireError, MAGIC_REPLY, MAGIC_REQUEST, MAX_FRAME_BYTES,
    WIRE_VERSION,
};
pub use listener::{WireServer, WireStats};
pub use loadgen::{LoadReport, LoadgenConfig, TenantOutcome, TenantSpec};
