//! Framed-TCP front end over the model [`Fleet`].
//!
//! One accept thread, one handler thread per connection. The handler
//! reads a frame, parses only its *header* ([`RequestView::parse`]),
//! and claims admission slots ([`RouteHandle::try_admit`]) row by row —
//! feature payload bytes are deserialized **only** for rows that were
//! admitted, so a saturated route sheds wire traffic at header-scan
//! cost (the shed-before-parse contract, DESIGN.md §6). Socket
//! backpressure thus maps directly onto the fleet's `QueueTicket`
//! gauge: a stalled backend fills the route's bounded queue, the
//! listener's `try_admit` starts refusing, and clients see `Shed` row
//! outcomes instead of unbounded buffering anywhere in the server.
//!
//! Error containment is per connection: a malformed or oversized frame
//! gets a protocol-error reply and closes *that* connection; unknown
//! tenants, arity mismatches and zero-row batches get a `Rejected`
//! reply and the connection stays usable. Neither path can panic a
//! handler or wedge the accept loop.

use super::frame::{
    encode_protocol_error, encode_rejected, encode_reply, write_frame, RequestView,
    RowOutcome, MAX_FRAME_BYTES,
};
use crate::coordinator::{Fleet, RouteHandle};
use std::io::{self, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Poll interval for the stop flag on otherwise-blocking reads.
const POLL_TIMEOUT: Duration = Duration::from_millis(100);
/// A connection that goes quiet *mid-frame* for this long is dropped
/// (a peer that sent a length prefix owes the body; an idle peer
/// between frames is fine and waits forever).
const MID_FRAME_DEADLINE: Duration = Duration::from_secs(10);

/// Point-in-time counters for one [`WireServer`].
#[derive(Clone, Debug, Default)]
pub struct WireStats {
    /// Connections accepted over the listener's lifetime.
    pub connections: u64,
    /// Well-formed request frames handled (including rejected ones).
    pub frames: u64,
    /// Rows offered across all request frames.
    pub rows_offered: u64,
    /// Rows that claimed an admission slot.
    pub rows_admitted: u64,
    /// Rows refused at the queue bound.
    pub rows_shed: u64,
    /// Rows whose feature payload was actually deserialized. The
    /// shed-before-parse contract: `rows_decoded == rows_admitted`
    /// always — shed rows never touch payload bytes.
    pub rows_decoded: u64,
    /// Connections torn down on a malformed/oversized/truncated frame.
    pub protocol_errors: u64,
    /// Well-framed requests refused whole (unknown tenant, arity
    /// mismatch, zero rows); their connections stayed up.
    pub rejected_frames: u64,
}

#[derive(Default)]
struct Counters {
    connections: AtomicU64,
    frames: AtomicU64,
    rows_offered: AtomicU64,
    rows_admitted: AtomicU64,
    rows_shed: AtomicU64,
    rows_decoded: AtomicU64,
    protocol_errors: AtomicU64,
    rejected_frames: AtomicU64,
}

/// The TCP front end: owns the accept thread and all connection
/// handlers. Dropping it without [`WireServer::shutdown`] leaks the
/// threads (they hold an `Arc<Fleet>`), so shut it down explicitly.
pub struct WireServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    counters: Arc<Counters>,
    accept_thread: JoinHandle<()>,
}

impl WireServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7711"`; port 0 picks a free port —
    /// read it back with [`WireServer::local_addr`]) and start serving
    /// `fleet` until [`WireServer::shutdown`].
    pub fn start(fleet: Arc<Fleet>, addr: &str) -> io::Result<WireServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(Counters::default());
        let accept_thread = {
            let stop = stop.clone();
            let counters = counters.clone();
            thread::Builder::new()
                .name("wire-accept".to_string())
                .spawn(move || accept_loop(listener, fleet, stop, counters))?
        };
        Ok(WireServer { addr, stop, counters, accept_thread })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot the listener's counters.
    pub fn stats(&self) -> WireStats {
        let c = &self.counters;
        WireStats {
            connections: c.connections.load(Ordering::Relaxed),
            frames: c.frames.load(Ordering::Relaxed),
            rows_offered: c.rows_offered.load(Ordering::Relaxed),
            rows_admitted: c.rows_admitted.load(Ordering::Relaxed),
            rows_shed: c.rows_shed.load(Ordering::Relaxed),
            rows_decoded: c.rows_decoded.load(Ordering::Relaxed),
            protocol_errors: c.protocol_errors.load(Ordering::Relaxed),
            rejected_frames: c.rejected_frames.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting, join the accept thread and every connection
    /// handler. In-flight requests finish first (handlers only exit at
    /// frame boundaries or on their read timeout noticing the flag), so
    /// no admitted row is abandoned by the front end. The fleet itself
    /// is not shut down — it belongs to the caller.
    pub fn shutdown(self) {
        self.stop.store(true, Ordering::Release);
        // Wake the blocking accept with a no-op connection; the loop
        // re-checks the flag before handling it.
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept_thread.join();
    }
}

fn accept_loop(
    listener: TcpListener,
    fleet: Arc<Fleet>,
    stop: Arc<AtomicBool>,
    counters: Arc<Counters>,
) {
    // Handler threads are reaped lazily each accept; the remainder are
    // joined on shutdown so `WireServer::shutdown` returns only when
    // every connection is done.
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    for conn in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            break;
        }
        handlers.retain(|h| !h.is_finished());
        match conn {
            Ok(stream) => {
                counters.connections.fetch_add(1, Ordering::Relaxed);
                let fleet = fleet.clone();
                let stop = stop.clone();
                let counters = counters.clone();
                match thread::Builder::new().name("wire-conn".to_string()).spawn(move || {
                    // A handler failure (peer reset, mid-frame EOF)
                    // is contained to this connection.
                    let _ = handle_connection(stream, &fleet, &stop, &counters);
                }) {
                    Ok(h) => handlers.push(h),
                    // Thread exhaustion is transient like EMFILE below:
                    // drop this connection (the stream closes, the peer
                    // sees a reset) and keep accepting.
                    Err(_) => continue,
                }
            }
            // Transient accept errors (e.g. EMFILE, aborted handshake)
            // must not kill the loop.
            Err(_) => continue,
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// Serve one connection until clean EOF, a protocol error, or shutdown.
fn handle_connection(
    stream: TcpStream,
    fleet: &Fleet,
    stop: &AtomicBool,
    counters: &Counters,
) -> io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(POLL_TIMEOUT))?;
    let mut reader = stream.try_clone()?;
    let mut writer = stream;
    loop {
        let body = match read_frame_interruptible(&mut reader, stop) {
            Ok(Some(body)) => body,
            // Clean EOF at a frame boundary, or shutdown while idle.
            Ok(None) => return Ok(()),
            Err(e) => {
                // Truncated / oversized / mid-frame disconnect: tell the
                // peer if it is still there, then drop the connection.
                counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let _ = write_frame(&mut writer, &encode_protocol_error(0, &e.to_string()));
                return Ok(());
            }
        };
        let view = match RequestView::parse(&body) {
            Ok(view) => view,
            Err(e) => {
                counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let _ = write_frame(&mut writer, &encode_protocol_error(0, &e.to_string()));
                return Ok(());
            }
        };
        counters.frames.fetch_add(1, Ordering::Relaxed);
        let reply = handle_request(&view, fleet, counters);
        if write_frame(&mut writer, &reply).is_err() {
            // Peer went away while we served its batch; nothing to do —
            // admitted rows were still answered by the fleet.
            return Ok(());
        }
    }
}

/// Serve one well-framed request, returning the encoded reply frame.
/// This is the shed-before-parse core: admission slots are claimed from
/// the header-only [`RequestView`], and `view.row(i)` — the only place
/// feature bytes are deserialized — runs solely for admitted rows.
fn handle_request(view: &RequestView<'_>, fleet: &Fleet, counters: &Counters) -> Vec<u8> {
    counters.rows_offered.fetch_add(view.n_rows as u64, Ordering::Relaxed);
    let handle: RouteHandle<'_> = match fleet.handle(view.tenant) {
        Ok(h) => h,
        Err(e) => {
            counters.rejected_frames.fetch_add(1, Ordering::Relaxed);
            return encode_rejected(view.id, &e);
        }
    };
    if view.n_rows == 0 {
        counters.rejected_frames.fetch_add(1, Ordering::Relaxed);
        return encode_rejected(view.id, "empty batch: a request must carry at least one row");
    }
    if let Err(e) = handle.check_arity(view.n_features) {
        counters.rejected_frames.fetch_add(1, Ordering::Relaxed);
        return encode_rejected(view.id, &e);
    }

    // Phase 1: claim slots row by row, decoding only admitted rows.
    let mut outcomes: Vec<Option<RowOutcome>> = vec![None; view.n_rows];
    let mut pending: Vec<(usize, Receiver<crate::coordinator::Reply>)> = Vec::new();
    for i in 0..view.n_rows {
        match handle.try_admit() {
            Some(slot) => {
                counters.rows_admitted.fetch_add(1, Ordering::Relaxed);
                counters.rows_decoded.fetch_add(1, Ordering::Relaxed);
                let row = view.row(i);
                pending.push((i, handle.submit_admitted(slot, &row)));
            }
            None => {
                counters.rows_shed.fetch_add(1, Ordering::Relaxed);
                outcomes[i] =
                    Some(RowOutcome::Shed { queue_depth: handle.queue_cap() as u32 });
            }
        }
    }

    // Phase 2: wait for every admitted row's reply (the drain contract
    // guarantees each channel is answered, even across a swap).
    for (i, rx) in pending {
        outcomes[i] = Some(match rx.recv() {
            Ok(reply) => match reply.error {
                None => RowOutcome::Served {
                    prediction: reply.prediction,
                    logits: reply.logits,
                },
                Some(error) => RowOutcome::Failed { error },
            },
            Err(_) => RowOutcome::Failed {
                error: "worker dropped the request".to_string(),
            },
        });
    }
    // Every slot was filled by the shed/submit/reply arms above; an
    // unresolved row would be a dispatch bug — contain it to this row
    // as a `Failed` outcome instead of tearing down the connection.
    let rows: Vec<RowOutcome> = outcomes
        .into_iter()
        .map(|o| {
            o.unwrap_or_else(|| RowOutcome::Failed {
                error: "internal: row outcome unresolved".to_string(),
            })
        })
        .collect();
    encode_reply(view.id, handle.queue_depth() as u32, &rows)
}

/// [`super::frame::read_frame`] over a socket with a read timeout: while *idle*
/// (waiting for a length prefix), timeouts just re-check the stop flag;
/// once a prefix has arrived the peer owes the body and gets
/// [`MID_FRAME_DEADLINE`] of cumulative silence before the connection
/// is declared truncated.
fn read_frame_interruptible(
    stream: &mut TcpStream,
    stop: &AtomicBool,
) -> io::Result<Option<Vec<u8>>> {
    let mut prefix = [0u8; 4];
    let mut filled = 0;
    // Idle phase: nothing read yet — shutdown exits cleanly.
    while filled == 0 {
        if stop.load(Ordering::Acquire) {
            return Ok(None);
        }
        match stream.read(&mut prefix) {
            Ok(0) => return Ok(None), // clean EOF between frames
            Ok(n) => filled = n,
            Err(e) if is_timeout(&e) => continue,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    // Committed phase: a frame has started; finish it or fail.
    read_remainder(stream, &mut prefix[filled..])?;
    let len = u32::from_le_bytes(prefix) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {MAX_FRAME_BYTES}-byte frame ceiling"),
        ));
    }
    let mut body = vec![0u8; len];
    read_remainder(stream, &mut body)?;
    Ok(Some(body))
}

/// `read_exact` under a read timeout: retries timeouts until
/// [`MID_FRAME_DEADLINE`] of cumulative mid-frame silence, and treats
/// EOF as truncation (we are mid-frame by construction).
fn read_remainder(stream: &mut TcpStream, buf: &mut [u8]) -> io::Result<()> {
    let deadline = Instant::now() + MID_FRAME_DEADLINE;
    let mut filled = 0;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!(
                        "peer disconnected {filled} bytes into a {}-byte frame section",
                        buf.len()
                    ),
                ))
            }
            Ok(n) => filled += n,
            Err(e) if is_timeout(&e) || e.kind() == io::ErrorKind::Interrupted => {
                if Instant::now() >= deadline {
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        "peer stalled mid-frame",
                    ));
                }
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Read timeouts surface as `WouldBlock` or `TimedOut` depending on the
/// platform; treat both as "keep polling".
fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}
