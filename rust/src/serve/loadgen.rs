//! Open-loop multi-tenant load generator for the wire front end.
//!
//! `xtime loadgen` drives a [`super::listener::WireServer`] the way the
//! paper's serving claim imagines traffic: many independent clients, a
//! skewed tenant mix, arrivals that do **not** slow down because the
//! server is slow. Each worker connection schedules its requests by a
//! seeded Poisson process (exponential inter-arrivals at its share of
//! the aggregate rate) and measures latency from the *scheduled*
//! arrival time, not the send time — so when the server falls behind,
//! the queueing delay a real open-loop client would have seen lands in
//! the tail percentiles instead of being silently absorbed
//! (coordinated omission). Workers reconnect every
//! [`LoadgenConfig::churn_every`] requests to keep the accept loop and
//! per-connection state under churn, and the whole run is deterministic
//! in its request sequence given [`LoadgenConfig::seed`].
//!
//! The run report aggregates per-tenant row accounting
//! (`offered == served + shed + failed`) and latency tails, and
//! [`report_json`] renders the stable-keyed `BENCH_serving.json` schema
//! (docs/BENCHMARKS.md).

use super::client::WireClient;
use super::frame::RowOutcome;
use crate::bench_support::{fast_mode, latency_tail_json};
use crate::util::{Json, Rng};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One tenant of the generated mix.
pub struct TenantSpec {
    /// Registered model name on the serving side.
    pub name: String,
    /// Request rows, cycled per worker (must be non-empty, all rows of
    /// the tenant model's arity).
    pub rows: Vec<Vec<f32>>,
    /// Relative share of the mix (> 0).
    pub weight: usize,
}

/// Load-generator run parameters.
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:7711`.
    pub addr: String,
    pub tenants: Vec<TenantSpec>,
    /// Total requests across all connections.
    pub requests: usize,
    /// Aggregate arrival rate (requests/s) split evenly across
    /// connections. `0` disables pacing: every worker sends back to
    /// back (maximum closed-loop pressure).
    pub rate_rps: f64,
    /// Concurrent worker connections.
    pub conns: usize,
    /// Rows per request frame.
    pub batch: usize,
    /// Reconnect each worker after this many requests (0 = never).
    pub churn_every: usize,
    pub seed: u64,
}

/// Per-tenant accounting for one run; rows, not requests
/// (`offered == served + shed + failed`).
#[derive(Clone, Debug, Default)]
pub struct TenantOutcome {
    pub offered_rows: u64,
    pub served_rows: u64,
    pub shed_rows: u64,
    pub failed_rows: u64,
    /// One sample per *request* that got a batch reply, in seconds from
    /// scheduled arrival to decoded reply.
    pub latencies: Vec<f64>,
}

impl TenantOutcome {
    pub fn shed_rate(&self) -> f64 {
        if self.offered_rows == 0 {
            0.0
        } else {
            self.shed_rows as f64 / self.offered_rows as f64
        }
    }

    fn absorb(&mut self, other: TenantOutcome) {
        self.offered_rows += other.offered_rows;
        self.served_rows += other.served_rows;
        self.shed_rows += other.shed_rows;
        self.failed_rows += other.failed_rows;
        self.latencies.extend(other.latencies);
    }
}

/// Aggregated outcome of a [`run`].
pub struct LoadReport {
    /// Wall-clock seconds for the whole run.
    pub wall_s: f64,
    /// Requests that failed at the transport/protocol level (their rows
    /// are counted as `failed_rows` on the tenant).
    pub request_errors: u64,
    /// Per-tenant accounting, name-keyed.
    pub tenants: BTreeMap<String, TenantOutcome>,
}

impl LoadReport {
    /// Row totals across tenants.
    pub fn totals(&self) -> TenantOutcome {
        let mut t = TenantOutcome::default();
        for o in self.tenants.values() {
            t.absorb(o.clone());
        }
        t
    }
}

/// Run the load generator to completion.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport, String> {
    if cfg.tenants.is_empty() {
        return Err("loadgen needs at least one tenant".to_string());
    }
    if cfg.tenants.iter().any(|t| t.rows.is_empty() || t.weight == 0) {
        return Err("every tenant needs rows and a positive weight".to_string());
    }
    if cfg.conns == 0 || cfg.batch == 0 {
        return Err("conns and batch must be positive".to_string());
    }
    let mut root = Rng::new(cfg.seed);
    let worker_seeds: Vec<u64> =
        (0..cfg.conns).map(|_| root.next_u64()).collect();
    let t0 = Instant::now();
    let partials: Vec<Result<WorkerOutcome, String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.conns)
            .map(|w| {
                // Spread the remainder so worker request counts sum to
                // exactly `cfg.requests`.
                let n = cfg.requests / cfg.conns
                    + usize::from(w < cfg.requests % cfg.conns);
                let seed = worker_seeds[w];
                scope.spawn(move || worker(cfg, n, seed))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err("worker panicked".to_string())))
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let mut tenants: BTreeMap<String, TenantOutcome> = cfg
        .tenants
        .iter()
        .map(|t| (t.name.clone(), TenantOutcome::default()))
        .collect();
    let mut request_errors = 0u64;
    for p in partials {
        let p = p?;
        request_errors += p.request_errors;
        for (spec, outcome) in cfg.tenants.iter().zip(p.per_tenant) {
            // The map was built from this same `cfg.tenants` iteration,
            // so every spec name is present.
            if let Some(t) = tenants.get_mut(&spec.name) {
                t.absorb(outcome);
            }
        }
    }
    Ok(LoadReport { wall_s, request_errors, tenants })
}

struct WorkerOutcome {
    /// Indexed like `cfg.tenants`.
    per_tenant: Vec<TenantOutcome>,
    request_errors: u64,
}

/// One worker connection: open-loop schedule, weighted tenant picks,
/// connection churn, reconnect-and-continue on request errors.
fn worker(cfg: &LoadgenConfig, n_requests: usize, seed: u64) -> Result<WorkerOutcome, String> {
    let mut rng = Rng::new(seed);
    let per_conn_rate = cfg.rate_rps / cfg.conns as f64;
    let total_weight: usize = cfg.tenants.iter().map(|t| t.weight).sum();
    let mut per_tenant: Vec<TenantOutcome> =
        cfg.tenants.iter().map(|_| TenantOutcome::default()).collect();
    let mut offsets = vec![0usize; cfg.tenants.len()];
    let mut request_errors = 0u64;
    let mut client = WireClient::connect(&cfg.addr)
        .map_err(|e| format!("connecting to {}: {e}", cfg.addr))?;
    let start = Instant::now();
    let mut scheduled = 0.0f64; // seconds since `start`
    for r in 0..n_requests {
        if per_conn_rate > 0.0 {
            // Exponential inter-arrival: a Poisson process per worker
            // (superposed across workers, still Poisson at the server).
            scheduled += -rng.f64().max(f64::MIN_POSITIVE).ln() / per_conn_rate;
            let now = start.elapsed().as_secs_f64();
            if scheduled > now {
                std::thread::sleep(Duration::from_secs_f64(scheduled - now));
            }
        } else {
            scheduled = start.elapsed().as_secs_f64();
        }
        if cfg.churn_every > 0 && r > 0 && r % cfg.churn_every == 0 {
            client = WireClient::connect(&cfg.addr)
                .map_err(|e| format!("reconnecting to {}: {e}", cfg.addr))?;
        }
        // Weighted tenant pick, then `batch` rows cycled from its pool.
        let mut pick = rng.below(total_weight);
        let mut ti = 0usize;
        while pick >= cfg.tenants[ti].weight {
            pick -= cfg.tenants[ti].weight;
            ti += 1;
        }
        let spec = &cfg.tenants[ti];
        let rows: Vec<Vec<f32>> = (0..cfg.batch)
            .map(|k| spec.rows[(offsets[ti] + k) % spec.rows.len()].clone())
            .collect();
        offsets[ti] = (offsets[ti] + cfg.batch) % spec.rows.len();
        let out = &mut per_tenant[ti];
        out.offered_rows += cfg.batch as u64;
        match client.request(&spec.name, &rows) {
            Ok(reply) => {
                for row in &reply.rows {
                    match row {
                        RowOutcome::Served { .. } => out.served_rows += 1,
                        RowOutcome::Shed { .. } => out.shed_rows += 1,
                        RowOutcome::Failed { .. } => out.failed_rows += 1,
                    }
                }
                out.latencies.push(start.elapsed().as_secs_f64() - scheduled);
            }
            Err(_) => {
                // Transport/protocol failure: the whole request's rows
                // are lost; reconnect and keep the schedule.
                request_errors += 1;
                out.failed_rows += cfg.batch as u64;
                client = WireClient::connect(&cfg.addr)
                    .map_err(|e| format!("reconnecting to {}: {e}", cfg.addr))?;
            }
        }
    }
    Ok(WorkerOutcome { per_tenant, request_errors })
}

/// Render the `BENCH_serving.json` schema (docs/BENCHMARKS.md): run
/// parameters, per-tenant row accounting + latency tails, and totals.
pub fn report_json(cfg: &LoadgenConfig, report: &LoadReport) -> Json {
    let totals = report.totals();
    let mut tenants = Json::obj();
    for (name, o) in &report.tenants {
        let weight = cfg
            .tenants
            .iter()
            .find(|t| &t.name == name)
            .map_or(0, |t| t.weight);
        tenants.set(name, outcome_json(o, Some(weight)));
    }
    let mut j = Json::obj();
    j.set("bench", Json::Str("serving".to_string()))
        .set("fast_mode", Json::Bool(fast_mode()))
        .set("addr", Json::Str(cfg.addr.clone()))
        .set("requests", Json::Num(cfg.requests as f64))
        .set("rate_rps", Json::Num(cfg.rate_rps))
        .set("conns", Json::Num(cfg.conns as f64))
        .set("batch", Json::Num(cfg.batch as f64))
        .set("churn_every", Json::Num(cfg.churn_every as f64))
        .set("seed", Json::Num(cfg.seed as f64))
        .set("wall_s", Json::Num(report.wall_s))
        .set(
            "rows_per_s",
            Json::Num(if report.wall_s > 0.0 {
                totals.offered_rows as f64 / report.wall_s
            } else {
                0.0
            }),
        )
        .set("request_errors", Json::Num(report.request_errors as f64))
        .set("tenants", tenants)
        .set("total", outcome_json(&totals, None));
    j
}

fn outcome_json(o: &TenantOutcome, weight: Option<usize>) -> Json {
    let mut j = Json::obj();
    if let Some(w) = weight {
        j.set("weight", Json::Num(w as f64));
    }
    j.set("offered_rows", Json::Num(o.offered_rows as f64))
        .set("served_rows", Json::Num(o.served_rows as f64))
        .set("shed_rows", Json::Num(o.shed_rows as f64))
        .set("failed_rows", Json::Num(o.failed_rows as f64))
        .set("shed_rate", Json::Num(o.shed_rate()))
        .set("latency_s", latency_tail_json(&o.latencies));
    j
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn outcome_accounting_and_shed_rate() {
        let mut a = TenantOutcome {
            offered_rows: 10,
            served_rows: 6,
            shed_rows: 3,
            failed_rows: 1,
            latencies: vec![0.1, 0.2],
        };
        a.absorb(TenantOutcome {
            offered_rows: 10,
            served_rows: 10,
            shed_rows: 0,
            failed_rows: 0,
            latencies: vec![0.3],
        });
        assert_eq!(a.offered_rows, a.served_rows + a.shed_rows + a.failed_rows);
        assert!((a.shed_rate() - 0.15).abs() < 1e-12);
        assert_eq!(a.latencies.len(), 3);
        assert_eq!(TenantOutcome::default().shed_rate(), 0.0);
    }

    #[test]
    fn report_json_has_stable_keys() {
        let cfg = LoadgenConfig {
            addr: "127.0.0.1:0".to_string(),
            tenants: vec![TenantSpec {
                name: "churn".to_string(),
                rows: vec![vec![0.5]],
                weight: 2,
            }],
            requests: 4,
            rate_rps: 100.0,
            conns: 1,
            batch: 2,
            churn_every: 0,
            seed: 7,
        };
        let mut tenants = BTreeMap::new();
        tenants.insert(
            "churn".to_string(),
            TenantOutcome {
                offered_rows: 8,
                served_rows: 5,
                shed_rows: 3,
                failed_rows: 0,
                latencies: vec![0.01, 0.02, 0.03, 0.04],
            },
        );
        let report = LoadReport { wall_s: 2.0, request_errors: 0, tenants };
        let j = report_json(&cfg, &report);
        assert_eq!(j.req_str("bench").unwrap(), "serving");
        assert_eq!(j.req_f64("rows_per_s").unwrap(), 4.0);
        let t = j.req("tenants").unwrap().req("churn").unwrap();
        assert_eq!(t.req_f64("weight").unwrap(), 2.0);
        assert!((t.req_f64("shed_rate").unwrap() - 0.375).abs() < 1e-12);
        assert!(t.req("latency_s").unwrap().req_f64("p999").unwrap() > 0.0);
        let total = j.req("total").unwrap();
        assert_eq!(total.req_f64("offered_rows").unwrap(), 8.0);
        assert!(total.get("weight").is_none());
        // Round-trips through the serializer/parser.
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req_str("bench").unwrap(), "serving");
    }
}
