//! Minimal blocking wire client: one connection, one request in flight.
//!
//! Used by `xtime loadgen`, the conformance battery, and anything else
//! that wants to talk to a [`super::listener::WireServer`] without
//! hand-rolling frames. Deliberately synchronous — the load generator
//! gets concurrency from worker threads, not from multiplexing.

use super::frame::{
    decode_reply, encode_request, read_frame, write_frame, ReplyFrame, RowOutcome,
};
use std::io;
use std::net::TcpStream;

/// A decoded batch reply: per-row outcomes in request order plus the
/// route's admitted-but-unanswered gauge observed after the batch.
#[derive(Clone, Debug)]
pub struct BatchReply {
    pub queue_depth: u32,
    pub rows: Vec<RowOutcome>,
}

/// Blocking client over one TCP connection. Request ids are assigned
/// sequentially per connection and checked against the reply's echo.
pub struct WireClient {
    stream: TcpStream,
    next_id: u64,
}

impl WireClient {
    /// Connect to a listening [`super::listener::WireServer`].
    pub fn connect(addr: &str) -> io::Result<WireClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(WireClient { stream, next_id: 1 })
    }

    /// Send one batch for `tenant` and block for the reply.
    ///
    /// `Ok` is a decoded [`BatchReply`]; `Err` covers transport
    /// failures, `Rejected` frames (unknown tenant, arity mismatch,
    /// zero rows — the connection stays usable afterwards) and
    /// `ProtocolError` frames (after which the server hangs up and this
    /// client is dead).
    pub fn request(&mut self, tenant: &str, rows: &[Vec<f32>]) -> Result<BatchReply, String> {
        let n_features = rows.first().map_or(0, Vec::len);
        self.request_shaped(tenant, n_features, rows)
    }

    /// [`WireClient::request`] with an explicit feature count, so tests
    /// can send zero-row (and otherwise oddly shaped) batches.
    pub fn request_shaped(
        &mut self,
        tenant: &str,
        n_features: usize,
        rows: &[Vec<f32>],
    ) -> Result<BatchReply, String> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = encode_request(id, tenant, n_features, rows);
        write_frame(&mut self.stream, &frame).map_err(|e| format!("send: {e}"))?;
        let body = read_frame(&mut self.stream)
            .map_err(|e| format!("recv: {e}"))?
            .ok_or_else(|| "server closed the connection before replying".to_string())?;
        match decode_reply(&body).map_err(|e| format!("recv: {e}"))? {
            ReplyFrame::Batch { id: got, queue_depth, rows } => {
                if got != id {
                    return Err(format!("reply id {got} does not match request id {id}"));
                }
                Ok(BatchReply { queue_depth, rows })
            }
            ReplyFrame::Rejected { reason, .. } => Err(format!("rejected: {reason}")),
            ReplyFrame::ProtocolError { reason, .. } => {
                Err(format!("protocol error: {reason}"))
            }
        }
    }
}
