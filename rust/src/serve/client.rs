//! Minimal blocking wire client: one connection, one request in flight.
//!
//! Used by `xtime loadgen`, the conformance battery, and anything else
//! that wants to talk to a [`super::listener::WireServer`] without
//! hand-rolling frames. Deliberately synchronous — the load generator
//! gets concurrency from worker threads, not from multiplexing.
//!
//! Transient faults: [`RetryPolicy`] bounds reconnect/retry behavior.
//! Connects retry on refusal with exponential backoff + deterministic
//! jitter; a *request* is retried only when it is provably safe — the
//! request frame was never (even partially) written to the socket, so
//! the server cannot have seen it and a retry cannot double-submit.
//! Once a single byte is out, the request's fate is unknown and the
//! error is surfaced instead ([`WireClient::request_with_retry`]).

use super::frame::{decode_reply, encode_request, read_frame, ReplyFrame, RowOutcome};
use crate::util::Rng;
use std::io::{self, Write};
use std::net::TcpStream;
use std::time::Duration;

/// A decoded batch reply: per-row outcomes in request order plus the
/// route's admitted-but-unanswered gauge observed after the batch.
#[derive(Clone, Debug)]
pub struct BatchReply {
    pub queue_depth: u32,
    pub rows: Vec<RowOutcome>,
}

/// Bounded exponential backoff with jitter for transient transport
/// faults (connection refused, reset before any request byte left).
///
/// Attempt `k` (0-based) sleeps a uniform draw from
/// `[backoff/2, backoff]` where `backoff = min(base · 2^k, max)` — full
/// exponential growth, half-window jitter so a thundering herd of
/// clients decorrelates. The jitter stream is seeded per policy value,
/// so tests are reproducible.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 = fail fast, no retry).
    pub max_retries: u32,
    /// First backoff ceiling (µs).
    pub base_backoff_us: u64,
    /// Backoff ceiling growth stops here (µs).
    pub max_backoff_us: u64,
    /// Seed of the deterministic jitter stream.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base_backoff_us: 5_000,
            max_backoff_us: 200_000,
            jitter_seed: 0x7E7B,
        }
    }
}

impl RetryPolicy {
    /// A policy that never retries (the plain-`request` behavior).
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_retries: 0, ..RetryPolicy::default() }
    }

    /// Jittered backoff for 0-based `attempt`: uniform in
    /// `[ceiling/2, ceiling]`, `ceiling = min(base · 2^attempt, max)`.
    pub fn backoff_us(&self, attempt: u32, rng: &mut Rng) -> u64 {
        let ceiling = self
            .base_backoff_us
            .saturating_mul(1u64 << attempt.min(32))
            .min(self.max_backoff_us)
            .max(1);
        let half = ceiling / 2;
        half + rng.below(ceiling - half + 1)
    }
}

/// `write_all` with explicit progress accounting: returns how many
/// bytes actually reached the socket alongside the error, which is the
/// fact the retry decision needs (`written == 0` ⇒ the server cannot
/// have seen the request ⇒ a resend cannot double-submit).
/// `std::io::Write::write_all` discards this.
fn write_all_tracked(w: &mut impl Write, buf: &[u8]) -> (usize, io::Result<()>) {
    let mut written = 0usize;
    while written < buf.len() {
        match w.write(&buf[written..]) {
            Ok(0) => {
                return (
                    written,
                    Err(io::Error::new(io::ErrorKind::WriteZero, "connection closed mid-frame")),
                )
            }
            Ok(n) => written += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return (written, Err(e)),
        }
    }
    (written, w.flush())
}

/// Blocking client over one TCP connection. Request ids are assigned
/// sequentially per connection and checked against the reply's echo.
pub struct WireClient {
    stream: TcpStream,
    /// Peer address, kept for transparent reconnects.
    addr: String,
    next_id: u64,
}

impl WireClient {
    /// Connect to a listening [`super::listener::WireServer`].
    pub fn connect(addr: &str) -> io::Result<WireClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(WireClient { stream, addr: addr.to_string(), next_id: 1 })
    }

    /// [`WireClient::connect`] retrying refused/unreachable connects
    /// under `policy` (bounded exponential backoff with jitter). The
    /// last error is returned once the retry budget is spent.
    pub fn connect_with_retry(addr: &str, policy: RetryPolicy) -> io::Result<WireClient> {
        let mut rng = Rng::new(policy.jitter_seed);
        let mut attempt = 0u32;
        loop {
            match WireClient::connect(addr) {
                Ok(client) => return Ok(client),
                Err(_) if attempt < policy.max_retries => {
                    std::thread::sleep(Duration::from_micros(
                        policy.backoff_us(attempt, &mut rng),
                    ));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Send one batch for `tenant` and block for the reply.
    ///
    /// `Ok` is a decoded [`BatchReply`]; `Err` covers transport
    /// failures, `Rejected` frames (unknown tenant, arity mismatch,
    /// zero rows — the connection stays usable afterwards) and
    /// `ProtocolError` frames (after which the server hangs up and this
    /// client is dead).
    pub fn request(&mut self, tenant: &str, rows: &[Vec<f32>]) -> Result<BatchReply, String> {
        let n_features = rows.first().map_or(0, Vec::len);
        self.request_shaped(tenant, n_features, rows)
    }

    /// [`WireClient::request`] with an explicit feature count, so tests
    /// can send zero-row (and otherwise oddly shaped) batches.
    pub fn request_shaped(
        &mut self,
        tenant: &str,
        n_features: usize,
        rows: &[Vec<f32>],
    ) -> Result<BatchReply, String> {
        self.request_retrying(tenant, n_features, rows, RetryPolicy::none())
    }

    /// [`WireClient::request`] with transient-fault retry under
    /// `policy`.
    ///
    /// **No-duplicate-submission guarantee**: a send failure is retried
    /// (after a reconnect + backoff) only if **zero** bytes of the
    /// request frame had been written — the server provably never saw
    /// the request. A partial write, or any failure after the frame is
    /// fully out (including a lost reply), is *not* retried: the server
    /// may have executed the request, and replaying it would
    /// double-submit. Those errors surface to the caller, who owns the
    /// idempotency decision.
    pub fn request_with_retry(
        &mut self,
        tenant: &str,
        rows: &[Vec<f32>],
        policy: RetryPolicy,
    ) -> Result<BatchReply, String> {
        let n_features = rows.first().map_or(0, Vec::len);
        self.request_retrying(tenant, n_features, rows, policy)
    }

    fn request_retrying(
        &mut self,
        tenant: &str,
        n_features: usize,
        rows: &[Vec<f32>],
        policy: RetryPolicy,
    ) -> Result<BatchReply, String> {
        let mut rng = Rng::new(policy.jitter_seed);
        let mut attempt = 0u32;
        loop {
            let id = self.next_id;
            self.next_id += 1;
            let frame = encode_request(id, tenant, n_features, rows);
            let (written, send) = write_all_tracked(&mut self.stream, &frame);
            if let Err(e) = send {
                // Retry-safety hinges on `written`: only an untouched
                // frame can be resent without double-submission risk.
                if written == 0 && attempt < policy.max_retries {
                    std::thread::sleep(Duration::from_micros(
                        policy.backoff_us(attempt, &mut rng),
                    ));
                    attempt += 1;
                    match WireClient::connect(&self.addr) {
                        Ok(fresh) => {
                            // Fresh connection, fresh id space.
                            *self = fresh;
                        }
                        Err(_) => continue, // next attempt retries the connect path
                    }
                    continue;
                }
                return Err(if written == 0 {
                    format!("send: {e}")
                } else {
                    format!("send: {e} ({written} of {} frame bytes written — not retried: the server may have received the request)", frame.len())
                });
            }
            return self.read_reply(id);
        }
    }

    fn read_reply(&mut self, id: u64) -> Result<BatchReply, String> {
        let body = read_frame(&mut self.stream)
            .map_err(|e| format!("recv: {e}"))?
            .ok_or_else(|| "server closed the connection before replying".to_string())?;
        match decode_reply(&body).map_err(|e| format!("recv: {e}"))? {
            ReplyFrame::Batch { id: got, queue_depth, rows } => {
                if got != id {
                    return Err(format!("reply id {got} does not match request id {id}"));
                }
                Ok(BatchReply { queue_depth, rows })
            }
            ReplyFrame::Rejected { reason, .. } => Err(format!("rejected: {reason}")),
            ReplyFrame::ProtocolError { reason, .. } => {
                Err(format!("protocol error: {reason}"))
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    /// Writer that accepts `limit` bytes, then fails every call.
    struct FailAfter {
        limit: usize,
        taken: usize,
    }

    impl Write for FailAfter {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.taken >= self.limit {
                return Err(io::Error::new(io::ErrorKind::BrokenPipe, "injected"));
            }
            let n = buf.len().min(self.limit - self.taken).min(3); // force short writes
            self.taken += n;
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn tracked_write_reports_exact_progress() {
        let buf = [7u8; 10];

        // Failure before any byte: written == 0 — the only retryable case.
        let mut w = FailAfter { limit: 0, taken: 0 };
        let (written, res) = write_all_tracked(&mut w, &buf);
        assert_eq!(written, 0);
        assert!(res.is_err());

        // Failure mid-frame, across several short writes: exact count.
        let mut w = FailAfter { limit: 7, taken: 0 };
        let (written, res) = write_all_tracked(&mut w, &buf);
        assert_eq!(written, 7);
        assert!(res.is_err());

        // Full frame: all bytes, Ok.
        let mut w = FailAfter { limit: 100, taken: 0 };
        let (written, res) = write_all_tracked(&mut w, &buf);
        assert_eq!(written, 10);
        assert!(res.is_ok());
    }

    #[test]
    fn backoff_grows_exponentially_caps_and_jitters_within_bounds() {
        let policy = RetryPolicy {
            max_retries: 8,
            base_backoff_us: 1_000,
            max_backoff_us: 16_000,
            jitter_seed: 11,
        };
        let mut rng = Rng::new(policy.jitter_seed);
        for attempt in 0..10 {
            let ceiling = (1_000u64 << attempt).min(16_000);
            for _ in 0..50 {
                let b = policy.backoff_us(attempt, &mut rng);
                assert!(b >= ceiling / 2 && b <= ceiling, "attempt {attempt}: {b} outside [{}, {ceiling}]", ceiling / 2);
            }
        }
    }

    #[test]
    fn backoff_is_deterministic_per_seed() {
        let policy = RetryPolicy::default();
        let seq = |seed: u64| -> Vec<u64> {
            let mut rng = Rng::new(seed);
            (0..6).map(|a| policy.backoff_us(a, &mut rng)).collect()
        };
        assert_eq!(seq(42), seq(42));
        assert_ne!(seq(42), seq(43), "different seeds should jitter differently");
    }

    #[test]
    fn huge_attempt_does_not_overflow() {
        let policy = RetryPolicy {
            max_retries: u32::MAX,
            base_backoff_us: u64::MAX / 2,
            max_backoff_us: u64::MAX,
            jitter_seed: 1,
        };
        let mut rng = Rng::new(1);
        // Saturating shift/mul: must not panic, must respect the cap.
        let b = policy.backoff_us(63, &mut rng);
        assert!(b <= u64::MAX);
    }

    #[test]
    fn connect_with_retry_bounded_on_refused_then_succeeds_on_live_listener() {
        use std::net::TcpListener;

        // A port with no listener: the budget must be spent, then error.
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = l.local_addr().unwrap().to_string();
            drop(l); // freed: connects will be refused
            addr
        };
        let fast = RetryPolicy {
            max_retries: 2,
            base_backoff_us: 100,
            max_backoff_us: 200,
            jitter_seed: 5,
        };
        assert!(WireClient::connect_with_retry(&dead, fast).is_err());

        // A live listener: first attempt connects, no budget needed.
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        let client = WireClient::connect_with_retry(&addr, fast);
        assert!(client.is_ok());
    }
}
