//! Wire format: length-prefixed binary frames with a lazy request decode.
//!
//! Every message on a connection is one frame: a little-endian `u32`
//! body length followed by the body. Request bodies carry a fixed-size
//! header (magic, version, request id, tenant name, row/feature counts)
//! *before* any feature bytes, so a server can route and make admission
//! decisions from the header alone; [`RequestView::parse`] validates the
//! frame's structure without touching the payload region, and feature
//! bytes are only deserialized when [`RequestView::row`] is called for a
//! row that was actually admitted (the shed-before-parse contract,
//! DESIGN.md §6). The payload is raw little-endian `f32` bits, so a
//! round trip is exact for every value including NaN payloads.
//!
//! Request body layout (after the 4-byte length prefix):
//!
//! ```text
//! offset  size  field
//! 0       4     magic "XTRQ"
//! 4       1     version (currently 1)
//! 5       8     request id (u64, echoed in the reply)
//! 13      2     tenant name length T (u16)
//! 15      T     tenant name (UTF-8)
//! 15+T    4     n_rows (u32)
//! 19+T    4     n_features (u32)
//! 23+T    n_rows × n_features × 4    row-major f32 feature payload
//! ```
//!
//! Reply body layout:
//!
//! ```text
//! 0       4     magic "XTRP"
//! 4       1     version
//! 5       8     request id
//! 13      1     frame status: 0 = batch reply,
//!                             1 = request rejected (connection stays usable),
//!                             2 = protocol error (server closes the connection)
//! status 1/2:   u16 reason length + reason bytes
//! status 0:     u32 n_rows, u32 queue_depth (route gauge after the batch),
//!               then per row: u8 row status —
//!                 0 = served:  f32 prediction, u16 n_logits, n × f32 logits
//!                 1 = shed:    u32 queue_depth (the configured admission bound)
//!                 2 = failed:  u16 error length + error bytes
//! ```

use std::fmt;
use std::io::{self, Read, Write};

/// Protocol version; a mismatch is a [`WireError::Malformed`] frame.
pub const WIRE_VERSION: u8 = 1;
/// Request-body magic (`"XTRQ"`).
pub const MAGIC_REQUEST: [u8; 4] = *b"XTRQ";
/// Reply-body magic (`"XTRP"`).
pub const MAGIC_REPLY: [u8; 4] = *b"XTRP";
/// Hard ceiling on one frame body. A length prefix above this is
/// rejected *before* any body byte is read, so a hostile or corrupt
/// prefix cannot make the server allocate or block on gigabytes.
pub const MAX_FRAME_BYTES: usize = 16 << 20;
/// Minimum request body: the fixed header with an empty tenant name.
pub const MIN_REQUEST_BYTES: usize = 23;

/// A malformed or oversized frame. Everything maps to a printable
/// reason the server echoes back in a reject/protocol-error reply.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Length prefix exceeds [`MAX_FRAME_BYTES`].
    Oversized { len: usize },
    /// Structurally invalid body (bad magic/version/lengths/UTF-8).
    Malformed(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Oversized { len } => write!(
                f,
                "frame length {len} exceeds the {MAX_FRAME_BYTES}-byte frame ceiling"
            ),
            WireError::Malformed(msg) => write!(f, "malformed frame: {msg}"),
        }
    }
}

// ---- little-endian put/get helpers ------------------------------------

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked cursor over a frame body; every read returns a
/// [`WireError::Malformed`] instead of panicking on short input.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

// `take(n)` hands back exactly `n` bytes, so the fixed-width
// `try_into()` conversions below are infallible.
#[allow(clippy::unwrap_used)]
impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], WireError> {
        if self.i + n > self.b.len() {
            return Err(WireError::Malformed(format!(
                "truncated body: {what} needs {n} bytes at offset {} of {}",
                self.i,
                self.b.len()
            )));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &str) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f32(&mut self, what: &str) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }
}

// ---- request ----------------------------------------------------------

/// Encode a request frame (length prefix included). `n_features` is
/// explicit so zero-row frames — a shape the conformance battery sends
/// on purpose — are encodable; all rows must match it.
pub fn encode_request(id: u64, tenant: &str, n_features: usize, rows: &[Vec<f32>]) -> Vec<u8> {
    assert!(tenant.len() <= u16::MAX as usize, "tenant name too long");
    assert!(
        rows.iter().all(|r| r.len() == n_features),
        "ragged request batch: all rows must have {n_features} features"
    );
    let body_len = MIN_REQUEST_BYTES + tenant.len() + rows.len() * n_features * 4;
    let mut buf = Vec::with_capacity(4 + body_len);
    put_u32(&mut buf, body_len as u32);
    buf.extend_from_slice(&MAGIC_REQUEST);
    buf.push(WIRE_VERSION);
    put_u64(&mut buf, id);
    put_u16(&mut buf, tenant.len() as u16);
    buf.extend_from_slice(tenant.as_bytes());
    put_u32(&mut buf, rows.len() as u32);
    put_u32(&mut buf, n_features as u32);
    for row in rows {
        for &v in row {
            put_f32(&mut buf, v);
        }
    }
    buf
}

/// A parsed request *header* borrowing the frame body. Parsing scans and
/// validates everything **up to** the payload region — magic, version,
/// id, tenant, row/feature counts, and that the body length accounts for
/// exactly `n_rows × n_features` f32s — but never reads a payload byte.
/// Feature bytes are deserialized one row at a time by
/// [`RequestView::row`], which the server calls only after that row has
/// claimed an admission slot.
pub struct RequestView<'a> {
    pub id: u64,
    pub tenant: &'a str,
    pub n_rows: usize,
    pub n_features: usize,
    payload: &'a [u8],
}

impl<'a> RequestView<'a> {
    /// Lazy parse of a request body (without the 4-byte length prefix).
    pub fn parse(body: &'a [u8]) -> Result<RequestView<'a>, WireError> {
        let mut c = Cursor { b: body, i: 0 };
        let magic = c.take(4, "magic")?;
        if magic != MAGIC_REQUEST {
            return Err(WireError::Malformed(format!(
                "bad magic {magic:02x?} (expected \"XTRQ\")"
            )));
        }
        let version = c.u8("version")?;
        if version != WIRE_VERSION {
            return Err(WireError::Malformed(format!(
                "unsupported protocol version {version} (this server speaks {WIRE_VERSION})"
            )));
        }
        let id = c.u64("request id")?;
        let tenant_len = c.u16("tenant length")? as usize;
        let tenant = std::str::from_utf8(c.take(tenant_len, "tenant name")?)
            .map_err(|_| WireError::Malformed("tenant name is not UTF-8".to_string()))?;
        let n_rows = c.u32("row count")? as usize;
        let n_features = c.u32("feature count")? as usize;
        // u128 math: two hostile u32 counts times 4 can overflow u64,
        // and a debug-build overflow panic is exactly the crash this
        // parser exists to rule out.
        let want = (n_rows as u128) * (n_features as u128) * 4;
        let have = (body.len() - c.i) as u128;
        if want != have {
            return Err(WireError::Malformed(format!(
                "payload length mismatch: {n_rows} rows × {n_features} features \
                 needs {want} bytes, frame carries {have}"
            )));
        }
        Ok(RequestView { id, tenant, n_rows, n_features, payload: &body[c.i..] })
    }

    /// Deserialize row `i`'s features — the **only** place request
    /// payload bytes are decoded. Panics on an out-of-range row index
    /// (a server bug, not a wire condition: `parse` proved the payload
    /// holds exactly `n_rows` rows).
    // The 4-byte slice makes `try_into()` infallible.
    #[allow(clippy::unwrap_used)]
    pub fn row(&self, i: usize) -> Vec<f32> {
        assert!(i < self.n_rows, "row {i} out of range ({} rows)", self.n_rows);
        let start = i * self.n_features * 4;
        (0..self.n_features)
            .map(|f| {
                let o = start + f * 4;
                f32::from_le_bytes(self.payload[o..o + 4].try_into().unwrap())
            })
            .collect()
    }
}

// ---- reply ------------------------------------------------------------

/// Per-row outcome in a batch reply.
#[derive(Clone, Debug, PartialEq)]
pub enum RowOutcome {
    /// Admitted and answered: the model's decision and full logits
    /// (f32 bits cross the wire exactly — contract 7 bit-identity).
    Served { prediction: f32, logits: Vec<f32> },
    /// Refused at the route's admission bound before any feature byte of
    /// this row was deserialized; carries the configured queue cap (the
    /// same deterministic figure as [`crate::coordinator::Admission::Shed`]).
    Shed { queue_depth: u32 },
    /// Admitted but the backend failed the batch (error replies keep the
    /// wire and the server alive, mirroring the in-process contract).
    Failed { error: String },
}

/// A decoded reply frame.
#[derive(Clone, Debug, PartialEq)]
pub enum ReplyFrame {
    /// One outcome per request row, in request order, plus the route's
    /// admitted-but-unanswered gauge observed after the batch.
    Batch { id: u64, queue_depth: u32, rows: Vec<RowOutcome> },
    /// The request was well-framed but unserviceable (unknown tenant,
    /// arity mismatch, zero-row batch). The connection stays usable.
    Rejected { id: u64, reason: String },
    /// The byte stream itself is broken (bad magic, truncation,
    /// oversized prefix). The server closes the connection after this.
    ProtocolError { id: u64, reason: String },
}

fn encode_reply_header(buf: &mut Vec<u8>, id: u64, status: u8) {
    buf.extend_from_slice(&MAGIC_REPLY);
    buf.push(WIRE_VERSION);
    put_u64(buf, id);
    buf.push(status);
}

fn finish_frame(mut body: Vec<u8>) -> Vec<u8> {
    let mut framed = Vec::with_capacity(4 + body.len());
    put_u32(&mut framed, body.len() as u32);
    framed.append(&mut body);
    framed
}

/// Encode a batch reply frame (length prefix included).
pub fn encode_reply(id: u64, queue_depth: u32, rows: &[RowOutcome]) -> Vec<u8> {
    let mut body = Vec::new();
    encode_reply_header(&mut body, id, 0);
    put_u32(&mut body, rows.len() as u32);
    put_u32(&mut body, queue_depth);
    for row in rows {
        match row {
            RowOutcome::Served { prediction, logits } => {
                body.push(0);
                put_f32(&mut body, *prediction);
                put_u16(&mut body, logits.len() as u16);
                for &l in logits {
                    put_f32(&mut body, l);
                }
            }
            RowOutcome::Shed { queue_depth } => {
                body.push(1);
                put_u32(&mut body, *queue_depth);
            }
            RowOutcome::Failed { error } => {
                body.push(2);
                let msg = truncate_msg(error);
                put_u16(&mut body, msg.len() as u16);
                body.extend_from_slice(msg.as_bytes());
            }
        }
    }
    finish_frame(body)
}

/// Encode a rejected-request reply (status 1; connection stays usable).
pub fn encode_rejected(id: u64, reason: &str) -> Vec<u8> {
    encode_status_frame(id, 1, reason)
}

/// Encode a protocol-error reply (status 2; sender closes afterwards).
pub fn encode_protocol_error(id: u64, reason: &str) -> Vec<u8> {
    encode_status_frame(id, 2, reason)
}

fn encode_status_frame(id: u64, status: u8, reason: &str) -> Vec<u8> {
    let mut body = Vec::new();
    encode_reply_header(&mut body, id, status);
    let msg = truncate_msg(reason);
    put_u16(&mut body, msg.len() as u16);
    body.extend_from_slice(msg.as_bytes());
    finish_frame(body)
}

/// Reasons ride in a u16-length field; clamp on a char boundary so a
/// pathological backend error cannot produce an unencodable frame.
fn truncate_msg(msg: &str) -> &str {
    let cap = u16::MAX as usize;
    if msg.len() <= cap {
        return msg;
    }
    let mut end = cap;
    while !msg.is_char_boundary(end) {
        end -= 1;
    }
    &msg[..end]
}

/// Decode a reply body (without the 4-byte length prefix).
pub fn decode_reply(body: &[u8]) -> Result<ReplyFrame, WireError> {
    let mut c = Cursor { b: body, i: 0 };
    let magic = c.take(4, "magic")?;
    if magic != MAGIC_REPLY {
        return Err(WireError::Malformed(format!(
            "bad magic {magic:02x?} (expected \"XTRP\")"
        )));
    }
    let version = c.u8("version")?;
    if version != WIRE_VERSION {
        return Err(WireError::Malformed(format!(
            "unsupported protocol version {version}"
        )));
    }
    let id = c.u64("request id")?;
    let status = c.u8("frame status")?;
    match status {
        0 => {
            let n_rows = c.u32("row count")? as usize;
            let queue_depth = c.u32("queue depth")?;
            let mut rows = Vec::with_capacity(n_rows.min(4096));
            for r in 0..n_rows {
                let tag = c.u8("row status")?;
                rows.push(match tag {
                    0 => {
                        let prediction = c.f32("prediction")?;
                        let n_logits = c.u16("logit count")? as usize;
                        let mut logits = Vec::with_capacity(n_logits);
                        for _ in 0..n_logits {
                            logits.push(c.f32("logit")?);
                        }
                        RowOutcome::Served { prediction, logits }
                    }
                    1 => RowOutcome::Shed { queue_depth: c.u32("shed depth")? },
                    2 => {
                        let len = c.u16("error length")? as usize;
                        let msg = std::str::from_utf8(c.take(len, "error message")?)
                            .map_err(|_| {
                                WireError::Malformed("error message is not UTF-8".to_string())
                            })?;
                        RowOutcome::Failed { error: msg.to_string() }
                    }
                    t => {
                        return Err(WireError::Malformed(format!(
                            "unknown row status {t} in row {r}"
                        )))
                    }
                });
            }
            if c.i != body.len() {
                return Err(WireError::Malformed(format!(
                    "{} trailing bytes after the last row",
                    body.len() - c.i
                )));
            }
            Ok(ReplyFrame::Batch { id, queue_depth, rows })
        }
        1 | 2 => {
            let len = c.u16("reason length")? as usize;
            let reason = std::str::from_utf8(c.take(len, "reason")?)
                .map_err(|_| WireError::Malformed("reason is not UTF-8".to_string()))?
                .to_string();
            if status == 1 {
                Ok(ReplyFrame::Rejected { id, reason })
            } else {
                Ok(ReplyFrame::ProtocolError { id, reason })
            }
        }
        s => Err(WireError::Malformed(format!("unknown frame status {s}"))),
    }
}

// ---- blocking stream I/O ----------------------------------------------

/// Read one frame from a blocking stream: `Ok(None)` on a clean EOF at
/// a frame boundary, `Err` on truncation, an oversized prefix, or any
/// other I/O failure.
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<Vec<u8>>> {
    let mut prefix = [0u8; 4];
    match read_exact_or_eof(r, &mut prefix)? {
        ReadStatus::CleanEof => return Ok(None),
        ReadStatus::Complete => {}
    }
    let len = u32::from_le_bytes(prefix) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            WireError::Oversized { len }.to_string(),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Some(body))
}

/// Write one already-encoded frame (the encoders include the prefix).
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> io::Result<()> {
    w.write_all(frame)?;
    w.flush()
}

enum ReadStatus {
    Complete,
    CleanEof,
}

/// `read_exact` that distinguishes EOF-before-any-byte (a peer closing
/// between frames — normal) from EOF-mid-buffer (truncation — an error).
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> io::Result<ReadStatus> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) if filled == 0 => return Ok(ReadStatus::CleanEof),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("stream closed {filled} bytes into a {}-byte read", buf.len()),
                ))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(ReadStatus::Complete)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_preserves_bits() {
        let rows = vec![
            vec![0.25f32, -1.5, f32::NAN, f32::INFINITY],
            vec![0.0, -0.0, f32::MIN_POSITIVE, 3.25e-39],
        ];
        let frame = encode_request(42, "tenant-é", 4, &rows);
        let body = &frame[4..];
        assert_eq!(u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize, body.len());
        let view = RequestView::parse(body).unwrap();
        assert_eq!(view.id, 42);
        assert_eq!(view.tenant, "tenant-é");
        assert_eq!(view.n_rows, 2);
        assert_eq!(view.n_features, 4);
        for (i, row) in rows.iter().enumerate() {
            let got = view.row(i);
            let want: Vec<u32> = row.iter().map(|v| v.to_bits()).collect();
            let have: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(want, have, "row {i}");
        }
    }

    #[test]
    fn zero_row_request_is_structurally_valid() {
        let frame = encode_request(7, "m", 5, &[]);
        let view = RequestView::parse(&frame[4..]).unwrap();
        assert_eq!(view.n_rows, 0);
        assert_eq!(view.n_features, 5);
    }

    #[test]
    fn parse_rejects_bad_magic_version_and_lengths() {
        let good = encode_request(1, "m", 2, &[vec![1.0, 2.0]]);
        let body = good[4..].to_vec();

        let mut bad_magic = body.clone();
        bad_magic[0] = b'Z';
        assert!(matches!(
            RequestView::parse(&bad_magic),
            Err(WireError::Malformed(m)) if m.contains("magic")
        ));

        let mut bad_version = body.clone();
        bad_version[4] = 99;
        assert!(matches!(
            RequestView::parse(&bad_version),
            Err(WireError::Malformed(m)) if m.contains("version")
        ));

        // Body shorter than the payload the counts promise.
        let truncated = &body[..body.len() - 3];
        assert!(matches!(
            RequestView::parse(truncated),
            Err(WireError::Malformed(m)) if m.contains("mismatch")
        ));

        // Tenant length pointing past the end of the body.
        let mut long_tenant = body.clone();
        long_tenant[13] = 0xFF;
        long_tenant[14] = 0xFF;
        assert!(RequestView::parse(&long_tenant).is_err());

        // Hostile row/feature counts must not overflow the length check.
        let mut hostile = encode_request(1, "", 0, &[]);
        let b = hostile.len();
        hostile[b - 8..b - 4].copy_from_slice(&u32::MAX.to_le_bytes());
        hostile[b - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(RequestView::parse(&hostile[4..]).is_err());
    }

    #[test]
    fn reply_roundtrip_all_row_kinds() {
        let rows = vec![
            RowOutcome::Served { prediction: 1.0, logits: vec![0.5, -0.25, f32::NAN] },
            RowOutcome::Shed { queue_depth: 64 },
            RowOutcome::Failed { error: "shard 1: injected fault".to_string() },
            RowOutcome::Served { prediction: -0.0, logits: Vec::new() },
        ];
        let frame = encode_reply(9, 3, &rows);
        match decode_reply(&frame[4..]).unwrap() {
            ReplyFrame::Batch { id, queue_depth, rows: got } => {
                assert_eq!(id, 9);
                assert_eq!(queue_depth, 3);
                assert_eq!(got.len(), rows.len());
                for (want, have) in rows.iter().zip(&got) {
                    match (want, have) {
                        (
                            RowOutcome::Served { prediction: p1, logits: l1 },
                            RowOutcome::Served { prediction: p2, logits: l2 },
                        ) => {
                            assert_eq!(p1.to_bits(), p2.to_bits());
                            let b1: Vec<u32> = l1.iter().map(|v| v.to_bits()).collect();
                            let b2: Vec<u32> = l2.iter().map(|v| v.to_bits()).collect();
                            assert_eq!(b1, b2);
                        }
                        (a, b) => assert_eq!(a, b),
                    }
                }
            }
            other => panic!("expected batch, got {other:?}"),
        }
    }

    #[test]
    fn rejected_and_protocol_error_roundtrip() {
        let f = encode_rejected(5, "unknown model `x`");
        assert_eq!(
            decode_reply(&f[4..]).unwrap(),
            ReplyFrame::Rejected { id: 5, reason: "unknown model `x`".to_string() }
        );
        let f = encode_protocol_error(0, "bad magic");
        assert_eq!(
            decode_reply(&f[4..]).unwrap(),
            ReplyFrame::ProtocolError { id: 0, reason: "bad magic".to_string() }
        );
    }

    #[test]
    fn decode_reply_rejects_garbage() {
        assert!(decode_reply(b"").is_err());
        assert!(decode_reply(b"XTRP").is_err());
        assert!(decode_reply(&[0u8; 32]).is_err());
        // Trailing bytes after a complete batch are an error.
        let mut f = encode_reply(1, 0, &[RowOutcome::Shed { queue_depth: 1 }]);
        f.push(0xAB);
        let body = &f[4..];
        assert!(decode_reply(body).is_err());
    }

    #[test]
    fn oversized_error_message_is_clamped() {
        let huge = "é".repeat(40_000); // 80 000 bytes, over the u16 cap
        let f = encode_rejected(1, &huge);
        match decode_reply(&f[4..]).unwrap() {
            ReplyFrame::Rejected { reason, .. } => {
                assert!(reason.len() <= u16::MAX as usize);
                assert!(reason.starts_with('é'));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn read_frame_roundtrip_and_guards() {
        let frame = encode_request(3, "t", 1, &[vec![1.0]]);
        let mut cursor = io::Cursor::new(frame.clone());
        let body = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(&body[..], &frame[4..]);
        // Clean EOF at the boundary.
        assert!(read_frame(&mut cursor).unwrap().is_none());
        // Truncated body.
        let mut short = io::Cursor::new(frame[..frame.len() - 2].to_vec());
        assert!(read_frame(&mut short).is_err());
        // Oversized prefix refused before any body read.
        let mut oversized = io::Cursor::new(u32::MAX.to_le_bytes().to_vec());
        let err = read_frame(&mut oversized).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }
}
