//! Content-addressed model artifact store (PR 8).
//!
//! Everything a trained-and-compiled model needs to travel through disk
//! and come back **bit-identical**:
//!
//! * [`digest`] — self-contained SHA-256 (FIPS 180-4); blob and
//!   manifest addresses are lowercase hex digests of canonical bytes.
//! * [`manifest`] — [`ArtifactManifest`], the versioned top-level
//!   record of one exported model: task/bits metadata plus
//!   digest-references to the program and optional shard-plan blobs.
//!   Distinct from the AOT bucket manifest
//!   ([`crate::runtime::AotManifest`]).
//! * [`store`] — [`ArtifactStore`], the local blob store:
//!   write-temp-then-rename atomicity, digest verification on every
//!   read, ref-counted index, [`ArtifactStore::gc`] for unreferenced
//!   data, and [`export_program`] which refuses to digest any encoding
//!   that is not round-trip stable.
//!
//! The contract (DESIGN.md §5, contract 9): a program loaded from an
//! artifact is verify-clean under the static verifier and produces
//! bit-identical predictions, logits, and per-shard partials to the
//! in-memory original it was exported from.

pub mod digest;
pub mod manifest;
pub mod store;

pub use digest::{sha256, sha256_hex};
pub use manifest::{
    ArtifactManifest, BlobRef, CompressionMeta, FORMAT_MARKER, FORMAT_VERSION, ROLE_PROGRAM,
    ROLE_SHARD_PLAN,
};
pub use store::{export_program, ArtifactStore, GcReport, IndexEntry, LoadedArtifact, StoreError};
