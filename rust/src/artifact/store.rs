//! Digest-addressed local blob store for compiled model artifacts.
//!
//! Layout under the store root:
//!
//! ```text
//! <root>/blobs/<sha256-hex>       # immutable content blobs (canonical JSON)
//! <root>/manifests/<id>.json      # artifact manifests, id = sha256(bytes)
//! <root>/index.json               # name→artifact map + blob refcounts
//! ```
//!
//! Every write is temp-file-then-rename, so a crash mid-write never
//! leaves a half-blob under its final name. Blobs are verified against
//! their digest on *every* read, so bit-rot and truncation surface as
//! [`StoreError::DigestMismatch`] rather than a decode panic downstream.
//! The index keeps a refcount per blob digest; [`ArtifactStore::gc`]
//! deletes blobs whose count reached zero and manifests no longer in
//! the index.

use super::manifest::{
    ArtifactManifest, BlobRef, CompressionMeta, FORMAT_VERSION, ROLE_PROGRAM, ROLE_SHARD_PLAN,
};
use super::digest::sha256_hex;
use crate::compiler::{CamProgram, ShardPlan};
use crate::util::Json;
use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

/// Everything that can go wrong talking to the store. All variants are
/// structured errors — the store never panics on hostile on-disk state.
#[derive(Debug)]
pub enum StoreError {
    /// Filesystem failure (permissions, missing file, full disk, …).
    Io { path: PathBuf, err: String },
    /// A blob or manifest's bytes no longer hash to their address —
    /// corruption, truncation, or tampering.
    DigestMismatch { path: PathBuf, expected: String, actual: String },
    /// Bytes hashed correctly but failed to parse/decode.
    Corrupt { path: PathBuf, detail: String },
    /// The manifest declares a format version this build does not know.
    UnknownVersion { found: usize, supported: usize },
    /// No artifact with this id in the store.
    UnknownArtifact { id: String },
    /// No artifact published under this model name.
    UnknownName { name: String },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io { path, err } => write!(f, "io error at {}: {err}", path.display()),
            StoreError::DigestMismatch { path, expected, actual } => write!(
                f,
                "digest mismatch at {}: expected {expected}, got {actual}",
                path.display()
            ),
            StoreError::Corrupt { path, detail } => {
                write!(f, "corrupt artifact data at {}: {detail}", path.display())
            }
            StoreError::UnknownVersion { found, supported } => write!(
                f,
                "artifact format version {found} is not supported (this build reads version {supported})"
            ),
            StoreError::UnknownArtifact { id } => write!(f, "no artifact with id {id}"),
            StoreError::UnknownName { name } => write!(f, "no artifact published under name `{name}`"),
        }
    }
}

impl std::error::Error for StoreError {}

fn io_err(path: &Path, e: std::io::Error) -> StoreError {
    StoreError::Io { path: path.to_path_buf(), err: e.to_string() }
}

/// One row of `xtime store ls`: the index's view of a published artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct IndexEntry {
    pub id: String,
    pub name: String,
    /// Monotone publish sequence; `resolve(name)` picks the max.
    pub seq: u64,
    pub n_shards: usize,
    pub n_trees: usize,
    pub n_bits: u8,
}

/// A fully loaded, digest-verified artifact ready to register with a
/// fleet or engine.
pub struct LoadedArtifact {
    pub id: String,
    pub manifest: ArtifactManifest,
    pub program: CamProgram,
    pub plan: Option<ShardPlan>,
}

/// Result of a [`ArtifactStore::gc`] sweep.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GcReport {
    pub kept_blobs: usize,
    pub removed_blobs: usize,
    pub removed_manifests: usize,
    pub bytes_freed: u64,
}

#[derive(Default)]
struct StoreIndex {
    next_seq: u64,
    artifacts: Vec<IndexEntry>,
    /// Blob digest → number of indexed manifests referencing it.
    refs: BTreeMap<String, u64>,
}

impl StoreIndex {
    fn to_json(&self) -> Json {
        let mut arts = Vec::with_capacity(self.artifacts.len());
        for a in &self.artifacts {
            let mut o = Json::obj();
            o.set("id", Json::Str(a.id.clone()))
                .set("name", Json::Str(a.name.clone()))
                .set("seq", Json::Num(a.seq as f64))
                .set("n_shards", Json::Num(a.n_shards as f64))
                .set("n_trees", Json::Num(a.n_trees as f64))
                .set("n_bits", Json::Num(a.n_bits as f64));
            arts.push(o);
        }
        let mut refs = Json::obj();
        for (d, c) in &self.refs {
            refs.set(d, Json::Num(*c as f64));
        }
        let mut o = Json::obj();
        o.set("format_version", Json::Num(FORMAT_VERSION as f64))
            .set("next_seq", Json::Num(self.next_seq as f64))
            .set("artifacts", Json::Arr(arts))
            .set("refs", refs);
        o
    }

    fn from_json(j: &Json) -> Result<StoreIndex, String> {
        let found = j.req_usize("format_version")?;
        if found != FORMAT_VERSION {
            // Encoded as a string the caller maps back onto the typed
            // variant; keeps this helper's error type uniform.
            return Err(format!("#version:{found}"));
        }
        let mut artifacts = Vec::new();
        match j.req("artifacts")? {
            Json::Arr(items) => {
                for a in items {
                    artifacts.push(IndexEntry {
                        id: a.req_str("id")?.to_string(),
                        name: a.req_str("name")?.to_string(),
                        seq: a.req_f64("seq")? as u64,
                        n_shards: a.req_usize("n_shards")?,
                        n_trees: a.req_usize("n_trees")?,
                        n_bits: a.req_usize("n_bits")? as u8,
                    });
                }
            }
            _ => return Err("field `artifacts` is not an array".into()),
        }
        let mut refs = BTreeMap::new();
        match j.req("refs")? {
            Json::Obj(m) => {
                for (d, c) in m {
                    let c = c.as_f64().ok_or_else(|| format!("ref `{d}` is not a number"))?;
                    refs.insert(d.clone(), c as u64);
                }
            }
            _ => return Err("field `refs` is not an object".into()),
        }
        Ok(StoreIndex { next_seq: j.req_f64("next_seq")? as u64, artifacts, refs })
    }
}

/// The local content-addressed artifact store.
pub struct ArtifactStore {
    root: PathBuf,
    index: StoreIndex,
}

impl ArtifactStore {
    /// Open (creating on first use) a store rooted at `root`.
    pub fn open(root: &Path) -> Result<ArtifactStore, StoreError> {
        let blobs = root.join("blobs");
        let manifests = root.join("manifests");
        fs::create_dir_all(&blobs).map_err(|e| io_err(&blobs, e))?;
        fs::create_dir_all(&manifests).map_err(|e| io_err(&manifests, e))?;
        let index_path = root.join("index.json");
        let index = if index_path.exists() {
            let text = fs::read_to_string(&index_path).map_err(|e| io_err(&index_path, e))?;
            let j = Json::parse(&text).map_err(|e| StoreError::Corrupt {
                path: index_path.clone(),
                detail: e,
            })?;
            StoreIndex::from_json(&j).map_err(|e| match e.strip_prefix("#version:") {
                Some(v) => StoreError::UnknownVersion {
                    found: v.parse().unwrap_or(0),
                    supported: FORMAT_VERSION,
                },
                None => StoreError::Corrupt { path: index_path.clone(), detail: e },
            })?
        } else {
            StoreIndex::default()
        };
        Ok(ArtifactStore { root: root.to_path_buf(), index })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn blob_path(&self, digest: &str) -> PathBuf {
        self.root.join("blobs").join(digest)
    }

    pub fn manifest_path(&self, id: &str) -> PathBuf {
        self.root.join("manifests").join(format!("{id}.json"))
    }

    fn index_path(&self) -> PathBuf {
        self.root.join("index.json")
    }

    /// Atomic write: temp file in the destination directory, then rename.
    fn write_atomic(&self, dest: &Path, bytes: &[u8]) -> Result<(), StoreError> {
        let dir = dest.parent().unwrap_or(&self.root);
        let tmp = dir.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            dest.file_name().and_then(|n| n.to_str()).unwrap_or("blob")
        ));
        fs::write(&tmp, bytes).map_err(|e| io_err(&tmp, e))?;
        fs::rename(&tmp, dest).map_err(|e| io_err(dest, e))
    }

    fn persist_index(&self) -> Result<(), StoreError> {
        self.write_atomic(&self.index_path(), self.index.to_json().to_string().as_bytes())
    }

    /// Store `bytes` under their SHA-256 address. Idempotent: an
    /// existing blob with the same digest is left untouched.
    pub fn put_blob(&self, bytes: &[u8]) -> Result<String, StoreError> {
        let digest = sha256_hex(bytes);
        let dest = self.blob_path(&digest);
        if !dest.exists() {
            self.write_atomic(&dest, bytes)?;
        }
        Ok(digest)
    }

    /// Read a blob and verify its bytes still hash to `digest`.
    pub fn get_blob(&self, digest: &str) -> Result<Vec<u8>, StoreError> {
        let path = self.blob_path(digest);
        let bytes = fs::read(&path).map_err(|e| io_err(&path, e))?;
        let actual = sha256_hex(&bytes);
        if actual != digest {
            return Err(StoreError::DigestMismatch {
                path,
                expected: digest.to_string(),
                actual,
            });
        }
        Ok(bytes)
    }

    /// Publish a manifest: write it under its content id, bump blob
    /// refcounts, and index it under its model name. Idempotent — a
    /// second publish of an identical manifest returns the same id
    /// without touching refcounts.
    pub fn publish(&mut self, m: &ArtifactManifest) -> Result<String, StoreError> {
        let bytes = m.canonical_bytes();
        let id = sha256_hex(&bytes);
        let path = self.manifest_path(&id);
        if self.index.artifacts.iter().any(|a| a.id == id) {
            return Ok(id);
        }
        // Publishing a manifest whose blobs are absent would index a
        // dangling artifact; refuse up front.
        for d in m.blob_digests() {
            let p = self.blob_path(d);
            if !p.exists() {
                return Err(StoreError::Corrupt {
                    path: p,
                    detail: format!("manifest references blob {d} which is not in the store"),
                });
            }
        }
        self.write_atomic(&path, &bytes)?;
        for d in m.blob_digests() {
            *self.index.refs.entry(d.to_string()).or_insert(0) += 1;
        }
        let seq = self.index.next_seq;
        self.index.next_seq += 1;
        self.index.artifacts.push(IndexEntry {
            id: id.clone(),
            name: m.name.clone(),
            seq,
            n_shards: m.n_shards,
            n_trees: m.n_trees,
            n_bits: m.n_bits,
        });
        self.persist_index()?;
        Ok(id)
    }

    /// Load and fully verify an artifact: manifest bytes must hash to
    /// `id`, the format version must be known, every referenced blob
    /// must hash to its digest, and every decode must succeed.
    pub fn load(&self, id: &str) -> Result<LoadedArtifact, StoreError> {
        let path = self.manifest_path(id);
        if !path.exists() {
            return Err(StoreError::UnknownArtifact { id: id.to_string() });
        }
        let bytes = fs::read(&path).map_err(|e| io_err(&path, e))?;
        let actual = sha256_hex(&bytes);
        if actual != id {
            return Err(StoreError::DigestMismatch {
                path,
                expected: id.to_string(),
                actual,
            });
        }
        let text = String::from_utf8(bytes)
            .map_err(|_| StoreError::Corrupt { path: path.clone(), detail: "not utf-8".into() })?;
        let j = Json::parse(&text)
            .map_err(|e| StoreError::Corrupt { path: path.clone(), detail: e })?;
        let found = j
            .req_usize("format_version")
            .map_err(|e| StoreError::Corrupt { path: path.clone(), detail: e })?;
        if found != FORMAT_VERSION {
            return Err(StoreError::UnknownVersion { found, supported: FORMAT_VERSION });
        }
        let manifest = ArtifactManifest::from_json(&j)
            .map_err(|e| StoreError::Corrupt { path: path.clone(), detail: e })?;

        let program = self.load_blob_json(manifest.program_blob().map_err(|e| {
            StoreError::Corrupt { path: path.clone(), detail: e }
        })?)?;
        let program = CamProgram::from_json(&program.1).map_err(|e| StoreError::Corrupt {
            path: program.0,
            detail: e,
        })?;

        let plan = match manifest.shard_plan_blob() {
            Some(b) => {
                let (bp, j) = self.load_blob_json(b)?;
                Some(ShardPlan::from_json(&j).map_err(|e| StoreError::Corrupt {
                    path: bp,
                    detail: e,
                })?)
            }
            None => None,
        };

        Ok(LoadedArtifact { id: id.to_string(), manifest, program, plan })
    }

    fn load_blob_json(&self, b: &BlobRef) -> Result<(PathBuf, Json), StoreError> {
        let path = self.blob_path(&b.digest);
        let bytes = self.get_blob(&b.digest)?;
        if bytes.len() as u64 != b.size {
            return Err(StoreError::Corrupt {
                path,
                detail: format!("blob size {} != manifest size {}", bytes.len(), b.size),
            });
        }
        let text = String::from_utf8(bytes)
            .map_err(|_| StoreError::Corrupt { path: path.clone(), detail: "not utf-8".into() })?;
        let j = Json::parse(&text)
            .map_err(|e| StoreError::Corrupt { path: path.clone(), detail: e })?;
        Ok((path, j))
    }

    /// Latest published artifact id for a model name.
    pub fn resolve(&self, name: &str) -> Result<String, StoreError> {
        self.index
            .artifacts
            .iter()
            .filter(|a| a.name == name)
            .max_by_key(|a| a.seq)
            .map(|a| a.id.clone())
            .ok_or_else(|| StoreError::UnknownName { name: name.to_string() })
    }

    /// Drop an artifact from the index and release its blob references.
    /// Files stay on disk until the next [`ArtifactStore::gc`].
    pub fn remove(&mut self, id: &str) -> Result<(), StoreError> {
        let pos = self
            .index
            .artifacts
            .iter()
            .position(|a| a.id == id)
            .ok_or_else(|| StoreError::UnknownArtifact { id: id.to_string() })?;
        self.index.artifacts.remove(pos);
        // Decrement refs for the blobs this manifest referenced. The
        // manifest file may itself be corrupt at this point; treat an
        // unreadable manifest as referencing nothing (gc sweeps it).
        if let Ok(art) = self.load_manifest_only(id) {
            for d in art.blob_digests() {
                if let Some(c) = self.index.refs.get_mut(d) {
                    *c = c.saturating_sub(1);
                }
            }
        }
        self.persist_index()
    }

    fn load_manifest_only(&self, id: &str) -> Result<ArtifactManifest, StoreError> {
        let path = self.manifest_path(id);
        let text = fs::read_to_string(&path).map_err(|e| io_err(&path, e))?;
        let j = Json::parse(&text)
            .map_err(|e| StoreError::Corrupt { path: path.clone(), detail: e })?;
        ArtifactManifest::from_json(&j)
            .map_err(|e| StoreError::Corrupt { path, detail: e })
    }

    /// Indexed artifacts, publish order.
    pub fn ls(&self) -> &[IndexEntry] {
        &self.index.artifacts
    }

    /// Sweep unreferenced data: blobs whose refcount is zero (or that
    /// no indexed manifest ever referenced) and manifest files whose id
    /// is no longer in the index.
    pub fn gc(&mut self) -> Result<GcReport, StoreError> {
        let mut report = GcReport::default();
        let live: std::collections::BTreeSet<&str> = self
            .index
            .refs
            .iter()
            .filter(|(_, c)| **c > 0)
            .map(|(d, _)| d.as_str())
            .collect();
        let blobs_dir = self.root.join("blobs");
        for entry in fs::read_dir(&blobs_dir).map_err(|e| io_err(&blobs_dir, e))? {
            let entry = entry.map_err(|e| io_err(&blobs_dir, e))?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.starts_with(".tmp-") || !live.contains(name.as_str()) {
                let len = entry.metadata().map(|m| m.len()).unwrap_or(0);
                fs::remove_file(entry.path()).map_err(|e| io_err(&entry.path(), e))?;
                report.removed_blobs += 1;
                report.bytes_freed += len;
            } else {
                report.kept_blobs += 1;
            }
        }
        let indexed: std::collections::BTreeSet<&str> =
            self.index.artifacts.iter().map(|a| a.id.as_str()).collect();
        let man_dir = self.root.join("manifests");
        for entry in fs::read_dir(&man_dir).map_err(|e| io_err(&man_dir, e))? {
            let entry = entry.map_err(|e| io_err(&man_dir, e))?;
            let name = entry.file_name().to_string_lossy().into_owned();
            let id = name.strip_suffix(".json").unwrap_or(&name);
            if name.starts_with(".tmp-") || !indexed.contains(id) {
                let len = entry.metadata().map(|m| m.len()).unwrap_or(0);
                fs::remove_file(entry.path()).map_err(|e| io_err(&entry.path(), e))?;
                report.removed_manifests += 1;
                report.bytes_freed += len;
            }
        }
        self.index.refs.retain(|_, c| *c > 0);
        self.persist_index()?;
        Ok(report)
    }
}

/// Canonically encode a value and prove the encoding is round-trip
/// stable (`encode(decode(bytes)) == bytes`) before it is digested —
/// an unstable encoding would give the same logical model two
/// addresses.
fn encode_stable(
    what: &str,
    j: Json,
    reencode: impl Fn(&Json) -> Result<Json, String>,
) -> Result<Vec<u8>, StoreError> {
    let text = j.to_string();
    let parsed = Json::parse(&text).map_err(|e| StoreError::Corrupt {
        path: PathBuf::from(what),
        detail: format!("encoding does not re-parse: {e}"),
    })?;
    let again = reencode(&parsed).map_err(|e| StoreError::Corrupt {
        path: PathBuf::from(what),
        detail: format!("encoding does not decode: {e}"),
    })?;
    let text2 = again.to_string();
    if text2 != text {
        return Err(StoreError::Corrupt {
            path: PathBuf::from(what),
            detail: "encoding is not round-trip stable (decode→encode changed bytes)".into(),
        });
    }
    Ok(text.into_bytes())
}

/// Export a compiled program (and optionally its shard plan) into the
/// store: write blobs, build the manifest, publish, return the
/// artifact id.
pub fn export_program(
    store: &mut ArtifactStore,
    program: &CamProgram,
    plan: Option<&ShardPlan>,
) -> Result<String, StoreError> {
    let prog_bytes = encode_stable("program", program.to_json(), |j| {
        CamProgram::from_json(j).map(|p| p.to_json())
    })?;
    let prog_digest = store.put_blob(&prog_bytes)?;
    let mut blobs = BTreeMap::new();
    blobs.insert(
        ROLE_PROGRAM.to_string(),
        BlobRef { digest: prog_digest, size: prog_bytes.len() as u64 },
    );
    let mut n_shards = 0;
    if let Some(p) = plan {
        let plan_bytes = encode_stable("shard_plan", p.to_json(), |j| {
            ShardPlan::from_json(j).map(|p| p.to_json())
        })?;
        let digest = store.put_blob(&plan_bytes)?;
        blobs.insert(
            ROLE_SHARD_PLAN.to_string(),
            BlobRef { digest, size: plan_bytes.len() as u64 },
        );
        n_shards = p.n_shards();
    }
    // Compressed programs advertise their capacity footprint in the
    // manifest (contract 11); uncompressed manifests carry no
    // `compression` key at all so pre-compression artifact ids are
    // unchanged.
    let compression = program.layouts.as_ref().map(|_| CompressionMeta {
        rows: program.total_rows(),
        phys_rows: program.total_phys_rows(),
    });
    let manifest = ArtifactManifest {
        name: program.name.clone(),
        task: program.task,
        n_bits: program.n_bits,
        n_features: program.n_features,
        n_trees: program.n_trees,
        n_shards,
        compression,
        blobs,
    };
    store.publish(&manifest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Task;

    fn tmp_root(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("xtime-store-unit-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        p
    }

    fn toy_manifest(digest: &str, size: u64, name: &str) -> ArtifactManifest {
        let mut blobs = BTreeMap::new();
        blobs.insert(ROLE_PROGRAM.to_string(), BlobRef { digest: digest.to_string(), size });
        ArtifactManifest {
            name: name.to_string(),
            task: Task::Binary,
            n_bits: 8,
            n_features: 4,
            n_trees: 2,
            n_shards: 0,
            compression: None,
            blobs,
        }
    }

    #[test]
    fn put_get_blob_roundtrip_is_idempotent() {
        let root = tmp_root("putget");
        let store = ArtifactStore::open(&root).unwrap();
        let d1 = store.put_blob(b"hello artifact").unwrap();
        let d2 = store.put_blob(b"hello artifact").unwrap();
        assert_eq!(d1, d2);
        assert_eq!(store.get_blob(&d1).unwrap(), b"hello artifact");
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn corrupt_blob_is_a_digest_mismatch_not_a_panic() {
        let root = tmp_root("corrupt");
        let store = ArtifactStore::open(&root).unwrap();
        let d = store.put_blob(b"payload").unwrap();
        fs::write(store.blob_path(&d), b"paXload").unwrap();
        match store.get_blob(&d) {
            Err(StoreError::DigestMismatch { expected, actual, .. }) => {
                assert_eq!(expected, d);
                assert_ne!(actual, d);
            }
            other => panic!("expected DigestMismatch, got {:?}", other.map(|_| ())),
        }
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn publish_resolve_remove_and_gc() {
        let root = tmp_root("lifecycle");
        let mut store = ArtifactStore::open(&root).unwrap();
        let bytes = b"fake program blob".to_vec();
        let d = store.put_blob(&bytes).unwrap();
        let m1 = toy_manifest(&d, bytes.len() as u64, "churn");
        let id1 = store.publish(&m1).unwrap();
        assert_eq!(store.publish(&m1).unwrap(), id1, "publish is idempotent");
        assert_eq!(store.resolve("churn").unwrap(), id1);

        // Second manifest shares the same blob: refcount 2.
        let mut m2 = toy_manifest(&d, bytes.len() as u64, "churn");
        m2.n_trees = 3;
        let id2 = store.publish(&m2).unwrap();
        assert_ne!(id1, id2);
        assert_eq!(store.resolve("churn").unwrap(), id2, "resolve picks latest");
        assert_eq!(store.ls().len(), 2);

        // Removing one ref keeps the shared blob alive through gc.
        store.remove(&id1).unwrap();
        let r = store.gc().unwrap();
        assert_eq!(r.kept_blobs, 1);
        assert_eq!(r.removed_manifests, 1, "unindexed manifest swept");
        assert!(store.blob_path(&d).exists());

        // Removing the last ref lets gc drop the blob.
        store.remove(&id2).unwrap();
        let r = store.gc().unwrap();
        assert_eq!(r.removed_blobs, 1);
        assert!(r.bytes_freed > 0);
        assert!(!store.blob_path(&d).exists());
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn publish_refuses_dangling_blob_refs() {
        let root = tmp_root("dangling");
        let mut store = ArtifactStore::open(&root).unwrap();
        let m = toy_manifest(&"00".repeat(32), 10, "ghost");
        match store.publish(&m) {
            Err(StoreError::Corrupt { detail, .. }) => assert!(detail.contains("not in the store")),
            other => panic!("expected Corrupt, got {:?}", other.map(|_| ())),
        }
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn unknown_index_version_is_structured() {
        let root = tmp_root("version");
        {
            let store = ArtifactStore::open(&root).unwrap();
            store.put_blob(b"x").unwrap();
        }
        let idx = root.join("index.json");
        fs::write(&idx, br#"{"artifacts":[],"format_version":99,"next_seq":0,"refs":{}}"#).unwrap();
        match ArtifactStore::open(&root) {
            Err(StoreError::UnknownVersion { found, supported }) => {
                assert_eq!(found, 99);
                assert_eq!(supported, FORMAT_VERSION);
            }
            other => panic!("expected UnknownVersion, got {:?}", other.err()),
        }
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn unknown_artifact_and_name_errors() {
        let root = tmp_root("unknown");
        let store = ArtifactStore::open(&root).unwrap();
        assert!(matches!(store.load("deadbeef"), Err(StoreError::UnknownArtifact { .. })));
        assert!(matches!(store.resolve("nope"), Err(StoreError::UnknownName { .. })));
        let _ = fs::remove_dir_all(&root);
    }
}
