//! The artifact manifest: the versioned top-level record of one exported
//! model.
//!
//! Not to be confused with the AOT HLO bucket manifest
//! ([`crate::runtime::AotManifest`], `artifacts/manifest.json`), which
//! describes XLA compilation buckets. *This* manifest describes a
//! **compiled CAM model** at rest: its identity metadata plus
//! content-digest references to the blobs that make it up — the
//! [`crate::compiler::CamProgram`] encoding (required) and an optional
//! [`crate::compiler::ShardPlan`] encoding.
//!
//! Manifests are themselves canonical JSON and are addressed by the
//! SHA-256 of their bytes (the *artifact id*), so a manifest can never
//! drift from the blobs it references without the id changing. They
//! deliberately carry no timestamps or host names: exporting the same
//! model on two machines yields the same artifact id.

use super::digest::sha256_hex;
use crate::data::Task;
use crate::util::Json;
use std::collections::BTreeMap;

/// On-disk format version. Bump on any breaking change to the manifest
/// or blob encodings; the store refuses versions it does not know
/// ([`super::StoreError::UnknownVersion`]) instead of misparsing them.
pub const FORMAT_VERSION: usize = 1;

/// Format marker distinguishing artifact manifests from every other JSON
/// file in the tree (model files, program files, AOT bucket manifests).
pub const FORMAT_MARKER: &str = "xtime-artifact";

/// Blob role for the program encoding (required in every manifest).
pub const ROLE_PROGRAM: &str = "program";

/// Blob role for the optional shard-plan encoding.
pub const ROLE_SHARD_PLAN: &str = "shard_plan";

/// A content-digest reference to one blob in the store.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlobRef {
    /// Lowercase hex SHA-256 of the blob bytes.
    pub digest: String,
    /// Blob size in bytes (a cheap pre-check before hashing on read).
    pub size: u64,
}

/// The top-level record of one exported model artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactManifest {
    /// Model name ([`crate::compiler::CamProgram::name`]); the store's
    /// `resolve` maps names to their latest published artifact.
    pub name: String,
    pub task: Task,
    pub n_bits: u8,
    pub n_features: usize,
    pub n_trees: usize,
    /// Shard count of the embedded plan blob; `0` when the artifact
    /// carries only the unsharded program.
    pub n_shards: usize,
    /// Capacity-compression summary of the program blob; `None` for
    /// uncompressed programs. Omitted entirely from the canonical
    /// encoding when `None`, so artifacts exported before the
    /// compression pass existed keep their ids byte for byte.
    pub compression: Option<CompressionMeta>,
    /// Role → blob reference. [`ROLE_PROGRAM`] is always present.
    pub blobs: BTreeMap<String, BlobRef>,
}

/// Manifest-level summary of a capacity-compressed program (DESIGN.md
/// §5 contract 11): enough to report the reduction without decoding the
/// program blob. The layouts themselves live in the program encoding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompressionMeta {
    /// Logical CAM rows (= physical words before compression).
    pub rows: usize,
    /// Physical words the compressed program occupies.
    pub phys_rows: usize,
}

impl CompressionMeta {
    fn to_json(self) -> Json {
        let mut o = Json::obj();
        o.set("rows", Json::Num(self.rows as f64))
            .set("phys_rows", Json::Num(self.phys_rows as f64));
        o
    }

    /// Strict decode: a manifest carrying a malformed `compression`
    /// object is corrupt and must surface as a structured error, never
    /// a panic or a silently-ignored field.
    fn from_json(j: &Json) -> Result<CompressionMeta, String> {
        if !matches!(j, Json::Obj(_)) {
            return Err("manifest field `compression` is not an object".into());
        }
        Ok(CompressionMeta {
            rows: j.req_usize("rows").map_err(|e| format!("manifest `compression`: {e}"))?,
            phys_rows: j
                .req_usize("phys_rows")
                .map_err(|e| format!("manifest `compression`: {e}"))?,
        })
    }
}

impl ArtifactManifest {
    /// Canonical encoding; [`ArtifactManifest::id`] digests these bytes.
    pub fn to_json(&self) -> Json {
        let mut blobs = Json::obj();
        for (role, b) in &self.blobs {
            let mut o = Json::obj();
            o.set("digest", Json::Str(b.digest.clone()))
                .set("size", Json::Num(b.size as f64));
            blobs.set(role, o);
        }
        let mut o = Json::obj();
        o.set("format", Json::Str(FORMAT_MARKER.to_string()))
            .set("format_version", Json::Num(FORMAT_VERSION as f64))
            .set("name", Json::Str(self.name.clone()))
            .set("task", Json::Str(self.task.name()))
            .set("n_classes", Json::Num(self.task.n_classes() as f64))
            .set("n_bits", Json::Num(self.n_bits as f64))
            .set("n_features", Json::Num(self.n_features as f64))
            .set("n_trees", Json::Num(self.n_trees as f64))
            .set("n_shards", Json::Num(self.n_shards as f64));
        if let Some(c) = self.compression {
            o.set("compression", c.to_json());
        }
        o.set("blobs", blobs);
        o
    }

    /// Decode a manifest. The caller (the store) checks
    /// `format_version` *before* calling this, so unknown future
    /// versions surface as a structured version error rather than a
    /// missing-field parse error.
    pub fn from_json(j: &Json) -> Result<ArtifactManifest, String> {
        if j.req_str("format")? != FORMAT_MARKER {
            return Err(format!("not an artifact manifest (format != `{FORMAT_MARKER}`)"));
        }
        let task = Task::from_name(j.req_str("task")?, j.req_usize("n_classes")?)?;
        let mut blobs = BTreeMap::new();
        match j.req("blobs")? {
            Json::Obj(m) => {
                for (role, b) in m {
                    blobs.insert(
                        role.clone(),
                        BlobRef {
                            digest: b.req_str("digest")?.to_string(),
                            size: b.req_f64("size")? as u64,
                        },
                    );
                }
            }
            _ => return Err("field `blobs` is not an object".into()),
        }
        let compression = match j.get("compression") {
            Some(c) => Some(CompressionMeta::from_json(c)?),
            None => None,
        };
        let m = ArtifactManifest {
            name: j.req_str("name")?.to_string(),
            task,
            n_bits: j.req_usize("n_bits")? as u8,
            n_features: j.req_usize("n_features")?,
            n_trees: j.req_usize("n_trees")?,
            n_shards: j.req_usize("n_shards")?,
            compression,
            blobs,
        };
        m.program_blob()?;
        Ok(m)
    }

    /// Serialized canonical bytes (what the store writes and digests).
    pub fn canonical_bytes(&self) -> Vec<u8> {
        self.to_json().to_string().into_bytes()
    }

    /// The artifact id: SHA-256 of the canonical manifest bytes.
    pub fn id(&self) -> String {
        sha256_hex(&self.canonical_bytes())
    }

    /// The required program blob reference.
    pub fn program_blob(&self) -> Result<&BlobRef, String> {
        self.blobs
            .get(ROLE_PROGRAM)
            .ok_or_else(|| format!("manifest for `{}` has no `{ROLE_PROGRAM}` blob", self.name))
    }

    /// The optional shard-plan blob reference.
    pub fn shard_plan_blob(&self) -> Option<&BlobRef> {
        self.blobs.get(ROLE_SHARD_PLAN)
    }

    /// Digests of every referenced blob (role-sorted).
    pub fn blob_digests(&self) -> Vec<&str> {
        self.blobs.values().map(|b| b.digest.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> ArtifactManifest {
        let mut blobs = BTreeMap::new();
        blobs.insert(
            ROLE_PROGRAM.to_string(),
            BlobRef { digest: "ab".repeat(32), size: 1234 },
        );
        ArtifactManifest {
            name: "churn".into(),
            task: Task::Binary,
            n_bits: 8,
            n_features: 13,
            n_trees: 16,
            n_shards: 0,
            compression: None,
            blobs,
        }
    }

    #[test]
    fn roundtrip_and_stable_id() {
        let m = toy();
        let text = m.to_json().to_string();
        let back = ArtifactManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.to_json().to_string(), text, "canonical");
        assert_eq!(back.id(), m.id(), "id must be a pure function of content");
        assert_eq!(m.id().len(), 64);
    }

    #[test]
    fn id_changes_with_content() {
        let a = toy();
        let mut b = toy();
        b.n_trees = 17;
        assert_ne!(a.id(), b.id());
    }

    #[test]
    fn missing_program_blob_is_an_error() {
        let mut m = toy();
        m.blobs.clear();
        let j = m.to_json();
        let err = ArtifactManifest::from_json(&j).unwrap_err();
        assert!(err.contains(ROLE_PROGRAM), "{err}");
    }

    #[test]
    fn wrong_format_marker_rejected() {
        let mut j = toy().to_json();
        j.set("format", Json::Str("hlo-text".into()));
        assert!(ArtifactManifest::from_json(&j).is_err());
    }

    #[test]
    fn compression_meta_roundtrips_and_gates_the_id() {
        let plain = toy();
        assert!(
            !plain.to_json().to_string().contains("compression"),
            "uncompressed manifests must not grow a compression key (id stability)"
        );
        let mut pressed = toy();
        pressed.compression = Some(CompressionMeta { rows: 1024, phys_rows: 400 });
        let text = pressed.to_json().to_string();
        let back = ArtifactManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, pressed);
        assert_eq!(back.to_json().to_string(), text, "canonical");
        assert_ne!(plain.id(), pressed.id());
    }

    #[test]
    fn malformed_compression_field_is_a_structured_error() {
        // Wrong type entirely.
        let mut j = toy().to_json();
        j.set("compression", Json::Str("yes".into()));
        let err = ArtifactManifest::from_json(&j).unwrap_err();
        assert!(err.contains("compression"), "{err}");
        // Right type, missing field.
        let mut j = toy().to_json();
        let mut c = Json::obj();
        c.set("rows", Json::Num(10.0));
        j.set("compression", c);
        let err = ArtifactManifest::from_json(&j).unwrap_err();
        assert!(err.contains("compression"), "{err}");
    }
}
