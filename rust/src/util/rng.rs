//! Deterministic pseudo-random number generation.
//!
//! The execution image has no `rand` crate, so this module provides the
//! xoshiro256++ generator (Blackman & Vigna) seeded through splitmix64 —
//! the standard recommendation for seeding xoshiro state. Everything in the
//! repository that needs randomness (dataset synthesis, bagging, defect
//! injection, property tests) goes through [`Rng`] so runs are reproducible
//! from a single `u64` seed.

/// splitmix64 step; used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Not cryptographic; excellent statistical quality and
/// extremely fast, which matters for the 100-run defect sweeps (Fig. 9b).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-tree / per-core
    /// streams). Mixes the label into fresh state.
    pub fn fork(&mut self, label: u64) -> Rng {
        let mut sm = self.next_u64() ^ label.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift rejection method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / ((1u64 << 53) as f64))
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / ((1u64 << 24) as f32))
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (polar form avoided to stay branch-
    /// predictable; trig form is fine off the hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(7);
        for n in [1usize, 2, 3, 10, 255, 1 << 20] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 20);
        assert!(u.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(123);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(17);
        let w = [0.0, 10.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.weighted(&w), 1);
        }
    }
}
