//! Utility substrates built from scratch for the offline image: PRNG, JSON,
//! CLI parsing, statistics, a property-test harness and a bench harness.

pub mod argparse;
pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

pub use argparse::Args;
pub use json::Json;
pub use rng::Rng;
