//! Small statistics helpers shared by benches, the simulator and metrics.

use super::rng::Rng;

/// Fixed-capacity uniform reservoir sampler (Vitter's algorithm R) with
/// a deterministic seed: bounded-memory percentile summaries over
/// unbounded streams. The serving engine's latency log uses one so
/// sustained load cannot grow the server's memory without limit; any
/// prefix of the stream is summarized from a uniform sample of what has
/// been offered so far.
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    rng: Rng,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        assert!(cap > 0, "reservoir capacity must be positive");
        Reservoir { cap, seen: 0, samples: Vec::new(), rng: Rng::new(seed) }
    }

    /// Offer one value: kept outright while the reservoir fills, then
    /// replaces a uniformly chosen slot with probability `cap / seen`.
    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            let j = self.rng.below(self.seen as usize);
            if j < self.cap {
                self.samples[j] = x;
            }
        }
    }

    /// Values offered so far (≥ [`Reservoir::len`]).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Values currently held (saturates at the capacity).
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Summary over the held sample; `None` before any value arrived.
    pub fn summary(&self) -> Option<Summary> {
        Summary::try_of(&self.samples)
    }
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Summary of a sample: min/median/mean/p95/max. Used by the bench harness.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub p95: f64,
    pub max: f64,
    pub std: f64,
}

impl Summary {
    /// Like [`Summary::of`] but `None` on an empty sample instead of
    /// panicking — for always-on paths (e.g. server latency logs) that
    /// may legitimately have seen no traffic yet.
    pub fn try_of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            None
        } else {
            Some(Summary::of(samples))
        }
    }

    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &x in &s {
            w.push(x);
        }
        Summary {
            n: s.len(),
            min: s[0],
            median: percentile_sorted(&s, 50.0),
            mean: w.mean(),
            p95: percentile_sorted(&s, 95.0),
            max: *s.last().unwrap(),
            std: w.std(),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, `p` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Arithmetic mean; returns 0 on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean of strictly positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Format a duration in seconds with an adaptive SI unit (ns/µs/ms/s).
pub fn fmt_si_time(seconds: f64) -> String {
    let abs = seconds.abs();
    if abs == 0.0 {
        "0 s".to_string()
    } else if abs < 1e-6 {
        format!("{:.1} ns", seconds * 1e9)
    } else if abs < 1e-3 {
        format!("{:.2} µs", seconds * 1e6)
    } else if abs < 1.0 {
        format!("{:.2} ms", seconds * 1e3)
    } else {
        format!("{:.2} s", seconds)
    }
}

/// Format a rate with adaptive SI unit (K/M/G per second).
pub fn fmt_si_rate(per_second: f64, unit: &str) -> String {
    let abs = per_second.abs();
    if abs >= 1e9 {
        format!("{:.2} G{unit}/s", per_second / 1e9)
    } else if abs >= 1e6 {
        format!("{:.2} M{unit}/s", per_second / 1e6)
    } else if abs >= 1e3 {
        format!("{:.2} K{unit}/s", per_second / 1e3)
    } else {
        format!("{:.1} {unit}/s", per_second)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.variance() - direct_var).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&s, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile_sorted(&s, 100.0) - 100.0).abs() < 1e-9);
        let med = percentile_sorted(&s, 50.0);
        assert!((med - 50.5).abs() < 1e-9, "median={med}");
    }

    #[test]
    fn summary_ordering() {
        let s = Summary::of(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert!(s.min <= s.median && s.median <= s.p95 && s.p95 <= s.max);
    }

    #[test]
    fn try_of_guards_empty_samples() {
        // `Summary::of`/`percentile_sorted` index into the slice; the
        // fallible constructor is the safe entry for maybe-empty logs.
        assert!(Summary::try_of(&[]).is_none());
        let s = Summary::try_of(&[2.0, 1.0]).unwrap();
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.n, 2);
    }

    #[test]
    fn reservoir_saturates_at_capacity() {
        // The satellite contract: memory is bounded however long the
        // stream runs, while `seen` keeps counting.
        let mut r = Reservoir::new(64, 9);
        for i in 0..10_000 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 64);
        assert_eq!(r.seen(), 10_000);
        let s = r.summary().unwrap();
        assert_eq!(s.n, 64);
        // A uniform sample of 0..10000 cannot be stuck in the prefix the
        // first 64 pushes filled.
        assert!(s.max > 64.0, "reservoir never replaced a slot: max={}", s.max);
        assert!((0.0..10_000.0).contains(&s.min));
        // Roughly uniform: the sample mean sits near the stream mean.
        assert!((s.mean - 5_000.0).abs() < 1_500.0, "mean={}", s.mean);
    }

    #[test]
    fn reservoir_below_capacity_keeps_everything() {
        let mut r = Reservoir::new(100, 1);
        assert!(r.is_empty());
        assert!(r.summary().is_none());
        for i in 0..10 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 10);
        let s = r.summary().unwrap();
        assert_eq!((s.min, s.max), (0.0, 9.0));
    }

    #[test]
    fn reservoir_is_deterministic() {
        let run = || {
            let mut r = Reservoir::new(32, 0xC0FFEE);
            for i in 0..5_000 {
                r.push(i as f64);
            }
            let mut s = r.samples.clone();
            s.sort_by(f64::total_cmp);
            s
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn geomean_simple() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_si_time(1.5e-7), "150.0 ns");
        assert_eq!(fmt_si_time(2.5e-4), "250.00 µs");
        assert_eq!(fmt_si_time(0.012), "12.00 ms");
        assert_eq!(fmt_si_time(2.0), "2.00 s");
    }
}
