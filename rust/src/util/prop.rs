//! Mini property-testing harness (proptest is not vendored offline).
//!
//! Usage:
//! ```ignore
//! prop::check(256, 0xBEEF, |g| {
//!     let q = g.u8();
//!     let (lo, hi) = (g.u8(), g.u8());
//!     prop::require(macro_cell(q, lo, hi) == ((lo..hi).contains(&q)),
//!                   format!("q={q} lo={lo} hi={hi}"))
//! });
//! ```
//! On failure the harness reports the iteration index, seed and the
//! user-supplied witness string so the case can be replayed with
//! `Gen::replay(seed, index)`.

use super::rng::Rng;

/// Value generator handed to each property iteration.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    fn new(seed: u64, iteration: u64) -> Gen {
        let mut root = Rng::new(seed);
        Gen { rng: root.fork(iteration) }
    }

    /// Rebuild the generator used in a given failing iteration.
    pub fn replay(seed: u64, iteration: u64) -> Gen {
        Gen::new(seed, iteration)
    }

    pub fn u8(&mut self) -> u8 {
        (self.rng.next_u64() & 0xFF) as u8
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.below(hi - lo)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_u8(&mut self, len: usize) -> Vec<u8> {
        (0..len).map(|_| self.u8()).collect()
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Outcome of one property iteration.
pub type PropResult = Result<(), String>;

/// Assertion helper: `Ok` when `cond`, otherwise `Err(witness)`.
pub fn require(cond: bool, witness: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(witness.into())
    }
}

/// Run `iters` iterations of `prop` with independent generators derived
/// from `seed`. Panics with a replayable report on the first failure.
pub fn check<F>(iters: u64, seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    for it in 0..iters {
        let mut g = Gen::new(seed, it);
        if let Err(witness) = prop(&mut g) {
            panic!(
                "property failed at iteration {it} (seed {seed:#x}).\n  witness: {witness}\n  \
                 replay: Gen::replay({seed:#x}, {it})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iterations() {
        let mut count = 0;
        check(64, 1, |g| {
            count += 1;
            require(g.usize_in(0, 10) < 10, "bound")
        });
        assert_eq!(count, 64);
    }

    #[test]
    #[should_panic(expected = "witness: boom")]
    fn failing_property_reports_witness() {
        check(8, 2, |_g| require(false, "boom"));
    }

    #[test]
    fn replay_reproduces_values() {
        let mut seen = Vec::new();
        check(4, 3, |g| {
            seen.push(g.u64());
            Ok(())
        });
        for (it, expect) in seen.iter().enumerate() {
            let mut g = Gen::replay(3, it as u64);
            assert_eq!(g.u64(), *expect);
        }
    }
}
