//! Tiny CLI argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed accessors and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument set. Build with [`Args::new`], describe options with
/// [`Args::opt`]/[`Args::flag`], then [`Args::parse`].
#[derive(Clone, Debug)]
pub struct Args {
    program: String,
    about: String,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Args {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            specs: Vec::new(),
            values: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Declare `--name <value>` with a default (None = required).
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Args {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(|s| s.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Args {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let head = if spec.is_flag {
                format!("  --{}", spec.name)
            } else {
                format!("  --{} <value>", spec.name)
            };
            let def = match &spec.default {
                Some(d) => format!(" [default: {d}]"),
                None if spec.is_flag => String::new(),
                None => " [required]".to_string(),
            };
            s.push_str(&format!("{head:<28} {}{def}\n", spec.help));
        }
        s
    }

    /// Parse a raw token list (without argv[0]).
    pub fn parse(mut self, argv: &[String]) -> Result<Args, String> {
        let known = |name: &str| self.specs.iter().find(|s| s.name == name);
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = known(&name)
                    .ok_or_else(|| format!("unknown option `--{name}`\n\n{}", self.usage()))?
                    .clone();
                let value = if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag `--{name}` does not take a value"));
                    }
                    "true".to_string()
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| format!("option `--{name}` needs a value"))?
                };
                self.values.insert(name, value);
            } else {
                self.positional.push(tok.clone());
            }
            i += 1;
        }
        // Check required options.
        for spec in &self.specs {
            if !spec.is_flag && spec.default.is_none() && !self.values.contains_key(&spec.name) {
                return Err(format!("missing required option `--{}`\n\n{}", spec.name, self.usage()));
            }
        }
        Ok(self)
    }

    /// Parse from the process environment, exiting with usage on error.
    pub fn parse_env(self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }

    fn raw(&self, name: &str) -> Option<String> {
        if let Some(v) = self.values.get(name) {
            return Some(v.clone());
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
    }

    pub fn get(&self, name: &str) -> String {
        self.raw(name)
            .unwrap_or_else(|| panic!("undeclared or missing option `{name}`"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("option `--{name}` is not an integer: {e}"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("option `--{name}` is not an integer: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("option `--{name}` is not a number: {e}"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::new("t", "test")
            .opt("dataset", Some("churn"), "dataset name")
            .opt("batch", None, "batch size")
            .flag("verbose", "chatty")
            .parse(&argv(&["--batch", "64", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get("dataset"), "churn");
        assert_eq!(a.get_usize("batch"), 64);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::new("t", "test")
            .opt("seed", Some("1"), "")
            .parse(&argv(&["--seed=99"]))
            .unwrap();
        assert_eq!(a.get_u64("seed"), 99);
    }

    #[test]
    fn missing_required_errors() {
        let r = Args::new("t", "test").opt("x", None, "").parse(&argv(&[]));
        assert!(r.unwrap_err().contains("--x"));
    }

    #[test]
    fn unknown_option_errors() {
        let r = Args::new("t", "test").parse(&argv(&["--nope"]));
        assert!(r.unwrap_err().contains("nope"));
    }

    #[test]
    fn flag_rejects_value() {
        let r = Args::new("t", "test").flag("v", "").parse(&argv(&["--v=1"]));
        assert!(r.is_err());
    }
}
