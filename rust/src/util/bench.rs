//! Bench harness (criterion is not vendored offline).
//!
//! `cargo bench` targets use `harness = false` and call [`time_fn`] /
//! [`Table`] to produce the same rows/series the paper reports.

use super::stats::{fmt_si_rate, fmt_si_time, Summary};
use std::time::Instant;

/// Time `f` with `warmup` discarded runs then `runs` measured runs;
/// returns per-run wall time statistics in seconds.
pub fn time_fn<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// Time `f` until at least `min_time` seconds of measurement accumulate
/// (minimum 5 runs), like criterion's auto-sampling.
pub fn time_auto<F: FnMut()>(min_time: f64, mut f: F) -> Summary {
    f(); // warmup
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < 5 || start.elapsed().as_secs_f64() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 100_000 {
            break;
        }
    }
    Summary::of(&samples)
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Plain-text aligned table writer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n=== {title} ===");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

/// Convenience formatters re-exported for bench binaries.
pub fn t(seconds: f64) -> String {
    fmt_si_time(seconds)
}

pub fn rate(per_second: f64, unit: &str) -> String {
    fmt_si_rate(per_second, unit)
}

/// `ratio(a, b)` as a "×" string, e.g. `9740×`.
pub fn times(x: f64) -> String {
    if x >= 100.0 {
        format!("{:.0}×", x)
    } else if x >= 10.0 {
        format!("{:.1}×", x)
    } else {
        format!("{:.2}×", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_runs() {
        let mut n = 0;
        let s = time_fn(2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(s.n, 10);
        assert!(s.min >= 0.0 && s.min <= s.max);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()])
        }));
        assert!(r.is_err());
    }

    #[test]
    fn times_formatting() {
        assert_eq!(times(9740.0), "9740×");
        assert_eq!(times(19.3), "19.3×");
        assert_eq!(times(1.5), "1.50×");
    }
}
