//! Minimal JSON parser and writer.
//!
//! serde is not available in the offline image, so model files, artifact
//! manifests and configs use this self-contained implementation. It supports
//! the full JSON grammar (RFC 8259) minus `\u` surrogate-pair edge cases
//! beyond the BMP, which none of our files contain.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_f32_slice(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_usize_slice(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Insert into an object; panics if self is not an object (programming
    /// error in our own serializers, so a panic is the right failure mode).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- accessors -----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors returning descriptive errors.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| format!("field `{key}` is not a number"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, String> {
        Ok(self.req_f64(key)? as usize)
    }

    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| format!("field `{key}` is not a string"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], String> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| format!("field `{key}` is not an array"))
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>, String> {
        self.as_arr()
            .ok_or("not an array".to_string())?
            .iter()
            .map(|j| j.as_f64().ok_or("non-number in array".to_string()))
            .collect()
    }

    pub fn f32_vec(&self) -> Result<Vec<f32>, String> {
        Ok(self.f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>, String> {
        Ok(self.f64_vec()?.into_iter().map(|x| x as usize).collect())
    }

    // ---- parsing -------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => write_num(f, *x),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{e}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_num(f: &mut fmt::Formatter<'_>, x: f64) -> fmt::Result {
    if !x.is_finite() {
        // JSON has no NaN/Inf; clamp to null (we never serialize these on
        // purpose — quantized models are finite by construction).
        return write!(f, "null");
    }
    if x == x.trunc() && x.abs() < 1e15 {
        write!(f, "{}", x as i64)
    } else {
        // 17 significant digits round-trips f64 exactly.
        write!(f, "{:e}", x)
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected `{}` at byte {}, found `{}`",
                c as char,
                self.i,
                self.peek().map(|b| b as char).unwrap_or('∅')
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number `{s}`: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Advance over a full UTF-8 code point.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] found {:?}", other.map(|b| b as char))),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} found {:?}", other.map(|b| b as char))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let mut o = Json::obj();
        o.set("name", Json::Str("churn \"model\"".into()))
            .set("vals", Json::from_f64_slice(&[1.0, 0.5, -3.25e-8]))
            .set("n", Json::Num(4096.0))
            .set("flag", Json::Bool(false));
        let text = o.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(o, back);
    }

    #[test]
    fn roundtrip_precise_floats() {
        let xs = [0.1, 1.0 / 3.0, std::f64::consts::PI, 1e-300, 123456789.123456];
        let j = Json::from_f64_slice(&xs);
        let back = Json::parse(&j.to_string()).unwrap().f64_vec().unwrap();
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a, b, "{a} vs {b}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn req_errors_name_field() {
        let j = Json::parse(r#"{"x": 1}"#).unwrap();
        let err = j.req_str("x").unwrap_err();
        assert!(err.contains("x"), "{err}");
        assert!(j.req("missing").is_err());
    }
}
