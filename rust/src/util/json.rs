//! Minimal JSON parser and writer.
//!
//! serde is not available in the offline image, so model files, artifact
//! manifests and configs use this self-contained implementation. It supports
//! the full JSON grammar (RFC 8259) minus `\u` surrogate-pair edge cases
//! beyond the BMP, which none of our files contain.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_f32_slice(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_usize_slice(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- canonical float encoding --------------------------------------
    //
    // The artifact store (`crate::artifact`) derives content digests from
    // serialized bytes, so every persisted float must re-encode to the
    // exact same text on every encode cycle AND re-parse to the exact
    // same bits. Finite values ride `Json::Num`: Rust's float `Display`
    // prints the shortest decimal that round-trips, and an `f32` widened
    // to `f64` is exact, so `Num` loses nothing. Non-finite values have
    // no JSON number form at all — they are encoded as tagged bit-pattern
    // strings (`"f32:0x7fc00123"`), which preserves NaN payloads and
    // infinity signs that a `null` clamp would destroy.

    /// Canonically encode an `f64`: `Num` when finite, a
    /// `"f64:0x<16 hex digits>"` bit-pattern string otherwise.
    pub fn canon_f64(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Str(format!("f64:0x{:016x}", x.to_bits()))
        }
    }

    /// Canonically encode an `f32`: `Num` (exactly widened) when finite,
    /// a `"f32:0x<8 hex digits>"` bit-pattern string otherwise.
    pub fn canon_f32(x: f32) -> Json {
        if x.is_finite() {
            Json::Num(x as f64)
        } else {
            Json::Str(format!("f32:0x{:08x}", x.to_bits()))
        }
    }

    /// Decode a value written by [`Json::canon_f64`]. Bit-exact: the
    /// returned value has the same bits as the encoded one.
    pub fn decode_f64(&self) -> Result<f64, String> {
        match self {
            Json::Num(x) => Ok(*x),
            Json::Str(s) => {
                let hex = s
                    .strip_prefix("f64:0x")
                    .ok_or_else(|| format!("`{s}` is not an f64 bit-pattern string"))?;
                let bits = u64::from_str_radix(hex, 16)
                    .map_err(|e| format!("bad f64 bit pattern `{s}`: {e}"))?;
                Ok(f64::from_bits(bits))
            }
            other => Err(format!("expected a canonical f64, found {other:?}")),
        }
    }

    /// Decode a value written by [`Json::canon_f32`]. Bit-exact: finite
    /// values narrow from the exact `f64` widening, non-finite values
    /// come back from their stored bit pattern (NaN payloads included).
    pub fn decode_f32(&self) -> Result<f32, String> {
        match self {
            Json::Num(x) => Ok(*x as f32),
            Json::Str(s) => {
                let hex = s
                    .strip_prefix("f32:0x")
                    .ok_or_else(|| format!("`{s}` is not an f32 bit-pattern string"))?;
                let bits = u32::from_str_radix(hex, 16)
                    .map_err(|e| format!("bad f32 bit pattern `{s}`: {e}"))?;
                Ok(f32::from_bits(bits))
            }
            other => Err(format!("expected a canonical f32, found {other:?}")),
        }
    }

    pub fn from_canon_f32_slice(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::canon_f32(x)).collect())
    }

    pub fn from_canon_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::canon_f64(x)).collect())
    }

    pub fn canon_f32_vec(&self) -> Result<Vec<f32>, String> {
        self.as_arr()
            .ok_or("not an array".to_string())?
            .iter()
            .map(Json::decode_f32)
            .collect()
    }

    pub fn canon_f64_vec(&self) -> Result<Vec<f64>, String> {
        self.as_arr()
            .ok_or("not an array".to_string())?
            .iter()
            .map(Json::decode_f64)
            .collect()
    }

    /// Insert into an object; panics if self is not an object (programming
    /// error in our own serializers, so a panic is the right failure mode).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- accessors -----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors returning descriptive errors.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| format!("field `{key}` is not a number"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, String> {
        Ok(self.req_f64(key)? as usize)
    }

    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| format!("field `{key}` is not a string"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], String> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| format!("field `{key}` is not an array"))
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>, String> {
        self.as_arr()
            .ok_or("not an array".to_string())?
            .iter()
            .map(|j| j.as_f64().ok_or("non-number in array".to_string()))
            .collect()
    }

    pub fn f32_vec(&self) -> Result<Vec<f32>, String> {
        Ok(self.f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>, String> {
        Ok(self.f64_vec()?.into_iter().map(|x| x as usize).collect())
    }

    // ---- parsing -------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => write_num(f, *x),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{e}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_num(f: &mut fmt::Formatter<'_>, x: f64) -> fmt::Result {
    if !x.is_finite() {
        // JSON has no NaN/Inf; clamp to null. Canonical encoders never
        // put a non-finite value in `Num` — they use `Json::canon_f32`/
        // `canon_f64`, which encode the bit pattern as a string.
        return write!(f, "null");
    }
    if x == 0.0 && x.is_sign_negative() {
        // The integer fast path below would print `-0.0` as `0`,
        // dropping the sign bit (and with it digest stability).
        return write!(f, "-0");
    }
    if x == x.trunc() && x.abs() < 1e15 {
        write!(f, "{}", x as i64)
    } else {
        // `{:e}` prints the shortest decimal that re-parses to the same
        // f64 — exact round-trip for every finite value.
        write!(f, "{:e}", x)
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected `{}` at byte {}, found `{}`",
                c as char,
                self.i,
                self.peek().map(|b| b as char).unwrap_or('∅')
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number `{s}`: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Advance over a full UTF-8 code point.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] found {:?}", other.map(|b| b as char))),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} found {:?}", other.map(|b| b as char))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let mut o = Json::obj();
        o.set("name", Json::Str("churn \"model\"".into()))
            .set("vals", Json::from_f64_slice(&[1.0, 0.5, -3.25e-8]))
            .set("n", Json::Num(4096.0))
            .set("flag", Json::Bool(false));
        let text = o.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(o, back);
    }

    #[test]
    fn roundtrip_precise_floats() {
        let xs = [0.1, 1.0 / 3.0, std::f64::consts::PI, 1e-300, 123456789.123456];
        let j = Json::from_f64_slice(&xs);
        let back = Json::parse(&j.to_string()).unwrap().f64_vec().unwrap();
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a, b, "{a} vs {b}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn req_errors_name_field() {
        let j = Json::parse(r#"{"x": 1}"#).unwrap();
        let err = j.req_str("x").unwrap_err();
        assert!(err.contains("x"), "{err}");
        assert!(j.req("missing").is_err());
    }

    /// Full canonical round trip for one f64: encode → serialize →
    /// parse → decode must reproduce the exact bit pattern.
    fn rt64(x: f64) -> u64 {
        let text = Json::canon_f64(x).to_string();
        let back = Json::parse(&text).unwrap().decode_f64().unwrap();
        // Canonical also means the re-encoding emits identical bytes
        // (digest stability across encode cycles).
        assert_eq!(Json::canon_f64(back).to_string(), text, "unstable encoding for {x:?}");
        back.to_bits()
    }

    fn rt32(x: f32) -> u32 {
        let text = Json::canon_f32(x).to_string();
        let back = Json::parse(&text).unwrap().decode_f32().unwrap();
        assert_eq!(Json::canon_f32(back).to_string(), text, "unstable encoding for {x:?}");
        back.to_bits()
    }

    #[test]
    fn canon_floats_hostile_values_bit_exact() {
        // The named horrors: negative zero, infinities, quiet/signaling
        // NaNs with payloads, subnormals, extremes.
        for x in [
            -0.0f64,
            0.0,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
            f64::from_bits(0x7ff0_0000_0000_0001), // signaling NaN, payload 1
            f64::from_bits(0xfff8_dead_beef_0123), // negative NaN, payload
            f64::MIN_POSITIVE,
            f64::from_bits(1),  // smallest subnormal
            f64::from_bits(0x000f_ffff_ffff_ffff), // largest subnormal
            f64::MAX,
            f64::MIN,
            f64::EPSILON,
            1e15,
            -1e15,
            0.1,
            std::f64::consts::PI,
        ] {
            assert_eq!(rt64(x), x.to_bits(), "f64 {x:?} (bits {:#018x})", x.to_bits());
        }
        for x in [
            -0.0f32,
            0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::from_bits(0x7f80_0001), // signaling NaN
            f32::from_bits(0xffc0_1234), // negative NaN with payload
            f32::MIN_POSITIVE,
            f32::from_bits(1),           // smallest subnormal
            f32::from_bits(0x007f_ffff), // largest subnormal
            f32::MAX,
            f32::MIN,
            f32::EPSILON,
        ] {
            assert_eq!(rt32(x), x.to_bits(), "f32 {x:?} (bits {:#010x})", x.to_bits());
        }
    }

    #[test]
    fn canon_floats_random_bit_patterns_bit_exact() {
        // Property: ANY bit pattern (finite, NaN-with-payload, subnormal,
        // ±inf all occur under uniform bits) survives the round trip.
        crate::util::prop::check(4096, 0xF10A7, |g| {
            let bits64 = g.u64();
            let bits32 = g.u64() as u32;
            crate::util::prop::require(
                rt64(f64::from_bits(bits64)) == bits64,
                format!("f64 bits {bits64:#018x}"),
            )?;
            crate::util::prop::require(
                rt32(f32::from_bits(bits32)) == bits32,
                format!("f32 bits {bits32:#010x}"),
            )
        });
    }

    #[test]
    fn negative_zero_keeps_its_sign_in_plain_num() {
        // `write_num` regression: -0.0 used to print as `0`.
        let text = Json::Num(-0.0).to_string();
        assert_eq!(text, "-0");
        let back = Json::parse(&text).unwrap().as_f64().unwrap();
        assert!(back == 0.0 && back.is_sign_negative());
    }

    #[test]
    fn decode_rejects_mistagged_patterns() {
        assert!(Json::Str("f64:0xzz".into()).decode_f64().is_err());
        assert!(Json::Str("f32:0x7fc00000".into()).decode_f64().is_err());
        assert!(Json::Str("f64:0x7ff8000000000000".into()).decode_f32().is_err());
        assert!(Json::Null.decode_f32().is_err());
        assert!(Json::Bool(true).decode_f64().is_err());
    }
}
