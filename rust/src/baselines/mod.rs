//! Comparison baselines for Fig. 10/11: the analytical V100/FIL GPU model
//! (substituting the paper's measured GPU, DESIGN.md S8), the Booster ASIC
//! model [26], and a *measured* CPU reference on this machine.

pub mod booster;
pub mod cpu;
pub mod gpu;

pub use booster::{BoosterModel, BoosterWorkload};
pub use cpu::{measure as cpu_measure, CpuReport};
pub use gpu::{GpuModel, GpuWorkload};
