//! Measured CPU baseline: actual wall-clock timing of the reference
//! `Ensemble` inference on this machine. Not a paper figure by itself, but
//! grounds the simulated comparisons with at least one *measured* software
//! point (and is the "exact" functional reference everything must agree
//! with).

use crate::data::Dataset;
use crate::trees::Ensemble;
use crate::util::stats::Summary;
use std::time::Instant;

/// Measured result of CPU batch inference.
#[derive(Clone, Debug)]
pub struct CpuReport {
    pub n_samples: usize,
    /// Per-sample latency stats, nanoseconds.
    pub latency_ns: Summary,
    /// Sustained throughput, samples/s.
    pub throughput_sps: f64,
}

/// Run the model over the first `n` rows of `data` (cycling if needed),
/// timing per-sample latency and aggregate throughput.
pub fn measure(model: &Ensemble, data: &Dataset, n: usize) -> CpuReport {
    assert!(data.n_rows() > 0);
    // Pre-quantize outside the timed loop? No: binning is part of the
    // serving cost on CPU just as the DAC is on chip. Keep it inside.
    let mut lat = Vec::with_capacity(n.min(4096));
    let t0 = Instant::now();
    let mut sink = 0f32;
    for i in 0..n {
        let row = data.row(i % data.n_rows());
        let s = Instant::now();
        sink += model.predict(row);
        if lat.len() < 4096 {
            lat.push(s.elapsed().as_nanos() as f64);
        }
    }
    let total = t0.elapsed().as_secs_f64();
    std::hint::black_box(sink);
    CpuReport {
        n_samples: n,
        latency_ns: Summary::of(&lat),
        throughput_sps: n as f64 / total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    #[test]
    fn measures_positive_throughput() {
        let d = by_name("telco").unwrap().generate_n(500);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 4, max_leaves: 4, ..Default::default() },
            None,
        );
        let r = measure(&m, &d, 1000);
        assert_eq!(r.n_samples, 1000);
        assert!(r.throughput_sps > 1000.0, "{}", r.throughput_sps);
        assert!(r.latency_ns.mean > 0.0);
    }

    #[test]
    fn bigger_models_are_slower() {
        let d = by_name("churn").unwrap().generate_n(800);
        let small = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 2, max_leaves: 4, ..Default::default() },
            None,
        );
        let big = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 64, max_leaves: 64, ..Default::default() },
            None,
        );
        let ts = measure(&small, &d, 3000).throughput_sps;
        let tb = measure(&big, &d, 3000).throughput_sps;
        assert!(tb < ts, "big {tb} ≥ small {ts}");
    }
}
