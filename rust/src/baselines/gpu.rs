//! Analytical NVIDIA V100 / RAPIDS-FIL performance model (DESIGN.md S8).
//!
//! No GPU exists in the execution environment, so the paper's *measured*
//! V100 baseline (§IV-C) is replaced by an analytical model built from the
//! paper's own explanation of what limits GPU tree inference (§II-B):
//!
//!  1. each sample × tree is a chain of `D` *dependent* memory accesses;
//!  2. accesses are coalesced near the root but become uncoalesced with
//!     depth, so the effective node-visit rate decays as trees deepen;
//!  3. a thread-block reduction synchronizes on the slowest (deepest)
//!     tree and adds a global inter-block reduction term;
//!  4. a fixed kernel-launch overhead dominates small batches.
//!
//! Constants are calibrated on the paper's anchor points (documented in
//! EXPERIMENTS.md): Churn at 119× lower throughput / 9740× higher latency
//! than X-TIME, and the overall Fig. 10 envelope (GPU latencies between
//! ~10 µs and ~1 ms across the seven datasets).

/// V100 model constants.
#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    /// Peak node-visit rate with perfectly coalesced access (visits/s).
    /// ~ L2-resident traversal on 80 SMs.
    pub peak_visit_rate: f64,
    /// Depth at which coalescing has decayed by 1× (paper §II-B: the
    /// fraction of coalesced accesses shrinks with every level).
    pub coalesce_depth: f64,
    /// Kernel launch + host-side overhead per inference call (s).
    pub launch_overhead_s: f64,
    /// Inter-thread-block reduction cost per tree (s) — the global
    /// reduction the paper identifies as the third limiter.
    pub block_reduce_s: f64,
    /// Batch size used for throughput saturation measurements (the paper
    /// increased batch size "up to a saturation point").
    pub saturation_batch: usize,
}

impl Default for GpuModel {
    fn default() -> Self {
        GpuModel {
            peak_visit_rate: 4.0e10,
            coalesce_depth: 4.0,
            launch_overhead_s: 10e-6,
            block_reduce_s: 2.0e-9,
            saturation_batch: 4096,
        }
    }
}

/// A model topology as the GPU sees it.
#[derive(Clone, Copy, Debug)]
pub struct GpuWorkload {
    pub n_trees: usize,
    /// Mean tree depth (node visits per tree per sample).
    pub mean_depth: f64,
    /// Max tree depth (synchronization / load imbalance term).
    pub max_depth: f64,
    pub n_features: usize,
}

impl GpuModel {
    /// Effective node-visit rate at a given depth: coalescing decays as
    /// the working set walks away from the root.
    pub fn visit_rate(&self, depth: f64) -> f64 {
        self.peak_visit_rate / (1.0 + depth / self.coalesce_depth)
    }

    /// Node visits per sample.
    fn work(&self, w: &GpuWorkload) -> f64 {
        w.n_trees as f64 * w.mean_depth
    }

    /// Kernel time for a batch of `b` samples (seconds) — the quantity the
    /// paper measures with nvprof (excludes host↔device transfers).
    pub fn batch_latency_s(&self, w: &GpuWorkload, b: usize) -> f64 {
        let rate = self.visit_rate(w.max_depth);
        let traversal = b as f64 * self.work(w) / rate;
        // Load imbalance: blocks wait for the deepest tree before the
        // global reduction (paper limiter #2/#3).
        let reduction = (w.n_trees as f64).log2().max(1.0) * self.block_reduce_s
            + w.max_depth * 1e-8;
        self.launch_overhead_s + traversal + reduction
    }

    /// Saturated throughput, samples/s.
    pub fn throughput_sps(&self, w: &GpuWorkload) -> f64 {
        let b = self.saturation_batch;
        b as f64 / self.batch_latency_s(w, b)
    }

    /// Latency reported in Fig. 10(a): per-batch kernel time at the
    /// saturation batch size.
    pub fn latency_s(&self, w: &GpuWorkload) -> f64 {
        self.batch_latency_s(w, self.saturation_batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn churn() -> GpuWorkload {
        // Table II: 404 trees, 256 leaves → depth ≈ 8.
        GpuWorkload { n_trees: 404, mean_depth: 8.0, max_depth: 10.0, n_features: 10 }
    }

    fn telco() -> GpuWorkload {
        // 159 trees, 4 leaves → depth 2: the small-model case.
        GpuWorkload { n_trees: 159, mean_depth: 2.0, max_depth: 2.0, n_features: 19 }
    }

    #[test]
    fn latencies_land_in_the_paper_decades() {
        let m = GpuModel::default();
        // Fig. 10a: GPU latencies between ~10 µs and ~1 ms.
        let churn_lat = m.latency_s(&churn());
        assert!((1e-4..5e-3).contains(&churn_lat), "churn {churn_lat}");
        let telco_lat = m.latency_s(&telco());
        assert!((1e-5..1e-4).contains(&telco_lat), "telco {telco_lat}");
    }

    #[test]
    fn churn_anchor_point() {
        // The headline: X-TIME (≈500 MS/s, ≈30-100 ns) vs GPU at ~119×
        // lower throughput and ~9740× lower latency. Check the model puts
        // GPU throughput within 2× of 500 MS/s / 119 ≈ 4.2 MS/s.
        let m = GpuModel::default();
        let tput = m.throughput_sps(&churn());
        assert!(
            (2.0e6..9.0e6).contains(&tput),
            "churn GPU throughput {tput} outside anchor band"
        );
    }

    #[test]
    fn throughput_decays_linearly_with_trees_and_depth() {
        // Fig. 11a: GPU throughput ∝ 1/(N_trees · D).
        let m = GpuModel::default();
        let base = GpuWorkload { n_trees: 128, mean_depth: 6.0, max_depth: 6.0, n_features: 32 };
        let double_trees = GpuWorkload { n_trees: 256, ..base };
        let t0 = m.throughput_sps(&base);
        let t1 = m.throughput_sps(&double_trees);
        let ratio = t0 / t1;
        assert!((1.7..2.3).contains(&ratio), "trees scaling ratio {ratio}");
        let deeper = GpuWorkload { mean_depth: 12.0, max_depth: 12.0, ..base };
        let t2 = m.throughput_sps(&deeper);
        assert!(t2 < t0 / 1.8, "depth scaling {t2} vs {t0}");
    }

    #[test]
    fn small_batches_are_launch_bound() {
        let m = GpuModel::default();
        let lat1 = m.batch_latency_s(&telco(), 1);
        // A single sample costs ≈ the launch overhead.
        assert!((lat1 - m.launch_overhead_s).abs() / m.launch_overhead_s < 0.2, "{lat1}");
    }

    #[test]
    fn throughput_flat_in_features() {
        // Fig. 11b: GPU shows no clear N_feat dependence (features are
        // read once into registers; traversal dominates).
        let m = GpuModel::default();
        let few = GpuWorkload { n_trees: 256, mean_depth: 8.0, max_depth: 8.0, n_features: 8 };
        let many = GpuWorkload { n_features: 512, ..few };
        assert_eq!(m.throughput_sps(&few), m.throughput_sps(&many));
    }
}
