//! Booster ASIC baseline (He et al. [26]; paper §V-B comparison).
//!
//! Booster is a purely digital accelerator whose cores store tree nodes in
//! LUTs and *walk* the tree: `D` sequential node fetches per sample, each
//! taking ~4 cycles (fetch node, compare, select child, address). The
//! paper's comparison (Fig. 10) keeps X-TIME's chip fabric (same NoC, same
//! core count) and swaps the core: time complexity per sample is O(D)
//! against the CAM's O(1), and the pipeline can only accept a new sample
//! every `4·D` cycles (§V-B: "throughput limited by the tree depth to
//! 1/4D"), with load imbalance synchronizing on the deepest tree.

use crate::sim::ChipConfig;

/// Booster timing model sharing the X-TIME chip fabric.
#[derive(Clone, Copy, Debug)]
pub struct BoosterModel {
    /// Cycles per tree-node visit (paper: 4).
    pub cycles_per_node: u64,
}

impl Default for BoosterModel {
    fn default() -> Self {
        BoosterModel { cycles_per_node: 4 }
    }
}

/// Workload topology for the Booster model.
#[derive(Clone, Copy, Debug)]
pub struct BoosterWorkload {
    pub max_depth: usize,
    pub n_features: usize,
    pub n_outputs: usize,
    /// Batch replicas mapped on the chip (same replication as X-TIME).
    pub n_replicas: usize,
}

impl BoosterModel {
    /// Core initiation interval: a new sample enters every `4·D_max`
    /// cycles (the deepest tree gates the whole core — load imbalance).
    pub fn core_interval(&self, w: &BoosterWorkload) -> u64 {
        self.cycles_per_node * w.max_depth as u64
    }

    /// Single-sample latency in cycles on the shared fabric: broadcast +
    /// tree walk + reduction + CP (same NoC terms as X-TIME).
    pub fn latency_cycles(&self, w: &BoosterWorkload, cfg: &ChipConfig) -> u64 {
        let levels = cfg.noc_levels();
        let walk = self.cycles_per_node * w.max_depth as u64;
        // +1 leaf fetch, +1 accumulate.
        cfg.input_flits(w.n_features)
            + levels * cfg.hop_cycles
            + walk
            + 2
            + levels * cfg.hop_cycles
            + w.n_outputs as u64
            + cfg.cp_cycles.max(w.n_outputs as u64)
    }

    pub fn latency_s(&self, w: &BoosterWorkload, cfg: &ChipConfig) -> f64 {
        self.latency_cycles(w, cfg) as f64 * cfg.cycle_ns() * 1e-9
    }

    /// Saturated chip throughput, samples/s: min of the core bound
    /// (n_replicas / II), the input broadcast bound and the output bound —
    /// identical fabric limits to X-TIME.
    pub fn throughput_sps(&self, w: &BoosterWorkload, cfg: &ChipConfig) -> f64 {
        let hz = cfg.clock_ghz * 1e9;
        let core = w.n_replicas as f64 / self.core_interval(w) as f64;
        let input = 1.0 / cfg.input_flits(w.n_features) as f64;
        let output = 1.0 / w.n_outputs as f64;
        core.min(input).min(output) * hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn o_of_d_walk_dominates_latency() {
        let cfg = ChipConfig::default();
        let m = BoosterModel::default();
        let shallow = BoosterWorkload { max_depth: 2, n_features: 19, n_outputs: 1, n_replicas: 1 };
        let deep = BoosterWorkload { max_depth: 10, ..shallow };
        let l_shallow = m.latency_cycles(&shallow, &cfg);
        let l_deep = m.latency_cycles(&deep, &cfg);
        assert_eq!(l_deep - l_shallow, 4 * 8, "walk cost is 4 cycles/level");
    }

    #[test]
    fn throughput_is_1_over_4d_per_core() {
        // §V-B: Booster throughput bound is 1/(4·D) samples per clock.
        let cfg = ChipConfig::default();
        let m = BoosterModel::default();
        let w = BoosterWorkload { max_depth: 8, n_features: 8, n_outputs: 1, n_replicas: 1 };
        let tput = m.throughput_sps(&w, &cfg);
        assert!((tput - 1e9 / 32.0).abs() < 1.0, "{tput}");
    }

    #[test]
    fn rossmann_like_8x_gap_vs_xtime() {
        // §V-B: "8× reduced speedup compared to X-TIME in the case of the
        // regression dataset": X-TIME II = 4 vs Booster II = 4·D = 32 at
        // D = 8, with identical fabric bounds elsewhere.
        let cfg = ChipConfig::default();
        let m = BoosterModel::default();
        let w = BoosterWorkload { max_depth: 8, n_features: 29, n_outputs: 1, n_replicas: 1 };
        let booster_ii = m.core_interval(&w);
        let xtime_ii = cfg.core_interval(8, 1);
        assert_eq!(booster_ii / xtime_ii, 8);
    }

    #[test]
    fn replication_helps_until_fabric_bound() {
        let cfg = ChipConfig::default();
        let m = BoosterModel::default();
        let w1 = BoosterWorkload { max_depth: 8, n_features: 130, n_outputs: 1, n_replicas: 1 };
        let w32 = BoosterWorkload { n_replicas: 32, ..w1 };
        let t1 = m.throughput_sps(&w1, &cfg);
        let t32 = m.throughput_sps(&w32, &cfg);
        assert!(t32 > t1);
        // 130 features → 17 input flits: fabric caps at 1/17 per clock.
        let input_bound = 1e9 / 17.0;
        assert!(t32 <= input_bound * 1.001, "{t32}");
    }
}
