//! AOT bucket manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Describes the shape-monomorphic HLO buckets and the
//! padding conventions baked into them.
//!
//! Not to be confused with [`crate::artifact::ArtifactManifest`], the
//! content-addressed record of an exported **model** (program + shard
//! plan). This one describes the **kernel bundle** a checkout compiled
//! ahead of time; the two live in different directories, carry
//! different `format` markers, and are loaded by different code paths.

use crate::util::Json;
use std::path::{Path, PathBuf};

/// One AOT-compiled shape bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct BucketInfo {
    pub file: String,
    pub batch: usize,
    pub features: usize,
    pub rows: usize,
    pub classes: usize,
}

impl BucketInfo {
    /// Can this bucket hold a program of the given dimensions?
    pub fn fits(&self, n_features: usize, n_rows: usize, n_outputs: usize) -> bool {
        self.features >= n_features && self.rows >= n_rows && self.classes >= n_outputs
    }

    /// Padded-volume cost proxy used to pick the cheapest fitting bucket.
    pub fn volume(&self) -> usize {
        self.rows * self.features
    }
}

/// Input/output tensor layout baked into the artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// `qt[u8,F,B], lo[u8,N,F], hi_inc[u8,N,F] → logits[f32,K,B]` — the
    /// perf-optimized layout (EXPERIMENTS.md §Perf).
    TransposedU8,
    /// `q[i32,B,F], lo[i32,N,F], hi[i32,N,F] → logits[f32,B,K]` — the
    /// hardware-mode (direct / macro_cell) kernels.
    BatchMajorI32,
}

/// Parsed `artifacts/manifest.json` (the AOT kernel bundle).
#[derive(Clone, Debug)]
pub struct AotManifest {
    pub dir: PathBuf,
    pub kernel_mode: String,
    pub layout: Layout,
    pub buckets: Vec<BucketInfo>,
}

/// Pre-PR-8 name, kept so existing `runtime::Manifest` callers build;
/// new code should write [`AotManifest`] (and mean the kernel bundle)
/// or [`crate::artifact::ArtifactManifest`] (and mean a stored model).
pub type Manifest = AotManifest;

impl AotManifest {
    pub fn load(dir: &Path) -> Result<AotManifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            format!("{path:?}: {e} — run `make artifacts` to build the AOT bundle")
        })?;
        let j = Json::parse(&text)?;
        if j.req_str("format")? != "hlo-text" {
            return Err("unsupported artifact format".into());
        }
        let buckets = j
            .req_arr("buckets")?
            .iter()
            .map(|b| {
                Ok(BucketInfo {
                    file: b.req_str("file")?.to_string(),
                    batch: b.req_usize("batch")?,
                    features: b.req_usize("features")?,
                    rows: b.req_usize("rows")?,
                    classes: b.req_usize("classes")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        let layout = match j.get("layout").and_then(|l| l.as_str()) {
            Some("transposed_u8") => Layout::TransposedU8,
            _ => Layout::BatchMajorI32,
        };
        Ok(AotManifest {
            dir: dir.to_path_buf(),
            kernel_mode: j.req_str("kernel_mode")?.to_string(),
            layout,
            buckets,
        })
    }

    /// Choose the cheapest bucket that fits the program, preferring batch
    /// capacity ≥ `batch_hint` (falls back to the largest-batch fitting
    /// bucket when no bucket reaches the hint).
    pub fn choose(
        &self,
        n_features: usize,
        n_rows: usize,
        n_outputs: usize,
        batch_hint: usize,
    ) -> Option<&BucketInfo> {
        let fitting: Vec<&BucketInfo> =
            self.buckets.iter().filter(|b| b.fits(n_features, n_rows, n_outputs)).collect();
        if fitting.is_empty() {
            return None;
        }
        let preferred: Vec<&BucketInfo> =
            fitting.iter().copied().filter(|b| b.batch >= batch_hint).collect();
        let pool = if preferred.is_empty() { &fitting } else { &preferred };
        pool.iter()
            .copied()
            .min_by_key(|b| (b.volume(), b.batch))
            .or_else(|| fitting.iter().copied().max_by_key(|b| b.batch))
    }

    pub fn bucket_path(&self, b: &BucketInfo) -> PathBuf {
        self.dir.join(&b.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> AotManifest {
        AotManifest {
            dir: PathBuf::from("/tmp"),
            kernel_mode: "fast_u8".into(),
            layout: Layout::TransposedU8,
            buckets: vec![
                BucketInfo { file: "a".into(), batch: 8, features: 16, rows: 256, classes: 8 },
                BucketInfo { file: "b".into(), batch: 1, features: 32, rows: 2048, classes: 8 },
                BucketInfo { file: "c".into(), batch: 64, features: 32, rows: 2048, classes: 8 },
                BucketInfo { file: "d".into(), batch: 64, features: 130, rows: 16384, classes: 8 },
            ],
        }
    }

    #[test]
    fn choose_prefers_smallest_fitting() {
        let m = toy_manifest();
        let b = m.choose(10, 200, 2, 8).unwrap();
        assert_eq!(b.file, "a");
        // More rows → next bucket up.
        let b = m.choose(10, 1000, 2, 64).unwrap();
        assert_eq!(b.file, "c");
    }

    #[test]
    fn choose_honors_batch_hint() {
        let m = toy_manifest();
        let b1 = m.choose(20, 1000, 1, 1).unwrap();
        assert_eq!(b1.file, "b");
        let b64 = m.choose(20, 1000, 1, 64).unwrap();
        assert_eq!(b64.file, "c");
    }

    #[test]
    fn choose_falls_back_when_hint_unreachable() {
        let m = toy_manifest();
        let b = m.choose(100, 10_000, 7, 512).unwrap();
        assert_eq!(b.file, "d");
    }

    #[test]
    fn choose_rejects_oversize() {
        let m = toy_manifest();
        assert!(m.choose(200, 100, 1, 1).is_none());
        assert!(m.choose(10, 100_000, 1, 1).is_none());
        assert!(m.choose(10, 100, 9, 1).is_none());
    }

    #[test]
    fn loads_real_manifest_when_built() {
        // Integration with the actual `make artifacts` output, skipped if
        // the bundle has not been built in this checkout.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = AotManifest::load(&dir).unwrap();
        assert!(!m.buckets.is_empty());
        assert!(m.buckets.iter().any(|b| b.features >= 130));
        for b in &m.buckets {
            assert!(m.bucket_path(b).exists(), "{:?} missing", b.file);
        }
    }
}
