//! XLA/PJRT inference engine: loads an AOT-compiled HLO bucket and serves
//! a compiled [`CamProgram`] on the CPU PJRT client.
//!
//! Layer boundaries (DESIGN.md §1): Python lowered the L2 graph once at
//! build time; this module only *loads and executes* `artifacts/*.hlo.txt`
//! — no Python anywhere near the request path.
//!
//! Hot-path design: the program tensors (`lo`, `hi`, `leaf`) are uploaded
//! to device buffers **once** at engine construction; each request batch
//! only uploads the (tiny) query literal and executes via `execute_b`.

use super::manifest::{AotManifest, BucketInfo, Layout};
use crate::compiler::CamProgram;
use crate::data::Task;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// PJRT-backed engine for one compiled program.
pub struct XlaCamEngine {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    bucket: BucketInfo,
    /// Program tensors resident on device.
    lo_buf: xla::PjRtBuffer,
    hi_buf: xla::PjRtBuffer,
    leaf_buf: xla::PjRtBuffer,
    pub task: Task,
    base_score: Vec<f32>,
    n_features: usize,
    n_outputs: usize,
    /// Bin-space → 8-bit scale (4-bit programs upshift by 16).
    scale: i32,
    layout: Layout,
}

impl XlaCamEngine {
    /// Build from a compiled program + artifact directory, choosing the
    /// cheapest bucket that fits (batch capacity ≥ `batch_hint` preferred).
    pub fn new(program: &CamProgram, artifacts: &Path, batch_hint: usize) -> Result<XlaCamEngine> {
        let manifest = AotManifest::load(artifacts).map_err(|e| anyhow!(e))?;
        Self::with_manifest(program, &manifest, batch_hint)
    }

    pub fn with_manifest(
        program: &CamProgram,
        manifest: &AotManifest,
        batch_hint: usize,
    ) -> Result<XlaCamEngine> {
        let n_rows = program.total_rows();
        let n_outputs = program.task.n_outputs();
        let bucket = manifest
            .choose(program.n_features, n_rows, n_outputs, batch_hint)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact bucket fits program (F={}, N={n_rows}, K={n_outputs}); \
                     re-run `make artifacts` with larger buckets or use the functional engine",
                    program.n_features
                )
            })?
            .clone();

        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let path = manifest.bucket_path(&bucket);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .with_context(|| format!("parsing {path:?}"))?;
        let exe = client
            .compile(&xla::XlaComputation::from_proto(&proto))
            .with_context(|| format!("compiling {path:?}"))?;

        // Pad program tensors into the bucket's shapes (same conventions
        // as python/compile/model.py::pad_program).
        let scale = (256 / program.n_bins.max(1)) as i32;
        let (nb, fb, kb) = (bucket.rows, bucket.features, bucket.classes);
        let mut lo = vec![0i32; nb * fb];
        let mut hi = vec![256i32; nb * fb];
        let mut leaf = vec![0f32; nb * kb];
        // Padding rows: never match.
        for r in n_rows..nb {
            for f in 0..fb {
                lo[r * fb + f] = 256;
                hi[r * fb + f] = 0;
            }
        }
        let mut r = 0usize;
        for core in &program.cores {
            for row in &core.rows {
                for f in 0..program.n_features {
                    lo[r * fb + f] = row.lo[f] as i32 * scale;
                    hi[r * fb + f] = row.hi[f] as i32 * scale;
                }
                leaf[r * kb + row.class as usize] = row.leaf;
                r += 1;
            }
        }
        debug_assert_eq!(r, n_rows);

        let (lo_buf, hi_buf) = match manifest.layout {
            Layout::TransposedU8 => {
                // u8 packing with INCLUSIVE upper bound: hi_inc = hi - 1;
                // never-match padding keeps lo=255 > hi_inc=0.
                let lo8: Vec<u8> = lo.iter().map(|&v| v.min(255) as u8).collect();
                let hi8: Vec<u8> = hi.iter().map(|&v| (v - 1).clamp(0, 255) as u8).collect();
                (
                    client
                        .buffer_from_host_buffer::<u8>(&lo8, &[nb, fb], None)
                        .context("uploading lo bounds (u8)")?,
                    client
                        .buffer_from_host_buffer::<u8>(&hi8, &[nb, fb], None)
                        .context("uploading hi bounds (u8)")?,
                )
            }
            Layout::BatchMajorI32 => (
                client
                    .buffer_from_host_buffer::<i32>(&lo, &[nb, fb], None)
                    .context("uploading lo bounds")?,
                client
                    .buffer_from_host_buffer::<i32>(&hi, &[nb, fb], None)
                    .context("uploading hi bounds")?,
            ),
        };
        let leaf_buf = client
            .buffer_from_host_buffer::<f32>(&leaf, &[nb, kb], None)
            .context("uploading leaf table")?;

        Ok(XlaCamEngine {
            client,
            exe,
            bucket,
            lo_buf,
            hi_buf,
            leaf_buf,
            task: program.task,
            base_score: program.base_score.clone(),
            n_features: program.n_features,
            n_outputs,
            scale,
            layout: manifest.layout,
        })
    }

    pub fn bucket(&self) -> &BucketInfo {
        &self.bucket
    }

    pub fn max_batch(&self) -> usize {
        self.bucket.batch
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// The program's additive prior (folded into `infer_bins_batch`
    /// outputs); sharded serving subtracts it to recover partial sums.
    pub fn base_score(&self) -> &[f32] {
        &self.base_score
    }

    /// Run one padded device batch over quantized bin rows
    /// (`rows.len() ≤ bucket.batch`). Returns logits per row.
    pub fn infer_bins_batch(&self, rows: &[Vec<u16>]) -> Result<Vec<Vec<f32>>> {
        let b = rows.len();
        assert!(b > 0 && b <= self.bucket.batch, "batch {b} exceeds bucket");
        let (bb, fb) = (self.bucket.batch, self.bucket.features);
        let q_buf = match self.layout {
            Layout::TransposedU8 => {
                // qt[F, B] u8 — batch innermost (perf layout).
                let mut q = vec![0u8; fb * bb];
                for (i, row) in rows.iter().enumerate() {
                    assert_eq!(row.len(), self.n_features, "feature arity mismatch");
                    for (f, &v) in row.iter().enumerate() {
                        q[f * bb + i] = (v as i32 * self.scale).min(255) as u8;
                    }
                }
                self.client
                    .buffer_from_host_buffer::<u8>(&q, &[fb, bb], None)
                    .context("uploading query batch (u8)")?
            }
            Layout::BatchMajorI32 => {
                let mut q = vec![0i32; bb * fb];
                for (i, row) in rows.iter().enumerate() {
                    assert_eq!(row.len(), self.n_features, "feature arity mismatch");
                    for (f, &v) in row.iter().enumerate() {
                        q[i * fb + f] = v as i32 * self.scale;
                    }
                }
                self.client
                    .buffer_from_host_buffer::<i32>(&q, &[bb, fb], None)
                    .context("uploading query batch")?
            }
        };
        let result = self
            .exe
            .execute_b(&[&q_buf, &self.lo_buf, &self.hi_buf, &self.leaf_buf])
            .context("executing CAM kernel")?;
        let lit = result[0][0].to_literal_sync().context("fetching result")?;
        let out = lit.to_tuple1().context("unwrapping 1-tuple")?;
        let flat = out.to_vec::<f32>().context("reading logits")?;
        let kb = self.bucket.classes;
        let mut logits = Vec::with_capacity(b);
        for i in 0..b {
            let mut l: Vec<f32> = match self.layout {
                // logits[K, B]: stride bb per class.
                Layout::TransposedU8 => {
                    (0..self.n_outputs).map(|k| flat[k * bb + i]).collect()
                }
                // logits[B, K]: contiguous per row.
                Layout::BatchMajorI32 => flat[i * kb..i * kb + self.n_outputs].to_vec(),
            };
            for (v, base) in l.iter_mut().zip(&self.base_score) {
                *v += base;
            }
            logits.push(l);
        }
        Ok(logits)
    }

    /// Quantize raw feature rows with the program's quantizer and infer.
    pub fn infer_rows(&self, program: &CamProgram, rows: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let bins: Vec<Vec<u16>> = rows.iter().map(|r| program.quantizer.bin_row(r)).collect();
        let mut out = Vec::with_capacity(bins.len());
        for chunk in bins.chunks(self.bucket.batch) {
            out.extend(self.infer_bins_batch(chunk)?);
        }
        Ok(out)
    }

    /// End-to-end predictions (CP decision applied).
    pub fn predict_rows(&self, program: &CamProgram, rows: &[&[f32]]) -> Result<Vec<f32>> {
        Ok(self.infer_rows(program, rows)?.iter().map(|l| self.task.decide(l)).collect())
    }
}
