//! PJRT (XLA) runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them on the request path with no
//! Python involvement (DESIGN.md §1).
//!
//! The artifact bundle is optional at runtime: without one, the loader
//! reports a clean error (and serving falls back to the functional
//! backend — `xtime serve --backend auto`), it never panics:
//!
//! ```
//! use std::path::Path;
//! use xtime::runtime::AotManifest;
//!
//! let err = AotManifest::load(Path::new("no/such/artifacts")).unwrap_err();
//! assert!(err.contains("make artifacts"), "error should say how to build: {err}");
//! ```

pub mod engine;
pub mod manifest;

pub use engine::XlaCamEngine;
pub use manifest::{AotManifest, BucketInfo, Manifest};
