//! PJRT (XLA) runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them on the request path with no
//! Python involvement (DESIGN.md §1).

pub mod engine;
pub mod manifest;

pub use engine::XlaCamEngine;
pub use manifest::{BucketInfo, Manifest};
