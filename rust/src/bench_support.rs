//! Shared helpers for the `rust/benches/` harnesses: a trained-model cache
//! (benches share Table II models instead of retraining), synthetic
//! ensemble generators for the Fig. 11 sweeps, and the sharded-pool
//! builder the scaling bench/example/tests share.

use crate::compiler::{CamProgram, ShardPlan};
use crate::coordinator::{
    Admission, Backend, BatchPolicy, Fleet, FleetStats, FunctionalBackend, Server,
};
use crate::data::{by_name, Dataset, FeatureQuantizer, Task};
use crate::trees::{paper_model, train_paper_model, Ensemble, Node, Tree};
use crate::util::bench::Table;
use crate::util::{Json, Rng};
use std::path::PathBuf;

/// `XTIME_FAST=1` shrinks bench workloads ~8× (CI-friendly smoke runs).
pub fn fast_mode() -> bool {
    std::env::var("XTIME_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Tree-count scale for trained-model benches.
pub fn tree_scale() -> f64 {
    if fast_mode() {
        0.125
    } else {
        1.0
    }
}

/// Write `BENCH_<name>.json` at the repo root: the machine-readable perf
/// trajectory next to `CHANGES.md`. Benches call this so every run
/// leaves a datapoint CI can upload as an artifact; keys should be
/// stable across PRs so the files diff meaningfully.
pub fn write_bench_json(name: &str, json: &Json) -> PathBuf {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(format!("BENCH_{name}.json"));
    std::fs::write(&path, format!("{json}\n"))
        .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    println!("wrote {}", path.display());
    path
}

/// Latency-tail summary (`p50/p90/p99/p999/mean/max/n`) of a sample in
/// seconds, as a stable-keyed object for `BENCH_*.json` files —
/// `Json::Null` on an empty sample (a tenant that never got a reply).
/// `xtime loadgen` writes these into `BENCH_serving.json`
/// (docs/BENCHMARKS.md documents the schema).
pub fn latency_tail_json(samples: &[f64]) -> Json {
    if samples.is_empty() {
        return Json::Null;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
    let mut j = Json::obj();
    j.set("n", Json::Num(sorted.len() as f64))
        .set("p50", Json::Num(crate::util::stats::percentile_sorted(&sorted, 50.0)))
        .set("p90", Json::Num(crate::util::stats::percentile_sorted(&sorted, 90.0)))
        .set("p99", Json::Num(crate::util::stats::percentile_sorted(&sorted, 99.0)))
        .set("p999", Json::Num(crate::util::stats::percentile_sorted(&sorted, 99.9)))
        .set("mean", Json::Num(mean))
        .set("max", Json::Num(*sorted.last().unwrap()));
    j
}

fn cache_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target/xtime_bench_cache");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Table II dataset at its catalog generation size.
pub fn bench_dataset(name: &str) -> Dataset {
    by_name(name).unwrap_or_else(|| panic!("unknown dataset {name}")).generate()
}

/// Canonical bench split (80/20, fixed seed). [`cached_model`] trains on
/// `.train`; benches must evaluate on `.test` of the same split.
pub fn bench_split(name: &str) -> crate::data::Split {
    bench_dataset(name).split(0.8, 0.0, 17)
}

/// Train (or load from cache) a Table II model. `n_bits` / `leaves_mult`
/// parameterize the Fig. 9a precision regimes; `trees` of `None` uses the
/// paper topology scaled by [`tree_scale`].
pub fn cached_model(
    name: &str,
    n_bits: u8,
    leaves_mult: usize,
    trees: Option<usize>,
) -> Ensemble {
    let spec = paper_model(name).unwrap_or_else(|| panic!("unknown dataset {name}"));
    let n_trees = trees.unwrap_or(((spec.n_trees as f64 * tree_scale()) as usize).max(4));
    let leaves = (spec.n_leaves_max * leaves_mult).min(256 * leaves_mult);
    let key = format!("{name}_b{n_bits}_l{leaves}_t{n_trees}.json");
    let path = cache_dir().join(&key);
    if let Ok(model) = Ensemble::load(&path) {
        return model;
    }
    // Train on the canonical bench split so evaluations on
    // `bench_split(name).test` are honest held-out scores.
    let split = bench_split(name);
    let model = train_paper_model(&split.train, &spec, n_bits, leaves, Some(n_trees));
    let _ = model.save(&path);
    model
}

/// A random balanced ensemble with exact topology (N_trees, depth, F) for
/// the Fig. 11 architecture sweeps — no training needed: architecture
/// latency/throughput depend only on topology.
pub fn random_ensemble(
    n_trees: usize,
    depth: usize,
    n_features: usize,
    task: Task,
    seed: u64,
) -> Ensemble {
    let n_bins = 256usize;
    let mut rng = Rng::new(seed);
    let k = task.n_outputs();
    let mut trees = Vec::with_capacity(n_trees);
    let mut tree_class = Vec::with_capacity(n_trees);
    for t in 0..n_trees {
        let mut tr = rng.fork(t as u64);
        trees.push(random_tree(depth, n_features, n_bins, &mut tr));
        tree_class.push((t % k) as u16);
    }
    // Uniform quantizer over [0, 1).
    let edges: Vec<Vec<f32>> = (0..n_features)
        .map(|_| (1..n_bins).map(|b| b as f32 / n_bins as f32).collect())
        .collect();
    Ensemble {
        name: format!("synthetic_t{n_trees}_d{depth}_f{n_features}"),
        task,
        n_features,
        trees,
        tree_class,
        base_score: vec![0.0; k],
        quantizer: FeatureQuantizer { n_bits: 8, edges },
    }
}

/// A quantized query batch for bench/test harnesses: `n` rows drawn
/// uniformly from the program's feature space and binned with its
/// quantizer. Shared by `benches/hotpath.rs`, `benches/shard_scaling.rs`
/// and `rust/tests/batch_agreement.rs` so measured and tested query
/// distributions cannot drift apart.
pub fn random_query_bins(program: &CamProgram, n: usize, seed: u64) -> Vec<Vec<u16>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let row: Vec<f32> = (0..program.n_features).map(|_| rng.f32()).collect();
            program.quantizer.bin_row(&row)
        })
        .collect()
}

/// Build a serving pool with one functional backend per shard of `plan` —
/// the software stand-in for one PCIe card per shard. Shared by
/// `benches/shard_scaling.rs`, `examples/fraud_serving.rs` and
/// `rust/tests/sharding.rs` so the measured configuration cannot drift
/// between them.
pub fn sharded_functional_pool(plan: &ShardPlan, policy: BatchPolicy) -> Server {
    let backends: Vec<Box<dyn Backend>> = plan
        .shards
        .iter()
        .map(|s| Box::new(FunctionalBackend::new(s)) as Box<dyn Backend>)
        .collect();
    Server::start_sharded(backends, plan.base_score.clone(), policy, plan.n_features)
}

/// One tenant of a skewed load mix driven by [`drive_skewed_mix`].
pub struct MixTenant<'a> {
    /// Registered model name in the fleet.
    pub name: &'a str,
    /// Request rows are drawn from this dataset (cycled).
    pub data: &'a Dataset,
    /// Relative share of the mix (integer weight > 0).
    pub weight: usize,
}

/// Outcome of one [`drive_skewed_mix`] run; `served + shed + errors`
/// equals the offered request count exactly.
pub struct MixOutcome {
    /// Requests admitted and answered with a successful reply.
    pub served: usize,
    /// Requests refused at a route's admission bound.
    pub shed: usize,
    /// Requests admitted but answered with an error reply (or dropped).
    pub errors: usize,
    /// Wall-clock seconds from first submit to last reply.
    pub wall_s: f64,
}

/// Drive a weighted multi-tenant request mix through `fleet`: each
/// request picks a tenant with probability proportional to its weight
/// (deterministic given `seed`), submits a row from that tenant's
/// dataset, and every accepted reply is awaited. Shared by
/// `xtime serve --models …` and `examples/fleet_serving.rs` so the two
/// load drivers cannot drift apart.
pub fn drive_skewed_mix(
    fleet: &Fleet,
    tenants: &[MixTenant],
    n_requests: usize,
    seed: u64,
) -> Result<MixOutcome, String> {
    assert!(!tenants.is_empty(), "need at least one tenant");
    assert!(tenants.iter().all(|t| t.weight > 0), "weights must be positive");
    let total_weight: usize = tenants.iter().map(|t| t.weight).sum();
    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(n_requests);
    let mut shed = 0usize;
    for r in 0..n_requests {
        let mut pick = rng.below(total_weight);
        let mut ti = 0usize;
        while pick >= tenants[ti].weight {
            pick -= tenants[ti].weight;
            ti += 1;
        }
        let d = tenants[ti].data;
        match fleet.submit(tenants[ti].name, d.row(r % d.n_rows()))? {
            Admission::Accepted(rx) => pending.push(rx),
            Admission::Shed { .. } => shed += 1,
        }
    }
    let mut served = 0usize;
    let mut errors = 0usize;
    for rx in pending {
        match rx.recv() {
            Ok(reply) if reply.is_ok() => served += 1,
            _ => errors += 1,
        }
    }
    Ok(MixOutcome { served, shed, errors, wall_s: t0.elapsed().as_secs_f64() })
}

/// Render a [`FleetStats`] snapshot as the standard fleet table —
/// shared by `xtime serve --models …` and `examples/fleet_serving.rs`
/// so the two surfaces can't drift apart.
pub fn fleet_table(stats: &FleetStats) -> Table {
    let mut table = Table::new(&[
        "model",
        "shards",
        "admitted",
        "shed",
        "served",
        "errors",
        "mean batch",
        "p50",
        "p95",
        "queue",
    ]);
    for m in &stats.models {
        let (p50, p95) = match &m.latency {
            Some(s) => (crate::util::bench::t(s.median), crate::util::bench::t(s.p95)),
            None => ("-".to_string(), "-".to_string()),
        };
        let cap = if m.queue_cap == 0 { "∞".to_string() } else { m.queue_cap.to_string() };
        table.row(&[
            m.name.clone(),
            m.shards.to_string(),
            m.admitted.to_string(),
            m.shed.to_string(),
            m.served.to_string(),
            m.errors.to_string(),
            format!("{:.1}", m.mean_batch),
            p50,
            p95,
            format!("{}/{cap}", m.queue_depth),
        ]);
    }
    table
}

fn random_tree(depth: usize, n_features: usize, n_bins: usize, rng: &mut Rng) -> Tree {
    // Complete binary tree: internal nodes then leaves, built recursively.
    let mut tree = Tree::default();
    build_node(&mut tree, depth, n_features, n_bins, rng);
    tree
}

fn build_node(
    tree: &mut Tree,
    depth: usize,
    n_features: usize,
    n_bins: usize,
    rng: &mut Rng,
) -> u32 {
    let idx = tree.nodes.len() as u32;
    if depth == 0 {
        tree.nodes.push(Node::Leaf { value: rng.f32() - 0.5 });
        return idx;
    }
    tree.nodes.push(Node::Leaf { value: 0.0 }); // placeholder
    let left = build_node(tree, depth - 1, n_features, n_bins, rng);
    let right = build_node(tree, depth - 1, n_features, n_bins, rng);
    tree.nodes[idx as usize] = Node::Split {
        feature: rng.below(n_features) as u32,
        threshold_bin: (1 + rng.below(n_bins - 1)) as u16,
        left,
        right,
    };
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_ensemble_topology_exact() {
        let e = random_ensemble(16, 5, 32, Task::Binary, 9);
        assert_eq!(e.n_trees(), 16);
        assert!(e.trees.iter().all(|t| t.n_leaves() == 32 && t.depth() == 5));
        assert_eq!(e.n_features, 32);
        // Predictions well-defined on arbitrary rows.
        let row = vec![0.3f32; 32];
        let l = e.logits(&row);
        assert_eq!(l.len(), 1);
        assert!(l[0].is_finite());
    }

    #[test]
    fn random_ensemble_multiclass_classes_cycle() {
        let e = random_ensemble(9, 3, 8, Task::MultiClass(3), 4);
        assert_eq!(e.tree_class, vec![0, 1, 2, 0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn fleet_table_renders_with_and_without_latency() {
        use crate::coordinator::ModelStats;
        let row = |name: &str, latency| ModelStats {
            name: name.to_string(),
            shards: 2,
            epoch: 1,
            degraded: false,
            admitted: 10,
            shed: 3,
            served: 9,
            errors: 1,
            batches: 4,
            mean_batch: 2.5,
            queue_depth: 0,
            queue_cap: 64,
            latency,
            shard_stats: Vec::new(),
        };
        let stats = FleetStats {
            models: vec![
                row("warm", crate::util::stats::Summary::try_of(&[0.001, 0.002])),
                row("cold", None),
            ],
            admitted: 20,
            shed: 6,
        };
        // Renders without panicking for both populated and empty latency.
        fleet_table(&stats).print("smoke");
    }

    #[test]
    fn latency_tail_json_is_ordered_and_null_on_empty() {
        assert_eq!(latency_tail_json(&[]), Json::Null);
        let samples: Vec<f64> = (1..=1000).map(|i| i as f64 / 1000.0).collect();
        let j = latency_tail_json(&samples);
        let p50 = j.req_f64("p50").unwrap();
        let p99 = j.req_f64("p99").unwrap();
        let p999 = j.req_f64("p999").unwrap();
        let max = j.req_f64("max").unwrap();
        assert!(p50 <= p99 && p99 <= p999 && p999 <= max);
        assert!((p50 - 0.5005).abs() < 1e-9, "p50={p50}");
        assert_eq!(max, 1.0);
        assert_eq!(j.req_f64("n").unwrap() as usize, 1000);
    }

    #[test]
    fn cached_model_roundtrip() {
        let a = cached_model("telco", 8, 1, Some(6));
        let b = cached_model("telco", 8, 1, Some(6)); // from cache
        assert_eq!(a.n_trees(), b.n_trees());
        let d = bench_dataset("telco");
        for i in 0..20 {
            assert_eq!(a.predict(d.row(i)), b.predict(d.row(i)));
        }
    }
}
