//! Pluggable inference backends for the serving engine.
//!
//! Three backends implement the same contract and must agree numerically
//! (integration-tested in `rust/tests/end_to_end.rs`):
//!
//! * [`CpuExactBackend`] — the reference `Ensemble` tree-walk (software
//!   baseline);
//! * [`FunctionalBackend`] — the analog-CAM functional model (bit-accurate
//!   chip semantics, defect-injectable);
//! * [`XlaBackend`] — the AOT-compiled Pallas/XLA artifact on PJRT (the
//!   production hot path).

use crate::compiler::{CamEngine, CamProgram};
use crate::data::Task;
use crate::runtime::XlaCamEngine;
use crate::trees::Ensemble;
use anyhow::Result;

/// A batch inference backend. `&mut self` because backends may keep
/// scratch state; each backend instance is owned by one worker thread.
pub trait Backend: Send {
    fn name(&self) -> &'static str;
    /// Preferred device batch size.
    fn max_batch(&self) -> usize;
    fn task(&self) -> Task;
    /// Logits (base score included) for a batch of quantized bin rows.
    /// Implementations should serve the whole batch through their
    /// engine's batched path (e.g. [`CamEngine::infer_batch`]) rather
    /// than looping rows — the worker threads hand over full device
    /// batches and the batched/scalar agreement contract (DESIGN.md §5)
    /// guarantees identical results.
    fn infer(&mut self, batch: &[Vec<u16>]) -> Result<Vec<Vec<f32>>>;

    /// Base-free per-class partial sums in f64, for shard aggregation:
    /// the sharded server sums these across shards in shard order, then
    /// applies the plan's base score once (`sum as f32 + base`).
    ///
    /// No default lift of [`Backend::infer`] is provided on purpose:
    /// `infer` folds the program's base score into its logits, and shard 0
    /// of a [`crate::compiler::ShardPlan`] carries the full base — a
    /// lifted default would silently double-count it. Backends that want
    /// to serve as shards must implement a genuinely base-free path (all
    /// built-in backends do); the default fails loudly instead.
    fn infer_partials(&mut self, _batch: &[Vec<u16>]) -> Result<Vec<Vec<f64>>> {
        Err(anyhow::anyhow!(
            "backend `{}` does not implement base-free partial sums \
             (required for sharded serving)",
            self.name()
        ))
    }

    /// CP decision per row.
    fn predict(&mut self, batch: &[Vec<u16>]) -> Result<Vec<f32>> {
        let task = self.task();
        Ok(self.infer(batch)?.iter().map(|l| task.decide(l)).collect())
    }

    /// Set the worker-thread count engine-backed backends use for the
    /// planned execution path (0 = one worker per available CPU). The
    /// planned path is bit-identical across thread counts, so this is a
    /// pure throughput knob; backends without an internal parallel path
    /// ignore it. Plumbed from [`crate::coordinator::BatchPolicy::threads`]
    /// by `Server::start`/`start_sharded`.
    fn set_threads(&mut self, _threads: usize) {}
}

/// Exact CPU tree-walk reference.
pub struct CpuExactBackend {
    pub model: Ensemble,
}

impl Backend for CpuExactBackend {
    fn name(&self) -> &'static str {
        "cpu-exact"
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn task(&self) -> Task {
        self.model.task
    }

    fn infer(&mut self, batch: &[Vec<u16>]) -> Result<Vec<Vec<f32>>> {
        Ok(batch.iter().map(|bins| self.model.logits_bins(bins)).collect())
    }

    /// Deliberately uses the CAM engines' arithmetic (f64 accumulation,
    /// single final rounding), *not* `logits_bins`' f32 running sum: a
    /// sharded pool must be bit-identical across backend kinds, so CPU
    /// shards match functional shards exactly — at the cost of a ≤ 1 ulp
    /// difference vs this backend's own unsharded `infer`.
    fn infer_partials(&mut self, batch: &[Vec<u16>]) -> Result<Vec<Vec<f64>>> {
        Ok(batch.iter().map(|bins| self.model.partial_sums_bins(bins)).collect())
    }
}

/// Analog-CAM functional model backend.
pub struct FunctionalBackend {
    pub engine: CamEngine,
    /// Planned-path worker threads (0 = auto; default 1).
    threads: usize,
}

impl FunctionalBackend {
    /// Single-threaded planned execution (the deterministic default; the
    /// planned path is bit-identical at every thread count anyway).
    pub fn new(program: &CamProgram) -> FunctionalBackend {
        Self::with_threads(program, 1)
    }

    /// Planned execution over `threads` workers (0 = one per available
    /// CPU).
    pub fn with_threads(program: &CamProgram, threads: usize) -> FunctionalBackend {
        FunctionalBackend { engine: CamEngine::new(program), threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}

impl Backend for FunctionalBackend {
    fn name(&self) -> &'static str {
        "cam-functional"
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn task(&self) -> Task {
        self.engine.task
    }

    /// Serves through [`CamEngine::infer_planned`] — the planned LUT +
    /// arena hot path, bit-identical to the row-at-a-time scalar engine
    /// at every thread count (property-tested in
    /// `rust/tests/batch_agreement.rs`).
    fn infer(&mut self, batch: &[Vec<u16>]) -> Result<Vec<Vec<f32>>> {
        Ok(self.engine.infer_planned(batch, self.threads))
    }

    fn infer_partials(&mut self, batch: &[Vec<u16>]) -> Result<Vec<Vec<f64>>> {
        Ok(self.engine.partials_planned(batch, self.threads))
    }

    fn set_threads(&mut self, threads: usize) {
        self.threads = threads;
    }
}

/// AOT XLA artifact backend (PJRT CPU).
pub struct XlaBackend {
    pub engine: XlaCamEngine,
}

// SAFETY: `XlaCamEngine` is not auto-Send because the `xla` crate wraps
// PJRT handles in `Rc` + raw pointers. Every `Rc` clone of the client
// lives *inside* the engine struct (client + the buffers holding client
// back-references), so moving the whole engine into exactly one worker
// thread — the only thing `Server::start` does — transfers all owners
// together and no cross-thread aliasing can occur. The engine is never
// shared (&-aliased) across threads; `Backend::infer` takes `&mut self`
// on the owning worker.
unsafe impl Send for XlaBackend {}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla-aot"
    }

    fn max_batch(&self) -> usize {
        self.engine.max_batch()
    }

    fn task(&self) -> Task {
        self.engine.task
    }

    fn infer(&mut self, batch: &[Vec<u16>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(batch.len());
        for chunk in batch.chunks(self.engine.max_batch()) {
            out.extend(self.engine.infer_bins_batch(chunk)?);
        }
        Ok(out)
    }

    /// The XLA kernel only produces f32 logits with the base already
    /// folded in, so partials are recovered by subtracting the base.
    /// `(partial + base) - base` is *not* exact under f32 rounding (error
    /// up to ½ ulp of the base per class), so an XLA shard is near-exact
    /// rather than bit-exact — consistent with the kernel's own 1e-3
    /// agreement contract (tests/runtime_xla.rs). Bit-identical sharding
    /// is guaranteed for the functional/CPU/sim-card backends only.
    fn infer_partials(&mut self, batch: &[Vec<u16>]) -> Result<Vec<Vec<f64>>> {
        let base = self.engine.base_score().to_vec();
        Ok(self
            .infer(batch)?
            .into_iter()
            .map(|l| {
                l.into_iter()
                    .enumerate()
                    .map(|(k, v)| (v - base.get(k).copied().unwrap_or(0.0)) as f64)
                    .collect()
            })
            .collect())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    fn setup() -> (crate::data::Dataset, Ensemble, CamProgram) {
        let d = by_name("telco").unwrap().generate_n(700);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 6, max_leaves: 4, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        (d, m, p)
    }

    #[test]
    fn functional_and_cpu_backends_agree() {
        let (d, m, p) = setup();
        let mut cpu = CpuExactBackend { model: m };
        let mut cam = FunctionalBackend::new(&p);
        let bins: Vec<Vec<u16>> =
            (0..32).map(|i| p.quantizer.bin_row(d.row(i))).collect();
        let a = cpu.predict(&bins).unwrap();
        let b = cam.predict(&bins).unwrap();
        assert_eq!(a, b);
        assert_eq!(cpu.task(), cam.task());
    }

    #[test]
    fn partials_plus_base_reproduce_infer() {
        let (d, _, p) = setup();
        let mut cam = FunctionalBackend::new(&p);
        let bins = vec![p.quantizer.bin_row(d.row(3))];
        let logits = cam.infer(&bins).unwrap();
        let partials = cam.infer_partials(&bins).unwrap();
        for (k, &l) in logits[0].iter().enumerate() {
            let b = p.base_score.get(k).copied().unwrap_or(0.0);
            assert_eq!(l, partials[0][k] as f32 + b, "class {k}");
        }
    }

    #[test]
    fn functional_backend_batch_is_bit_identical_to_scalar_engine() {
        // The backend serves through the batched interval index; its
        // output must equal the row-at-a-time scalar engine bit for bit.
        let (d, _, p) = setup();
        let mut cam = FunctionalBackend::new(&p);
        let scalar = CamEngine::new(&p);
        let bins: Vec<Vec<u16>> = (0..48).map(|i| p.quantizer.bin_row(d.row(i))).collect();
        let logits = cam.infer(&bins).unwrap();
        let partials = cam.infer_partials(&bins).unwrap();
        for (i, b) in bins.iter().enumerate() {
            assert_eq!(logits[i], scalar.infer_bins(b), "row {i} logits");
            assert_eq!(partials[i], scalar.partials_bins(b), "row {i} partials");
        }
    }

    #[test]
    fn threaded_backend_is_bit_identical_too() {
        // The threads knob is a throughput lever only: a multi-worker
        // backend must serve the exact bits of the single-worker one.
        let (d, _, p) = setup();
        let mut one = FunctionalBackend::new(&p);
        let mut many = FunctionalBackend::with_threads(&p, 4);
        assert_eq!(many.threads(), 4);
        let bins: Vec<Vec<u16>> = (0..40).map(|i| p.quantizer.bin_row(d.row(i))).collect();
        assert_eq!(one.infer(&bins).unwrap(), many.infer(&bins).unwrap());
        assert_eq!(one.infer_partials(&bins).unwrap(), many.infer_partials(&bins).unwrap());
        // And `set_threads` re-routes the same backend live.
        many.set_threads(0); // auto
        assert_eq!(one.infer(&bins).unwrap(), many.infer(&bins).unwrap());
    }

    #[test]
    fn empty_batch_serves_empty() {
        let (_, m, p) = setup();
        let mut cam = FunctionalBackend::new(&p);
        let mut cpu = CpuExactBackend { model: m };
        assert!(cam.infer(&[]).unwrap().is_empty());
        assert!(cam.infer_partials(&[]).unwrap().is_empty());
        assert!(cpu.predict(&[]).unwrap().is_empty());
    }

    #[test]
    fn default_predict_applies_decision() {
        let (d, m, p) = setup();
        let task = m.task;
        let mut cpu = CpuExactBackend { model: m };
        let bins = vec![p.quantizer.bin_row(d.row(0))];
        let logits = cpu.infer(&bins).unwrap();
        let preds = cpu.predict(&bins).unwrap();
        assert_eq!(preds[0], task.decide(&logits[0]));
    }
}
