//! Repair driver: background defect-aware retrain → verify → hot swap.
//!
//! The self-healing loop's *actuator* (DESIGN.md §"Self-healing"): when
//! the [`super::monitor`] trips, [`SelfHealer::heal`] runs the full
//! repair against the live card's tracked defect draw, end to end,
//! while the route keeps serving in degraded mode:
//!
//! 1. **flag** — [`Fleet::set_degraded`] so every reply carries
//!    `degraded = true` and callers can abstain on low-confidence rows;
//! 2. **diagnose** — read the exact `(DefectSpec, seed)` draw the card
//!    is serving through ([`crate::sim::DefectInjector::live_draw`]);
//!    the engine's defect stream is deterministic per draw, so the
//!    retrain probe sees precisely the deployed defects;
//! 3. **retrain** — [`crate::compiler::hat_defect_retrain`] on a
//!    background thread (traffic keeps flowing through the defective
//!    card meanwhile): re-fits the affected trees and keeps the best
//!    pass by defective-deployment score;
//! 4. **verify** — the repaired program passes the contract-8 static
//!    verifier gate before anything is published (explicit here because
//!    the swap ships prebuilt sim-card backends, which bypasses
//!    `swap_program`'s internal gate);
//! 5. **export** — optionally into the content-addressed artifact store
//!    (contract 9), so the repair survives a restart;
//! 6. **swap** — [`Fleet::swap_backends_expecting`] pinned to the epoch
//!    diagnosed in step 2: a concurrent operator replacement surfaces as
//!    a structured error instead of being clobbered. The old server
//!    drains under contract 6 — zero dropped replies;
//! 7. **prove** — contract 10: post-swap replies are checked
//!    bit-identical to `CamEngine::with_defects(&repaired, spec, seed)`,
//!    the retrained program on the same defective card, before the
//!    degraded flag clears.
//!
//! The caller (probe loop) then re-arms its [`super::HealthMonitor`]
//! against the repaired deployment via
//! [`super::HealthMonitor::rearm_with`].

use super::backend::Backend;
use super::router::{Admission, Fleet, ModelConfig};
use super::server::BatchPolicy;
use crate::analysis::{self, VerifyPolicy};
use crate::artifact::{export_program, ArtifactStore};
use crate::cam::DefectSpec;
use crate::compiler::{compile, hat_defect_retrain, CamEngine, CompileOptions};
use crate::data::Dataset;
use crate::sim::{CardConfig, ChipConfig, DefectInjector, SimCardBackend};
use crate::trees::hat::{HatParams, RetrainReport};
use crate::trees::Ensemble;
use std::sync::Arc;
use std::time::Instant;

/// Everything a repair needs that is not per-cycle state: the fleet and
/// route, the training data to retrain on, and how to rebuild + publish
/// the repaired card.
pub struct HealContext {
    pub fleet: Arc<Fleet>,
    /// Route name the healer owns.
    pub model: String,
    /// Training rows for the defect-aware refit.
    pub train: Dataset,
    /// Held-out rows scoring each retrain pass (and the contract-10
    /// probe rows).
    pub eval: Dataset,
    pub params: HatParams,
    pub options: CompileOptions,
    /// Card model the repaired backend is calibrated against.
    pub chip: ChipConfig,
    pub card: CardConfig,
    /// Serving config of the published replacement route.
    pub batch_policy: BatchPolicy,
    pub queue_cap: usize,
    /// Contract-8 gate for the repaired program.
    pub verify: VerifyPolicy,
    /// When set, every repaired program is exported here (contract 9)
    /// before it goes live.
    pub store: Option<ArtifactStore>,
}

/// Outcome of one completed repair cycle.
#[derive(Clone, Debug)]
pub struct HealReport {
    /// Defect draw the repair was made against.
    pub defects: DefectSpec,
    pub seed: u64,
    /// The retrain loop's own report: passes run, affected-tree counts,
    /// defective-deployment score before → after.
    pub retrain: RetrainReport,
    /// Artifact id of the exported repaired program, when a store is
    /// configured.
    pub artifact_id: Option<String>,
    /// Deployment epochs: the defective route that was diagnosed and
    /// replaced, and the repaired route now live.
    pub old_epoch: u64,
    pub new_epoch: u64,
    /// Rows proven bit-identical to the retrained program post-swap
    /// (contract 10).
    pub bit_identity_rows: usize,
    /// Wall-clock of the whole cycle (degraded-serving window).
    pub wall_s: f64,
}

/// The repair driver. One instance owns one route's repair policy;
/// [`SelfHealer::heal`] runs a full cycle and can be called again for
/// every subsequent drift verdict (the example runs ≥ 2 autonomous
/// cycles back to back).
pub struct SelfHealer {
    ctx: HealContext,
    history: Vec<HealReport>,
}

/// Rows checked for post-swap bit-identity (capped by the eval set).
const BIT_IDENTITY_ROWS: usize = 64;

impl SelfHealer {
    pub fn new(ctx: HealContext) -> SelfHealer {
        SelfHealer { ctx, history: Vec::new() }
    }

    /// Completed repair cycles, oldest first.
    pub fn history(&self) -> &[HealReport] {
        &self.history
    }

    /// Run one full repair cycle against the live route. `current` is
    /// the deployed ensemble (the healer returns its repaired successor
    /// for the next cycle) and `injector` the live card's defect hook.
    ///
    /// On success the repaired program is live, serving bit-identically
    /// to `CamEngine::with_defects(&repaired, spec, seed)` (contract
    /// 10), and the degraded flag is cleared. On failure the defective
    /// route keeps serving **with the degraded flag still set** — wrong
    /// answers stay flagged until a later repair lands.
    pub fn heal(
        &mut self,
        current: Ensemble,
        injector: &Arc<DefectInjector>,
    ) -> Result<(Ensemble, Arc<DefectInjector>, HealReport), String> {
        let t0 = Instant::now();
        let fleet = self.ctx.fleet.clone();
        let model = self.ctx.model.clone();

        // Pin the deployment being repaired: the swap below is
        // compare-and-swap'd against this epoch.
        let old_epoch = fleet
            .route_epoch(&model)
            .ok_or_else(|| format!("unknown model `{model}`"))?;
        fleet.set_degraded(&model, true)?;

        let (spec, seed) = injector.live_draw().ok_or_else(|| {
            format!("model `{model}` tripped the monitor but its card reports no defect draw")
        })?;

        // Background retrain; live traffic keeps flowing through the
        // (degraded-flagged) defective card while this thread works.
        let ctx = &self.ctx;
        let (repaired, retrain) = std::thread::scope(|s| {
            s.spawn(|| {
                hat_defect_retrain(
                    &ctx.train,
                    &ctx.eval,
                    current,
                    &ctx.params,
                    &ctx.options,
                    spec,
                    seed,
                )
            })
            .join()
        })
        .map_err(|_| "defect-retrain thread panicked".to_string())?
        .map_err(|e| format!("defect retrain for `{model}` failed: {e}"))?;

        let program = compile(&repaired, &self.ctx.options)
            .map_err(|e| format!("compiling repaired `{model}`: {e}"))?;

        // Contract 8: the repaired program must be verify-clean before
        // it is published. Explicit, because the swap below ships
        // prebuilt sim-card backends (the path that skips the fleet's
        // internal program gate).
        if self.ctx.verify != VerifyPolicy::Skip {
            let report = analysis::verify_program(&program);
            if let Some(f) = self.ctx.verify.blocks(&report) {
                return Err(format!(
                    "static verifier refused repaired `{model}` ({} deny, {} warn): {f}",
                    report.deny_count(),
                    report.warn_count()
                ));
            }
        }

        let artifact_id = match &mut self.ctx.store {
            Some(store) => Some(
                export_program(store, &program, None)
                    .map_err(|e| format!("exporting repaired `{model}`: {e}"))?,
            ),
            None => None,
        };

        // The repaired program deploys onto the *same defective card*:
        // the fresh backend is struck with the diagnosed draw before its
        // first batch, exactly the deployment `hat_defect_retrain`
        // optimized (its probe scores candidates through
        // `with_defects(candidate, spec, seed)`).
        let new_injector = DefectInjector::new();
        new_injector.strike(spec, seed);
        let backend = SimCardBackend::new(&program, &self.ctx.chip, &self.ctx.card)
            .with_injector(new_injector.clone());
        let cfg = ModelConfig::for_program(&program)
            .with_policy(self.ctx.batch_policy)
            .with_queue_cap(self.ctx.queue_cap)
            .with_verify(self.ctx.verify);

        fleet.swap_backends_expecting(
            &model,
            old_epoch,
            vec![Box::new(backend) as Box<dyn Backend>],
            Vec::new(),
            cfg,
        )?;
        let new_epoch = fleet
            .route_epoch(&model)
            .ok_or_else(|| format!("model `{model}` vanished right after its swap"))?;

        // Contract 10: post-swap replies are bit-identical to the
        // retrained program on the diagnosed defect draw. Shed rows are
        // retried (the check competes with live traffic for queue
        // slots); an error reply or a single diverging logit fails the
        // cycle.
        let reference = CamEngine::with_defects(&program, spec, seed);
        let n_check = BIT_IDENTITY_ROWS.min(self.ctx.eval.n_rows());
        for i in 0..n_check {
            let row = self.ctx.eval.row(i);
            let reply = loop {
                match fleet.submit(&model, row)? {
                    Admission::Accepted(rx) => {
                        break rx
                            .recv()
                            .map_err(|_| "worker dropped a contract-10 probe".to_string())?
                    }
                    Admission::Shed { .. } => std::thread::yield_now(),
                }
            };
            if let Some(e) = reply.error {
                return Err(format!("contract-10 probe row {i} failed: {e}"));
            }
            let want = reference.infer_bins(&program.quantizer.bin_row(row));
            if reply.logits != want {
                return Err(format!(
                    "contract 10 violated: post-swap reply for row {i} diverges from the \
                     retrained program ({:?} != {want:?})",
                    reply.logits
                ));
            }
        }

        fleet.set_degraded(&model, false)?;

        let report = HealReport {
            defects: spec,
            seed,
            retrain,
            artifact_id,
            old_epoch,
            new_epoch,
            bit_identity_rows: n_check,
            wall_s: t0.elapsed().as_secs_f64(),
        };
        self.history.push(report.clone());
        Ok((repaired, new_injector, report))
    }
}
