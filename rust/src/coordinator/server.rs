//! Serving engine: dynamic batcher + a pool of per-shard worker threads.
//!
//! The deployment the paper envisions (§III-D: X-TIME PCIe cards that a
//! host CPU offloads decision-tree inference to) is a *serving* problem:
//! requests arrive one by one, the cards want full batches, and one card
//! caps throughput. This module implements the host-side coordination:
//!
//! * a dynamic batcher (batch up to `max_batch` or `max_wait`);
//! * **single-card mode** ([`Server::start`]) — one worker thread owns one
//!   [`Backend`] and serves whole batches, exactly the paper's single-card
//!   deployment;
//! * **sharded mode** ([`Server::start_sharded`]) — each batch fans out to
//!   N shard workers (one `Backend` each, e.g. one per PCIe card holding a
//!   [`crate::compiler::ShardPlan`] shard). Workers return base-free f64
//!   partial class sums; the dispatcher sums them in shard order and
//!   applies the base score once — the functional engine's exact
//!   arithmetic (`sum as f32 + base`), so a sharded pool is bit-identical
//!   to the unsharded *functional* engine (`rust/tests/sharding.rs`).
//!   The CPU backend's own `infer` walks trees in f32 and may differ from
//!   both by ≤ 1 ulp; XLA shards are near-exact (see `backend.rs`).
//!
//! Fault containment: a backend/shard error fails only the batch it was
//! serving — every affected request receives a [`Reply`] with `error`
//! set (empty logits, NaN prediction), the failure is recorded on the
//! shard's [`ShardStats`] (`errors`, `last_error`), and the server keeps
//! serving subsequent batches.
//!
//! Mirrors vLLM-style router/worker separation, scaled out to a card pool.

use super::backend::Backend;
use crate::compiler::apply_base;
use crate::util::stats::{Reservoir, Summary};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Latency samples retained for [`Server::latency_summary`]: a
/// fixed-capacity reservoir, so server memory stays bounded under
/// sustained load (the log once grew one `f64` per request, forever).
pub const LATENCY_RESERVOIR_CAP: usize = 1024;
/// Deterministic reservoir seed — summaries are reproducible for a
/// fixed request order.
const LATENCY_RESERVOIR_SEED: u64 = 0x1A7E0C7;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush a partial batch after this long (µs).
    pub max_wait_us: u64,
    /// Cap batches at this size (0 = backend's max_batch).
    pub max_batch: usize,
    /// Planned-path worker threads pushed to every backend in the pool
    /// via [`Backend::set_threads`] at startup: `None` keeps each
    /// backend as constructed, `Some(0)` means one worker per available
    /// CPU, `Some(n)` pins `n` workers. Results are bit-identical for
    /// every setting (the planned path's determinism contract).
    pub threads: Option<usize>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_wait_us: 200, max_batch: 0, threads: None }
    }
}

/// Quantized request payload. Deliberately **not** `Clone`: batch
/// assembly must move bins out of the request (`Request::into_parts`),
/// so a per-request clone can never sneak back onto the hot path — the
/// compiler rejects it.
struct Bins(Vec<u16>);

/// RAII slot in a bounded admission queue (the fleet's per-model
/// backpressure gauge). Claimed by [`QueueTicket::try_claim`] before a
/// request enters the server, released — the gauge decrements — exactly
/// when the ticket drops, which the worker loops arrange to happen
/// right after the request's [`Reply`] is sent. Because the ticket
/// rides inside `Request`/`Pending` and the drain contract guarantees
/// every queued request is replied to, the gauge can never leak a slot:
/// admitted − replied is always the true in-server depth.
pub(crate) struct QueueTicket(Arc<AtomicUsize>);

impl QueueTicket {
    /// Claim a slot against `depth`, refusing once `cap` slots are
    /// held (`cap == 0` means unbounded — always admit). Lock-free CAS
    /// loop so concurrent submitters can never overshoot the cap.
    pub(crate) fn try_claim(depth: &Arc<AtomicUsize>, cap: usize) -> Option<QueueTicket> {
        if cap == 0 {
            depth.fetch_add(1, Ordering::AcqRel);
            return Some(QueueTicket(depth.clone()));
        }
        let mut cur = depth.load(Ordering::Acquire);
        loop {
            if cur >= cap {
                return None;
            }
            match depth.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some(QueueTicket(depth.clone())),
                Err(observed) => cur = observed,
            }
        }
    }
}

impl Drop for QueueTicket {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

struct Request {
    bins: Bins,
    enqueued: Instant,
    reply: Sender<Reply>,
    /// Admission-queue slot, released when the reply has been sent
    /// (`None` for un-gated submitters like `Server::submit`).
    ticket: Option<QueueTicket>,
}

/// A request's reply-side remainder once its bins moved into the device
/// batch. Dropping it (after the reply send) releases the admission
/// ticket.
struct Pending {
    enqueued: Instant,
    reply: Sender<Reply>,
    #[allow(dead_code)] // held for its Drop (queue-depth release)
    ticket: Option<QueueTicket>,
}

impl Request {
    /// Split into the device-batch row (moved, not cloned) and the
    /// reply-side remainder.
    fn into_parts(self) -> (Vec<u16>, Pending) {
        (
            self.bins.0,
            Pending { enqueued: self.enqueued, reply: self.reply, ticket: self.ticket },
        )
    }
}

/// Response to one request.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub prediction: f32,
    /// Soft-boundary confidence in the prediction, `[0, 1]`: the MoS₂
    /// graded match-line response ([`crate::cam::analog::soft_confidence`])
    /// of the task's decision margin. 1.0 for regression (point
    /// predictions have no boundary), 0.5 on the class boundary, 0.0 for
    /// error replies. During a degraded-serving window callers can
    /// flag/abstain on low-confidence rows instead of trusting them.
    pub confidence: f32,
    /// True when the route was serving in degraded mode (a defect was
    /// detected and a repair is in flight) when this reply was produced.
    pub degraded: bool,
    /// Time spent queued + batched + inferred, as measured by the server.
    pub latency: Duration,
    /// Size of the device batch this request rode in.
    pub batch_size: usize,
    /// `Some` when the backing batch failed (a backend/shard error):
    /// `logits` is empty and `prediction` is NaN. The server stays up —
    /// subsequent requests are served normally.
    pub error: Option<String>,
}

impl Reply {
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Per-row confidence attached to every successful reply: the
/// soft-boundary response of the decision margin.
fn confidence_of(task: crate::data::Task, logits: &[f32]) -> f32 {
    crate::cam::analog::soft_confidence(task.decision_margin(logits))
}

/// Aggregated server-side counters.
#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    batches: AtomicU64,
    batch_rows: AtomicU64,
    errors: AtomicU64,
}

/// Mutex access continuing through poisoning: every mutex in this
/// module guards a plain value (an error string, the latency
/// reservoir) that is valid at any point a panicking holder could have
/// stopped, so poison carries no integrity signal — and stats readers
/// must keep working after a worker panic (fault containment).
fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Per-shard-worker counters (one per backend in the pool).
struct ShardCounter {
    name: String,
    batches: AtomicU64,
    rows: AtomicU64,
    errors: AtomicU64,
    busy_us: AtomicU64,
    last_error: Mutex<Option<String>>,
}

impl ShardCounter {
    fn new(name: String) -> ShardCounter {
        ShardCounter {
            name,
            batches: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            busy_us: AtomicU64::new(0),
            last_error: Mutex::new(None),
        }
    }

    fn record(&self, t0: Instant, rows: usize, ok: bool) {
        self.busy_us.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        if ok {
            self.batches.fetch_add(1, Ordering::Relaxed);
            self.rows.fetch_add(rows as u64, Ordering::Relaxed);
        } else {
            self.errors.fetch_add(rows as u64, Ordering::Relaxed);
        }
    }

    fn set_last_error(&self, msg: String) {
        *lock_clean(&self.last_error) = Some(msg);
    }

    /// A failure observed by the dispatcher rather than the worker
    /// itself (e.g. the worker thread is gone).
    fn fail(&self, rows: usize, msg: &str) {
        self.errors.fetch_add(rows as u64, Ordering::Relaxed);
        self.set_last_error(msg.to_string());
    }

    fn snapshot(&self) -> ShardStats {
        ShardStats {
            name: self.name.clone(),
            batches: self.batches.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            busy_us: self.busy_us.load(Ordering::Relaxed),
            last_error: lock_clean(&self.last_error).clone(),
        }
    }
}

/// Point-in-time statistics of one shard worker.
#[derive(Clone, Debug)]
pub struct ShardStats {
    /// `<backend name>#<shard index>`.
    pub name: String,
    pub batches: u64,
    /// Rows inferred (each shard sees every batch row).
    pub rows: u64,
    pub errors: u64,
    /// Wall time spent inside the backend (µs) — utilization numerator.
    pub busy_us: u64,
    /// Most recent backend error on this shard, if any.
    pub last_error: Option<String>,
}

/// Point-in-time server statistics.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub errors: u64,
    /// One entry per worker in the pool (a single entry in unsharded mode).
    pub shards: Vec<ShardStats>,
}

/// A batch job broadcast to every shard worker.
struct ShardJob {
    batch: Arc<Vec<Vec<u16>>>,
    reply: Sender<(usize, anyhow::Result<Vec<Vec<f64>>>)>,
}

/// Handle to a running inference server.
pub struct Server {
    tx: Option<Sender<Request>>,
    worker: Option<std::thread::JoinHandle<()>>,
    shard_workers: Vec<std::thread::JoinHandle<()>>,
    counters: Arc<Counters>,
    shard_counters: Arc<Vec<ShardCounter>>,
    latencies: Arc<Mutex<Reservoir>>,
    n_features: usize,
    /// Degraded-serving flag (a repair is in flight); stamped onto every
    /// reply so callers can see which answers rode a defective card.
    degraded: Arc<AtomicBool>,
}

/// Collect a batch: `first` plus whatever arrives before `max_batch` fills
/// or `wait` expires.
fn collect_batch(
    rx: &Receiver<Request>,
    first: Request,
    max_batch: usize,
    wait: Duration,
) -> Vec<Request> {
    let mut reqs = vec![first];
    let deadline = Instant::now() + wait;
    while reqs.len() < max_batch {
        match rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
            Ok(r) => reqs.push(r),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    reqs
}

impl Server {
    /// Spawn a single worker thread owning `backend` (the paper's
    /// one-card deployment).
    pub fn start(backend: Box<dyn Backend>, policy: BatchPolicy, n_features: usize) -> Server {
        Server::start_sharded(vec![backend], Vec::new(), policy, n_features)
    }

    /// Spawn a pool of per-shard workers (one `Backend` each) fed by a
    /// dispatcher that fans every batch out and aggregates partial sums.
    ///
    /// `base_score` is the *source ensemble's* additive prior, applied
    /// once after cross-shard summation (pass
    /// [`crate::compiler::ShardPlan::base_score`]; ignored for a pool of
    /// one, where the backend's own `infer` handles it). All backends
    /// must serve the same task.
    ///
    /// Panics if `backends` is empty or tasks disagree.
    pub fn start_sharded(
        mut backends: Vec<Box<dyn Backend>>,
        base_score: Vec<f32>,
        policy: BatchPolicy,
        n_features: usize,
    ) -> Server {
        assert!(!backends.is_empty(), "need at least one backend");
        if let Some(threads) = policy.threads {
            for b in &mut backends {
                b.set_threads(threads);
            }
        }
        let task = backends[0].task();
        assert!(
            backends.iter().all(|b| b.task() == task),
            "all shard backends must serve the same task"
        );
        // Invariant: asserted non-empty above, so a minimum exists.
        #[allow(clippy::unwrap_used)]
        let cap = backends.iter().map(|b| b.max_batch()).min().unwrap();
        let max_batch = if policy.max_batch == 0 {
            cap
        } else {
            policy.max_batch.min(cap)
        };
        let wait = Duration::from_micros(policy.max_wait_us);

        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let counters = Arc::new(Counters::default());
        let shard_counters: Arc<Vec<ShardCounter>> = Arc::new(
            backends
                .iter()
                .enumerate()
                .map(|(i, b)| ShardCounter::new(format!("{}#{i}", b.name())))
                .collect(),
        );
        let latencies = Arc::new(Mutex::new(Reservoir::new(
            LATENCY_RESERVOIR_CAP,
            LATENCY_RESERVOIR_SEED,
        )));

        let degraded = Arc::new(AtomicBool::new(false));

        let c2 = counters.clone();
        let s2 = shard_counters.clone();
        let l2 = latencies.clone();
        let d2 = degraded.clone();

        if backends.len() == 1 {
            // Single-card fast path: the worker owns the backend and
            // serves logits directly (backend applies any base score).
            // Invariant: this branch is `backends.len() == 1`.
            #[allow(clippy::unwrap_used)]
            let mut backend = backends.pop().unwrap();
            let worker = std::thread::spawn(move || {
                while let Ok(first) = rx.recv() {
                    let reqs = collect_batch(&rx, first, max_batch, wait);
                    // Bins *move* into the device batch — no per-request
                    // clone on the hot path (`Bins` is not `Clone`).
                    let (batch, pending): (Vec<Vec<u16>>, Vec<Pending>) =
                        reqs.into_iter().map(Request::into_parts).unzip();
                    let t0 = Instant::now();
                    let result = backend.infer(&batch).and_then(|l| {
                        if l.len() == batch.len() {
                            Ok(l)
                        } else {
                            Err(anyhow::anyhow!(
                                "backend `{}` returned {} rows for a batch of {}",
                                backend.name(),
                                l.len(),
                                batch.len()
                            ))
                        }
                    });
                    s2[0].record(t0, batch.len(), result.is_ok());
                    match result {
                        Ok(logits) => {
                            c2.batches.fetch_add(1, Ordering::Relaxed);
                            c2.batch_rows.fetch_add(pending.len() as u64, Ordering::Relaxed);
                            let mut lat_log = lock_clean(&l2);
                            let deg = d2.load(Ordering::Relaxed);
                            for (req, l) in pending.into_iter().zip(logits) {
                                let latency = req.enqueued.elapsed();
                                lat_log.push(latency.as_secs_f64());
                                let _ = req.reply.send(Reply {
                                    prediction: task.decide(&l),
                                    confidence: confidence_of(task, &l),
                                    degraded: deg,
                                    logits: l,
                                    latency,
                                    batch_size: batch.len(),
                                    error: None,
                                });
                            }
                        }
                        Err(e) => {
                            // Error replies, not a dead server: callers
                            // see what failed and the worker keeps going.
                            let msg = format!("{e:#}");
                            c2.errors.fetch_add(pending.len() as u64, Ordering::Relaxed);
                            s2[0].set_last_error(msg.clone());
                            eprintln!("backend error (batch dropped): {msg}");
                            let deg = d2.load(Ordering::Relaxed);
                            for req in pending {
                                let _ = req.reply.send(Reply {
                                    logits: Vec::new(),
                                    prediction: f32::NAN,
                                    confidence: 0.0,
                                    degraded: deg,
                                    latency: req.enqueued.elapsed(),
                                    batch_size: batch.len(),
                                    error: Some(msg.clone()),
                                });
                            }
                        }
                    }
                }
            });
            return Server {
                tx: Some(tx),
                worker: Some(worker),
                shard_workers: Vec::new(),
                counters,
                shard_counters,
                latencies,
                n_features,
                degraded,
            };
        }

        // Sharded mode: one worker per backend plus a dispatcher.
        let n_shards = backends.len();
        let mut job_txs: Vec<Sender<ShardJob>> = Vec::with_capacity(n_shards);
        let mut shard_workers = Vec::with_capacity(n_shards);
        for (idx, mut backend) in backends.into_iter().enumerate() {
            let (jtx, jrx): (Sender<ShardJob>, Receiver<ShardJob>) = channel();
            job_txs.push(jtx);
            let sc = shard_counters.clone();
            shard_workers.push(std::thread::spawn(move || {
                while let Ok(job) = jrx.recv() {
                    let t0 = Instant::now();
                    // A short result would desynchronize row aggregation;
                    // surface it as a shard error instead.
                    let result = backend.infer_partials(&job.batch).and_then(|p| {
                        if p.len() == job.batch.len() {
                            Ok(p)
                        } else {
                            Err(anyhow::anyhow!(
                                "backend `{}` returned {} rows for a batch of {}",
                                backend.name(),
                                p.len(),
                                job.batch.len()
                            ))
                        }
                    });
                    sc[idx].record(t0, job.batch.len(), result.is_ok());
                    if let Err(e) = &result {
                        sc[idx].set_last_error(format!("{e:#}"));
                    }
                    let _ = job.reply.send((idx, result));
                }
            }));
        }

        let dispatcher = std::thread::spawn(move || {
            while let Ok(first) = rx.recv() {
                let reqs = collect_batch(&rx, first, max_batch, wait);
                let n_rows = reqs.len();
                // Same move-not-clone batch assembly as the single-card
                // worker; the broadcast to shard workers shares one Arc.
                let (batch, reqs): (Vec<Vec<u16>>, Vec<Pending>) =
                    reqs.into_iter().map(Request::into_parts).unzip();
                let batch: Arc<Vec<Vec<u16>>> = Arc::new(batch);

                // Fan out, then collect exactly one reply per live shard.
                let (ptx, prx) = channel();
                let mut failures: Vec<String> = Vec::new();
                // Shards whose failure is already accounted for (send
                // error or an Err reply); the sweep below catches workers
                // that died silently mid-batch.
                let mut noted = vec![false; n_shards];
                for (i, jtx) in job_txs.iter().enumerate() {
                    let job = ShardJob { batch: batch.clone(), reply: ptx.clone() };
                    if jtx.send(job).is_err() {
                        s2[i].fail(n_rows, "shard worker disconnected");
                        failures.push(format!("shard {i}: worker disconnected"));
                        noted[i] = true;
                    }
                }
                drop(ptx);
                let mut partials: Vec<Option<Vec<Vec<f64>>>> = vec![None; n_shards];
                while let Ok((s, result)) = prx.recv() {
                    match result {
                        Ok(p) => partials[s] = Some(p),
                        Err(e) => {
                            failures.push(format!("shard {s}: {e:#}"));
                            noted[s] = true;
                        }
                    }
                }
                for s in 0..n_shards {
                    if partials[s].is_none() && !noted[s] {
                        s2[s].fail(n_rows, "shard worker exited without replying");
                        failures.push(format!("shard {s}: worker exited without replying"));
                    }
                }

                let collected: Option<Vec<Vec<Vec<f64>>>> = partials.into_iter().collect();
                let shard_partials = match collected {
                    Some(p) if failures.is_empty() => p,
                    _ => {
                        // One failed shard must not take the server (or
                        // even this batch's callers) down: every affected
                        // request gets an error reply and the dispatcher
                        // moves on to the next batch.
                        let msg = failures.join("; ");
                        c2.errors.fetch_add(n_rows as u64, Ordering::Relaxed);
                        eprintln!("sharded batch failed ({msg}); returning error replies");
                        let deg = d2.load(Ordering::Relaxed);
                        for req in reqs {
                            let _ = req.reply.send(Reply {
                                logits: Vec::new(),
                                prediction: f32::NAN,
                                confidence: 0.0,
                                degraded: deg,
                                latency: req.enqueued.elapsed(),
                                batch_size: n_rows,
                                error: Some(msg.clone()),
                            });
                        }
                        continue;
                    }
                };

                // Aggregate: Σ shards (f64, shard order), then base —
                // `sum as f32 + base`, the same arithmetic as the
                // unsharded functional engine.
                c2.batches.fetch_add(1, Ordering::Relaxed);
                c2.batch_rows.fetch_add(n_rows as u64, Ordering::Relaxed);
                let mut lat_log = lock_clean(&l2);
                let deg = d2.load(Ordering::Relaxed);
                for (i, req) in reqs.into_iter().enumerate() {
                    let mut total: Vec<f64> = Vec::new();
                    for p in shard_partials.iter() {
                        let row = &p[i];
                        if row.len() > total.len() {
                            total.resize(row.len(), 0.0);
                        }
                        for (k, v) in row.iter().enumerate() {
                            total[k] += v;
                        }
                    }
                    // The engine's exact rounding — shared helper so the
                    // sharded path cannot drift from the unsharded one.
                    let logits = apply_base(&total, &base_score);
                    let latency = req.enqueued.elapsed();
                    lat_log.push(latency.as_secs_f64());
                    let _ = req.reply.send(Reply {
                        prediction: task.decide(&logits),
                        confidence: confidence_of(task, &logits),
                        degraded: deg,
                        logits,
                        latency,
                        batch_size: n_rows,
                        error: None,
                    });
                }
            }
            // rx closed: dropping job_txs here stops the shard workers.
        });

        Server {
            tx: Some(tx),
            worker: Some(dispatcher),
            shard_workers,
            counters,
            shard_counters,
            latencies,
            n_features,
            degraded,
        }
    }

    /// Flip degraded-serving mode: subsequent replies carry
    /// `degraded = true` until cleared. Set by the self-healing driver
    /// while a repair is in flight ([`crate::coordinator::healer`]).
    pub fn set_degraded(&self, on: bool) {
        self.degraded.store(on, Ordering::Relaxed);
    }

    /// Whether the server is currently flagged degraded.
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Number of worker backends in the pool.
    pub fn n_shards(&self) -> usize {
        self.shard_counters.len()
    }

    /// Submit a quantized request; returns the reply channel.
    pub fn submit(&self, bins: Vec<u16>) -> Receiver<Reply> {
        self.submit_ticketed(bins, None)
    }

    /// [`Server::submit`] carrying an admission [`QueueTicket`]: the
    /// fleet's bounded per-model queues ride this — the ticket's slot is
    /// released when the worker has sent this request's reply, so the
    /// queue-depth gauge tracks exactly the requests the server still
    /// owes a reply.
    pub(crate) fn submit_ticketed(
        &self,
        bins: Vec<u16>,
        ticket: Option<QueueTicket>,
    ) -> Receiver<Reply> {
        assert_eq!(bins.len(), self.n_features, "feature arity mismatch");
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = channel();
        // Invariant: `tx` is `Some` until `shutdown`/`Drop` consume the
        // server, so no `&self` caller can observe `None`; and the
        // worker holds `rx` until `tx` is dropped, so `send` cannot
        // fail while `tx` is alive.
        #[allow(clippy::expect_used)]
        self.tx
            .as_ref()
            .expect("server stopped")
            .send(Request { bins: Bins(bins), enqueued: Instant::now(), reply: rtx, ticket })
            .expect("worker gone");
        rrx
    }

    /// Blocking convenience: submit and wait.
    pub fn infer_blocking(&self, bins: Vec<u16>) -> Reply {
        // Invariant: the drain contract — every submitted request's
        // reply sender is used before the worker exits — so `recv` can
        // only fail if the worker *panicked*, which already tore down
        // the process's serving guarantees.
        #[allow(clippy::expect_used)]
        self.submit(bins).recv().expect("worker dropped request")
    }

    pub fn stats(&self) -> ServerStats {
        let batches = self.counters.batches.load(Ordering::Relaxed);
        let rows = self.counters.batch_rows.load(Ordering::Relaxed);
        ServerStats {
            requests: self.counters.requests.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 { 0.0 } else { rows as f64 / batches as f64 },
            errors: self.counters.errors.load(Ordering::Relaxed),
            shards: self.shard_counters.iter().map(|c| c.snapshot()).collect(),
        }
    }

    /// Latency summary (seconds) over served traffic; `None` before any
    /// traffic (or if every batch failed). Backed by a fixed-capacity
    /// deterministic reservoir ([`LATENCY_RESERVOIR_CAP`] samples), so
    /// the summary is over a uniform sample of everything served and
    /// server memory stays bounded under sustained load.
    pub fn latency_summary(&self) -> Option<Summary> {
        lock_clean(&self.latencies).summary()
    }

    /// Latency samples offered to the reservoir so far (= rows served
    /// successfully).
    pub fn latency_samples_seen(&self) -> u64 {
        lock_clean(&self.latencies).seen()
    }

    /// Stop the workers.
    ///
    /// **Drain contract:** every request already `submit`ted — including
    /// ones still queued in the channel, not yet picked up by a batcher —
    /// receives a [`Reply`] (successful or error) before the workers
    /// exit; no reply sender is ever dropped unanswered, so a caller
    /// blocked in [`Server::infer_blocking`] can never panic on a closed
    /// reply channel because of a shutdown. This works because dropping
    /// the submit side only *closes* the request channel: the worker's
    /// `recv` keeps returning queued requests until the channel is empty,
    /// and only then observes the disconnect and exits (same for the
    /// dispatcher → shard-worker job channels). Regression-tested by
    /// `stop_under_load_drains_queued_requests` (single and sharded).
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        for w in self.shard_workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::compiler::{compile, partition, CamEngine, CompileOptions, PartitionOptions};
    use crate::coordinator::backend::{CpuExactBackend, FunctionalBackend};
    use crate::data::{by_name, Task};
    use crate::trees::{gbdt, GbdtParams};

    /// Fault injection: fails every batch.
    struct FailingBackend {
        task: Task,
    }

    impl Backend for FailingBackend {
        fn name(&self) -> &'static str {
            "always-fails"
        }

        fn max_batch(&self) -> usize {
            64
        }

        fn task(&self) -> Task {
            self.task
        }

        fn infer(&mut self, _batch: &[Vec<u16>]) -> anyhow::Result<Vec<Vec<f32>>> {
            Err(anyhow::anyhow!("injected fault"))
        }

        fn infer_partials(&mut self, _batch: &[Vec<u16>]) -> anyhow::Result<Vec<Vec<f64>>> {
            Err(anyhow::anyhow!("injected fault"))
        }
    }

    /// Fault injection: fails the first `remaining_failures` partial
    /// batches, then serves through a healthy functional engine.
    struct FlakyBackend {
        inner: FunctionalBackend,
        remaining_failures: usize,
    }

    impl Backend for FlakyBackend {
        fn name(&self) -> &'static str {
            "flaky"
        }

        fn max_batch(&self) -> usize {
            self.inner.max_batch()
        }

        fn task(&self) -> Task {
            self.inner.task()
        }

        fn infer(&mut self, batch: &[Vec<u16>]) -> anyhow::Result<Vec<Vec<f32>>> {
            self.inner.infer(batch)
        }

        fn infer_partials(&mut self, batch: &[Vec<u16>]) -> anyhow::Result<Vec<Vec<f64>>> {
            if self.remaining_failures > 0 {
                self.remaining_failures -= 1;
                return Err(anyhow::anyhow!("transient fault"));
            }
            self.inner.infer_partials(batch)
        }
    }

    /// Wraps a healthy backend with a per-batch delay so a shutdown can
    /// race a backlog of queued requests.
    struct SlowBackend {
        inner: FunctionalBackend,
        delay: Duration,
    }

    impl Backend for SlowBackend {
        fn name(&self) -> &'static str {
            "slow"
        }

        fn max_batch(&self) -> usize {
            self.inner.max_batch()
        }

        fn task(&self) -> Task {
            self.inner.task()
        }

        fn infer(&mut self, batch: &[Vec<u16>]) -> anyhow::Result<Vec<Vec<f32>>> {
            std::thread::sleep(self.delay);
            self.inner.infer(batch)
        }

        fn infer_partials(&mut self, batch: &[Vec<u16>]) -> anyhow::Result<Vec<Vec<f64>>> {
            std::thread::sleep(self.delay);
            self.inner.infer_partials(batch)
        }
    }

    fn setup() -> (crate::data::Dataset, crate::trees::Ensemble, crate::compiler::CamProgram) {
        let d = by_name("churn").unwrap().generate_n(800);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 8, max_leaves: 8, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        (d, m, p)
    }

    #[test]
    fn serves_correct_predictions() {
        let (d, m, p) = setup();
        let server = Server::start(
            Box::new(FunctionalBackend::new(&p)),
            BatchPolicy::default(),
            p.n_features,
        );
        for i in 0..40 {
            let bins = p.quantizer.bin_row(d.row(i));
            let reply = server.infer_blocking(bins);
            assert_eq!(reply.prediction, m.predict(d.row(i)), "row {i}");
            assert!(reply.batch_size >= 1);
        }
        let stats = server.stats();
        assert_eq!(stats.requests, 40);
        assert_eq!(stats.errors, 0);
        server.shutdown();
    }

    #[test]
    fn batches_form_under_concurrent_load() {
        let (d, m, p) = setup();
        let server = Arc::new(Server::start(
            Box::new(CpuExactBackend { model: m }),
            BatchPolicy { max_wait_us: 2_000, max_batch: 16, threads: None },
            p.n_features,
        ));
        let n = 200;
        let mut rxs = Vec::new();
        for i in 0..n {
            rxs.push(server.submit(p.quantizer.bin_row(d.row(i % d.n_rows()))));
        }
        let mut max_batch_seen = 0;
        for rx in rxs {
            let r = rx.recv().unwrap();
            max_batch_seen = max_batch_seen.max(r.batch_size);
        }
        // Back-to-back submissions must have been coalesced.
        assert!(max_batch_seen > 1, "no batching happened");
        let stats = server.stats();
        assert!(stats.batches < n as u64);
        assert!(stats.mean_batch > 1.0);
    }

    #[test]
    fn latency_summary_populates() {
        let (d, _, p) = setup();
        let server = Server::start(
            Box::new(FunctionalBackend::new(&p)),
            BatchPolicy::default(),
            p.n_features,
        );
        for i in 0..10 {
            server.infer_blocking(p.quantizer.bin_row(d.row(i)));
        }
        let s = server.latency_summary().unwrap();
        assert_eq!(s.n, 10);
        assert!(s.min > 0.0);
    }

    /// Satellite (ISSUE 4): the latency log is a fixed-capacity
    /// reservoir — sustained load cannot grow server memory, while the
    /// summary still reflects a uniform sample of everything served.
    #[test]
    fn latency_log_is_bounded_under_sustained_load() {
        let (d, m, p) = setup();
        let server = Server::start(
            Box::new(CpuExactBackend { model: m }),
            BatchPolicy { max_wait_us: 500, max_batch: 64, threads: None },
            p.n_features,
        );
        let n = super::LATENCY_RESERVOIR_CAP + 500;
        let rxs: Vec<_> = (0..n)
            .map(|i| server.submit(p.quantizer.bin_row(d.row(i % d.n_rows()))))
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        // Every served row was offered to the reservoir…
        assert_eq!(server.latency_samples_seen(), n as u64);
        // …but only the capacity is retained and summarized.
        let s = server.latency_summary().unwrap();
        assert_eq!(s.n, super::LATENCY_RESERVOIR_CAP);
        assert!(s.min > 0.0 && s.min <= s.p95);
        server.shutdown();
    }

    /// The `BatchPolicy::threads` knob reaches every backend in the pool
    /// and leaves results bit-identical (the planned path's determinism
    /// contract) — here against the scalar reference engine.
    #[test]
    fn policy_threads_keep_serving_bit_identical() {
        let (d, _, p) = setup();
        let reference = CamEngine::new(&p);
        for threads in [Some(1), Some(4), Some(0)] {
            let server = Server::start(
                Box::new(FunctionalBackend::new(&p)),
                BatchPolicy { max_wait_us: 200, max_batch: 16, threads },
                p.n_features,
            );
            for i in 0..12 {
                let bins = p.quantizer.bin_row(d.row(i));
                let reply = server.infer_blocking(bins.clone());
                assert_eq!(
                    reply.logits,
                    reference.infer_bins(&bins),
                    "threads={threads:?} row {i}"
                );
            }
            server.shutdown();
        }
    }

    #[test]
    #[should_panic(expected = "feature arity mismatch")]
    fn rejects_wrong_arity() {
        let (_, _, p) = setup();
        let server = Server::start(
            Box::new(FunctionalBackend::new(&p)),
            BatchPolicy::default(),
            p.n_features,
        );
        server.submit(vec![0u16; 3]);
    }

    /// Satellite: a partial batch must flush after `max_wait_us` even
    /// though `max_batch` never fills.
    #[test]
    fn partial_batch_flushes_on_max_wait() {
        let (d, _, p) = setup();
        let server = Server::start(
            Box::new(FunctionalBackend::new(&p)),
            BatchPolicy { max_wait_us: 30_000, max_batch: 64, threads: None },
            p.n_features,
        );
        let t0 = Instant::now();
        let rxs: Vec<_> =
            (0..3).map(|i| server.submit(p.quantizer.bin_row(d.row(i)))).collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(10)).expect("flush never happened");
            // Far fewer rows than max_batch rode together.
            assert!(r.batch_size <= 3);
        }
        // Replies arrived without anything close to 64 requests: the wait
        // timer — not batch fill — triggered the flush.
        assert!(t0.elapsed() < Duration::from_secs(10));
        let stats = server.stats();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.errors, 0);
        server.shutdown();
    }

    /// Satellite: `max_batch` caps device batches even under backlog.
    #[test]
    fn max_batch_caps_batch_size() {
        let (d, m, p) = setup();
        let server = Server::start(
            Box::new(CpuExactBackend { model: m }),
            BatchPolicy { max_wait_us: 20_000, max_batch: 4, threads: None },
            p.n_features,
        );
        let rxs: Vec<_> = (0..32)
            .map(|i| server.submit(p.quantizer.bin_row(d.row(i % d.n_rows()))))
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.batch_size <= 4, "batch {} exceeds cap", r.batch_size);
        }
        let stats = server.stats();
        assert!(stats.batches >= 8, "32 requests / cap 4 needs ≥ 8 batches");
        assert!(stats.mean_batch <= 4.0);
        server.shutdown();
    }

    /// Regression (ISSUE 3 satellite): requests still queued in the
    /// channel when `stop()` runs must receive replies — never a dropped
    /// reply sender that panics the caller. A slow backend guarantees a
    /// deep backlog when shutdown starts.
    #[test]
    fn stop_under_load_drains_queued_requests() {
        let (d, m, p) = setup();
        let reference = m;
        let server = Server::start(
            Box::new(SlowBackend {
                inner: FunctionalBackend::new(&p),
                delay: Duration::from_millis(15),
            }),
            BatchPolicy { max_wait_us: 0, max_batch: 4, threads: None },
            p.n_features,
        );
        let n = 32;
        let rows: Vec<usize> = (0..n).map(|i| i % d.n_rows()).collect();
        let rxs: Vec<_> =
            rows.iter().map(|&i| server.submit(p.quantizer.bin_row(d.row(i)))).collect();
        // Shut down while most of the backlog is still queued (the first
        // batch alone takes 15 ms). `shutdown` must block until the
        // worker drained everything.
        server.shutdown();
        for (req, &i) in rxs.into_iter().zip(&rows) {
            let reply = req
                .recv()
                .unwrap_or_else(|_| panic!("request for row {i} was dropped at shutdown"));
            assert!(reply.is_ok(), "row {i}: {:?}", reply.error);
            assert_eq!(reply.prediction, reference.predict(d.row(i)), "row {i}");
        }
    }

    /// Same drain contract for the sharded dispatcher: queued requests
    /// flow through the fan-out/aggregate path before the pool exits.
    #[test]
    fn sharded_stop_under_load_drains_queued_requests() {
        let (d, _, p) = setup();
        let reference = CamEngine::new(&p);
        let plan = partition(&p, 2, &PartitionOptions::default()).unwrap();
        let backends: Vec<Box<dyn Backend>> = plan
            .shards
            .iter()
            .map(|s| {
                Box::new(SlowBackend {
                    inner: FunctionalBackend::new(s),
                    delay: Duration::from_millis(10),
                }) as Box<dyn Backend>
            })
            .collect();
        let server = Server::start_sharded(
            backends,
            plan.base_score.clone(),
            BatchPolicy { max_wait_us: 0, max_batch: 4, threads: None },
            p.n_features,
        );
        let n = 24;
        let bins: Vec<Vec<u16>> =
            (0..n).map(|i| p.quantizer.bin_row(d.row(i % d.n_rows()))).collect();
        let rxs: Vec<_> = bins.iter().map(|b| server.submit(b.clone())).collect();
        server.shutdown();
        for (req, b) in rxs.into_iter().zip(&bins) {
            let reply = req.recv().expect("queued request dropped at sharded shutdown");
            assert!(reply.is_ok(), "{:?}", reply.error);
            assert_eq!(reply.logits, reference.infer_bins(b));
        }
    }

    /// Regression: a failing shard used to hit
    /// `partials[0].as_ref().unwrap()` / drop reply senders, killing the
    /// callers (`infer_blocking` panicked on the closed channel). Now
    /// every affected request gets an error `Reply`, the failure lands in
    /// `ServerStats.shards`, and the server keeps serving.
    #[test]
    fn failed_shard_returns_error_replies_and_server_survives() {
        let (d, _, p) = setup();
        let plan = partition(&p, 2, &PartitionOptions::default()).unwrap();
        let backends: Vec<Box<dyn Backend>> = vec![
            Box::new(FunctionalBackend::new(&plan.shards[0])),
            Box::new(FailingBackend { task: p.task }),
        ];
        let server = Server::start_sharded(
            backends,
            plan.base_score.clone(),
            BatchPolicy::default(),
            p.n_features,
        );
        for i in 0..5 {
            let reply = server.infer_blocking(p.quantizer.bin_row(d.row(i)));
            assert!(!reply.is_ok(), "request {i} should carry the shard error");
            let msg = reply.error.as_deref().unwrap_or("");
            assert!(msg.contains("injected fault"), "unexpected error `{msg}`");
            assert!(reply.logits.is_empty());
            assert!(reply.prediction.is_nan());
        }
        let stats = server.stats();
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.errors, 5);
        assert_eq!(stats.shards[1].errors, 5, "failing shard must be identified");
        assert!(stats.shards[1].last_error.is_some());
        assert_eq!(stats.shards[0].errors, 0, "healthy shard must stay clean");
        // No successful rows → no latency samples, and no panic either.
        assert!(server.latency_summary().is_none());
        server.shutdown();
    }

    /// After a transient shard failure the pool must resume serving
    /// bit-correct results.
    #[test]
    fn pool_recovers_after_transient_shard_failure() {
        let (d, _, p) = setup();
        let reference = CamEngine::new(&p);
        let plan = partition(&p, 2, &PartitionOptions::default()).unwrap();
        let backends: Vec<Box<dyn Backend>> = vec![
            Box::new(FunctionalBackend::new(&plan.shards[0])),
            Box::new(FlakyBackend {
                inner: FunctionalBackend::new(&plan.shards[1]),
                remaining_failures: 1,
            }),
        ];
        let server = Server::start_sharded(
            backends,
            plan.base_score.clone(),
            BatchPolicy::default(),
            p.n_features,
        );
        let first = server.infer_blocking(p.quantizer.bin_row(d.row(0)));
        assert!(!first.is_ok(), "first batch rides the injected fault");
        for i in 0..10 {
            let bins = p.quantizer.bin_row(d.row(i));
            let reply = server.infer_blocking(bins.clone());
            assert!(reply.is_ok(), "row {i}: {:?}", reply.error);
            assert_eq!(reply.logits, reference.infer_bins(&bins), "row {i}");
        }
        let stats = server.stats();
        assert_eq!(stats.errors, 1);
        assert!(stats.shards[1].last_error.is_some());
        server.shutdown();
    }

    /// The single-backend path also degrades to error replies instead of
    /// hanging up on callers.
    #[test]
    fn single_backend_error_becomes_error_reply() {
        let (d, _, p) = setup();
        let server = Server::start(
            Box::new(FailingBackend { task: p.task }),
            BatchPolicy::default(),
            p.n_features,
        );
        let reply = server.infer_blocking(p.quantizer.bin_row(d.row(0)));
        assert!(!reply.is_ok());
        assert!(reply.prediction.is_nan());
        let stats = server.stats();
        assert_eq!(stats.errors, 1);
        assert!(stats.shards[0].last_error.is_some());
        server.shutdown();
    }

    /// The admission ticket is pure CAS bookkeeping: `cap` slots, claims
    /// beyond it refused, every drop releasing exactly one slot, and
    /// `cap == 0` admitting without bound while still counting depth.
    #[test]
    fn queue_ticket_caps_and_releases_slots() {
        let depth = Arc::new(AtomicUsize::new(0));
        let t1 = QueueTicket::try_claim(&depth, 2).expect("slot 1");
        let _t2 = QueueTicket::try_claim(&depth, 2).expect("slot 2");
        assert!(QueueTicket::try_claim(&depth, 2).is_none(), "cap must refuse slot 3");
        assert_eq!(depth.load(Ordering::Acquire), 2);
        drop(t1);
        assert_eq!(depth.load(Ordering::Acquire), 1);
        let _t3 = QueueTicket::try_claim(&depth, 2).expect("freed slot reclaims");

        let unbounded = Arc::new(AtomicUsize::new(0));
        let held: Vec<QueueTicket> =
            (0..100).map(|_| QueueTicket::try_claim(&unbounded, 0).unwrap()).collect();
        assert_eq!(unbounded.load(Ordering::Acquire), 100);
        drop(held);
        assert_eq!(unbounded.load(Ordering::Acquire), 0);
    }

    /// A ticketed request's slot is released only once its reply has
    /// been sent — the gauge measures requests the server still owes.
    #[test]
    fn ticket_released_when_reply_sent() {
        let (d, _, p) = setup();
        let server = Server::start(
            Box::new(SlowBackend {
                inner: FunctionalBackend::new(&p),
                delay: Duration::from_millis(40),
            }),
            BatchPolicy { max_wait_us: 0, max_batch: 8, threads: None },
            p.n_features,
        );
        let depth = Arc::new(AtomicUsize::new(0));
        let ticket = QueueTicket::try_claim(&depth, 1).unwrap();
        let rx = server.submit_ticketed(p.quantizer.bin_row(d.row(0)), Some(ticket));
        // While the slow batch is in flight the slot stays held.
        assert_eq!(depth.load(Ordering::Acquire), 1);
        let reply = rx.recv().unwrap();
        assert!(reply.is_ok());
        // The worker drops `Pending` right after the send; give its loop
        // a moment to finish the iteration.
        let t0 = Instant::now();
        while depth.load(Ordering::Acquire) != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "ticket never released");
            std::thread::yield_now();
        }
        server.shutdown();
    }

    /// Satellite: per-shard counters populate and every shard sees every
    /// batch row.
    #[test]
    fn shard_counters_populate() {
        let (d, _, p) = setup();
        let plan = partition(&p, 2, &PartitionOptions::default()).unwrap();
        let backends: Vec<Box<dyn crate::coordinator::Backend>> = plan
            .shards
            .iter()
            .map(|s| Box::new(FunctionalBackend::new(s)) as Box<dyn crate::coordinator::Backend>)
            .collect();
        let server = Server::start_sharded(
            backends,
            plan.base_score.clone(),
            BatchPolicy::default(),
            p.n_features,
        );
        assert_eq!(server.n_shards(), 2);
        for i in 0..20 {
            server.infer_blocking(p.quantizer.bin_row(d.row(i)));
        }
        let stats = server.stats();
        assert_eq!(stats.requests, 20);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.shards.len(), 2);
        for s in &stats.shards {
            assert!(s.batches > 0, "{} served no batches", s.name);
            assert_eq!(s.rows, 20, "{} must see every row", s.name);
            assert_eq!(s.errors, 0);
        }
        server.shutdown();
    }
}
