//! Serving engine: dynamic batcher + worker thread owning a backend.
//!
//! The deployment the paper envisions (§III-D: an X-TIME PCIe card that a
//! host CPU offloads decision-tree inference to) is a *serving* problem:
//! requests arrive one by one, the card wants full batches. This module
//! implements the host-side coordination: a lock-free-ish request queue,
//! a dynamic batcher (batch up to `max_batch` or `max_wait`), and a worker
//! thread that owns the device engine — mirroring vLLM-style router/worker
//! separation at a single-node scale.

use super::backend::Backend;
use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush a partial batch after this long (µs).
    pub max_wait_us: u64,
    /// Cap batches at this size (0 = backend's max_batch).
    pub max_batch: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_wait_us: 200, max_batch: 0 }
    }
}

struct Request {
    bins: Vec<u16>,
    enqueued: Instant,
    reply: Sender<Reply>,
}

/// Response to one request.
#[derive(Clone, Debug)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub prediction: f32,
    /// Time spent queued + batched + inferred, as measured by the server.
    pub latency: Duration,
    /// Size of the device batch this request rode in.
    pub batch_size: usize,
}

/// Aggregated server-side counters.
#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    batches: AtomicU64,
    batch_rows: AtomicU64,
    errors: AtomicU64,
}

/// Point-in-time server statistics.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub errors: u64,
}

/// Handle to a running inference server.
pub struct Server {
    tx: Option<Sender<Request>>,
    worker: Option<std::thread::JoinHandle<()>>,
    counters: Arc<Counters>,
    latencies: Arc<Mutex<Vec<f64>>>,
    n_features: usize,
}

impl Server {
    /// Spawn the worker thread owning `backend`.
    pub fn start(mut backend: Box<dyn Backend>, policy: BatchPolicy, n_features: usize) -> Server {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let counters = Arc::new(Counters::default());
        let latencies = Arc::new(Mutex::new(Vec::new()));
        let c2 = counters.clone();
        let l2 = latencies.clone();
        let worker = std::thread::spawn(move || {
            let max_batch = if policy.max_batch == 0 {
                backend.max_batch()
            } else {
                policy.max_batch.min(backend.max_batch())
            };
            let wait = Duration::from_micros(policy.max_wait_us);
            let task = backend.task();
            while let Ok(first) = rx.recv() {
                // Dynamic batching: collect until full or the wait expires.
                let mut reqs = vec![first];
                let deadline = Instant::now() + wait;
                while reqs.len() < max_batch {
                    match rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                        Ok(r) => reqs.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                let batch: Vec<Vec<u16>> = reqs.iter().map(|r| r.bins.clone()).collect();
                match backend.infer(&batch) {
                    Ok(logits) => {
                        c2.batches.fetch_add(1, Ordering::Relaxed);
                        c2.batch_rows.fetch_add(reqs.len() as u64, Ordering::Relaxed);
                        let mut lat_log = l2.lock().unwrap();
                        for (req, l) in reqs.into_iter().zip(logits) {
                            let latency = req.enqueued.elapsed();
                            lat_log.push(latency.as_secs_f64());
                            let _ = req.reply.send(Reply {
                                prediction: task.decide(&l),
                                logits: l,
                                latency,
                                batch_size: batch.len(),
                            });
                        }
                    }
                    Err(e) => {
                        c2.errors.fetch_add(reqs.len() as u64, Ordering::Relaxed);
                        eprintln!("backend error: {e:#}");
                        // Drop reply senders → callers see disconnect.
                    }
                }
            }
        });
        Server { tx: Some(tx), worker: Some(worker), counters, latencies, n_features }
    }

    /// Submit a quantized request; returns the reply channel.
    pub fn submit(&self, bins: Vec<u16>) -> Receiver<Reply> {
        assert_eq!(bins.len(), self.n_features, "feature arity mismatch");
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = channel();
        self.tx
            .as_ref()
            .expect("server stopped")
            .send(Request { bins, enqueued: Instant::now(), reply: rtx })
            .expect("worker gone");
        rrx
    }

    /// Blocking convenience: submit and wait.
    pub fn infer_blocking(&self, bins: Vec<u16>) -> Reply {
        self.submit(bins).recv().expect("worker dropped request")
    }

    pub fn stats(&self) -> ServerStats {
        let batches = self.counters.batches.load(Ordering::Relaxed);
        let rows = self.counters.batch_rows.load(Ordering::Relaxed);
        ServerStats {
            requests: self.counters.requests.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 { 0.0 } else { rows as f64 / batches as f64 },
            errors: self.counters.errors.load(Ordering::Relaxed),
        }
    }

    /// Latency summary (seconds) over everything served so far.
    pub fn latency_summary(&self) -> Option<Summary> {
        let l = self.latencies.lock().unwrap();
        if l.is_empty() {
            None
        } else {
            Some(Summary::of(&l))
        }
    }

    /// Stop the worker (drains in-flight requests).
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::coordinator::backend::{CpuExactBackend, FunctionalBackend};
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    fn setup() -> (crate::data::Dataset, crate::trees::Ensemble, crate::compiler::CamProgram) {
        let d = by_name("churn").unwrap().generate_n(800);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 8, max_leaves: 8, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        (d, m, p)
    }

    #[test]
    fn serves_correct_predictions() {
        let (d, m, p) = setup();
        let server = Server::start(
            Box::new(FunctionalBackend::new(&p)),
            BatchPolicy::default(),
            p.n_features,
        );
        for i in 0..40 {
            let bins = p.quantizer.bin_row(d.row(i));
            let reply = server.infer_blocking(bins);
            assert_eq!(reply.prediction, m.predict(d.row(i)), "row {i}");
            assert!(reply.batch_size >= 1);
        }
        let stats = server.stats();
        assert_eq!(stats.requests, 40);
        assert_eq!(stats.errors, 0);
        server.shutdown();
    }

    #[test]
    fn batches_form_under_concurrent_load() {
        let (d, m, p) = setup();
        let server = Arc::new(Server::start(
            Box::new(CpuExactBackend { model: m }),
            BatchPolicy { max_wait_us: 2_000, max_batch: 16 },
            p.n_features,
        ));
        let n = 200;
        let mut rxs = Vec::new();
        for i in 0..n {
            rxs.push(server.submit(p.quantizer.bin_row(d.row(i % d.n_rows()))));
        }
        let mut max_batch_seen = 0;
        for rx in rxs {
            let r = rx.recv().unwrap();
            max_batch_seen = max_batch_seen.max(r.batch_size);
        }
        // Back-to-back submissions must have been coalesced.
        assert!(max_batch_seen > 1, "no batching happened");
        let stats = server.stats();
        assert!(stats.batches < n as u64);
        assert!(stats.mean_batch > 1.0);
    }

    #[test]
    fn latency_summary_populates() {
        let (d, _, p) = setup();
        let server = Server::start(
            Box::new(FunctionalBackend::new(&p)),
            BatchPolicy::default(),
            p.n_features,
        );
        for i in 0..10 {
            server.infer_blocking(p.quantizer.bin_row(d.row(i)));
        }
        let s = server.latency_summary().unwrap();
        assert_eq!(s.n, 10);
        assert!(s.min > 0.0);
    }

    #[test]
    #[should_panic(expected = "feature arity mismatch")]
    fn rejects_wrong_arity() {
        let (_, _, p) = setup();
        let server = Server::start(
            Box::new(FunctionalBackend::new(&p)),
            BatchPolicy::default(),
            p.n_features,
        );
        server.submit(vec![0u16; 3]);
    }
}
