//! L3 serving coordinator: pluggable inference backends, a dynamic
//! batcher feeding a pool of per-shard worker threads, and a multi-model
//! request router — the host-side system for the multi-card PCIe
//! deployment the paper envisions (§III-D), patterned after vLLM's
//! router/worker split. See DESIGN.md §"Sharded serving".

pub mod backend;
pub mod router;
pub mod server;

pub use backend::{Backend, CpuExactBackend, FunctionalBackend, XlaBackend};
pub use router::Router;
pub use server::{BatchPolicy, Reply, Server, ServerStats, ShardStats, LATENCY_RESERVOIR_CAP};
