//! L3 serving coordinator: pluggable inference backends, a dynamic
//! batcher feeding a pool of per-shard worker threads, and a
//! multi-tenant model [`Fleet`] — the host-side system for the
//! multi-card PCIe deployment the paper envisions (§III-D), patterned
//! after vLLM's router/worker split. See DESIGN.md §"Sharded serving"
//! and §"Model fleet".
//!
//! The fleet registers each model as a sharded server pool with a
//! bounded admission queue, and replaces models via drain-on-swap
//! ([`Fleet::swap_program`]) so a retrain→redeploy never drops an
//! in-flight request:
//!
//! ```
//! use xtime::compiler::{compile, CompileOptions};
//! use xtime::coordinator::{Fleet, ModelConfig};
//! use xtime::data::by_name;
//! use xtime::trees::{gbdt, GbdtParams};
//!
//! // Train and compile a small model, then serve it through the fleet.
//! let data = by_name("churn").unwrap().generate_n(300);
//! let params = GbdtParams { n_rounds: 3, max_leaves: 4, ..Default::default() };
//! let model = gbdt::train(&data, &params, None);
//! let program = compile(&model, &CompileOptions::default()).unwrap();
//!
//! let fleet = Fleet::new();
//! fleet.register_program("churn", &program, ModelConfig::for_program(&program)).unwrap();
//! let reply = fleet.infer("churn", data.row(0)).unwrap();
//! assert_eq!(reply.prediction, model.predict(data.row(0)));
//! assert_eq!(fleet.stats().models[0].served, 1);
//! fleet.shutdown(); // drains: every admitted request is answered first
//! ```

// Panic-path lint spine: coordinator threads hold the fleet's locks and
// worker queues — an unwind here can poison shared state for every
// tenant. Surviving `unwrap`/`expect` sites carry an `#[allow]` stating
// the invariant; everything else returns typed errors or degrades
// per-request.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod backend;
pub mod healer;
pub mod monitor;
pub mod router;
pub mod server;

pub use crate::analysis::VerifyPolicy;
pub use backend::{Backend, CpuExactBackend, FunctionalBackend, XlaBackend};
pub use healer::{HealContext, HealReport, SelfHealer};
pub use monitor::{
    CanarySet, DriftConfig, DriftDetector, DriftVerdict, HealthMonitor, HealthReading,
};
pub use router::{
    AdmitSlot, Admission, Fleet, FleetStats, ModelConfig, ModelStats, RouteHandle, Router,
    DEFAULT_QUEUE_CAP,
};
pub use server::{BatchPolicy, Reply, Server, ServerStats, ShardStats, LATENCY_RESERVOIR_CAP};
