//! Health monitor: closed-loop defect-drift detection on a live route.
//!
//! The self-healing loop's *sensor* (DESIGN.md §"Self-healing"): a
//! [`CanarySet`] of held-out rows with reference predictions pinned at
//! deployment time is periodically shadow-scored through the fleet, and
//! the agreement fraction — diluted by any backend errors the route's
//! [`super::ModelStats`] accrued since the last probe — feeds a
//! thresholded, hysteretic [`DriftDetector`]. A card whose analog CAM
//! cells pick up memristor defects (paper §V-A; injected mid-serve via
//! [`crate::sim::DefectInjector`]) starts contradicting its own pinned
//! predictions; `K` consecutive breaches below the trigger trip the
//! detector, and the [`super::healer`] takes over.
//!
//! Detection is *label-free*: the canary references are the deployed
//! model's own answers on frozen rows, so drift means "the silicon no
//! longer computes the program we verified", not "the world changed".
//! That is exactly the failure the defect-aware retrain loop
//! ([`crate::compiler::hat_defect_retrain`]) can repair.
//!
//! The detector is a pure state machine (no clocks, no I/O): probes are
//! whatever cadence the caller drives, which keeps every transition unit
//! testable (`rust/tests/self_heal.rs`) and the monitor reusable from a
//! test, the example's probe thread, or an operator loop.

use super::router::Fleet;

/// Thresholds and pacing of the drift detector.
#[derive(Clone, Copy, Debug)]
pub struct DriftConfig {
    /// A probe with agreement strictly below this fraction is a breach.
    pub trigger_below: f64,
    /// Hysteresis: a suspect route is considered healthy again only at
    /// agreement at or above this (must be ≥ `trigger_below`; probes in
    /// the band between neither breach nor clear — no flapping on
    /// borderline drift).
    pub clear_above: f64,
    /// Consecutive breaches required to trip (≥ 1). One noisy probe —
    /// a shed canary row, a transient shard error — must not trigger a
    /// retrain.
    pub breaches_to_trip: usize,
    /// Cold-start grace: this many initial probes are observed but never
    /// counted as breaches, so a route still filling its caches (or a
    /// just-repaired deployment warming up) cannot trip spuriously.
    pub grace_probes: usize,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig {
            trigger_below: 0.90,
            clear_above: 0.97,
            breaches_to_trip: 2,
            grace_probes: 1,
        }
    }
}

/// Outcome of one probe observation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftVerdict {
    /// Within the cold-start grace window; nothing counted.
    Grace,
    /// Agreement at or above `clear_above` (or in the hysteresis band
    /// with no breach streak in progress).
    Healthy,
    /// Breaches have started but the trip threshold is not reached, or
    /// the probe landed in the hysteresis band mid-streak.
    Suspect {
        /// Consecutive breaches so far.
        breaches: usize,
    },
    /// This probe tripped the detector: drift is confirmed, repair
    /// should start. Emitted exactly once per trip.
    Drift,
    /// Already tripped (repair presumably in flight); stays until
    /// [`DriftDetector::rearm`].
    Tripped,
}

/// Thresholded + hysteretic drift detector (pure state machine).
///
/// Trip rule: after the grace window, `breaches_to_trip` *consecutive*
/// probes below `trigger_below`. Probes in the hysteresis band
/// `[trigger_below, clear_above)` neither extend nor reset the streak —
/// a route hovering at the boundary stays `Suspect` instead of flapping
/// between healthy and tripped. Only agreement ≥ `clear_above` resets
/// the streak. Once tripped, the detector reports [`DriftVerdict::Tripped`]
/// until [`DriftDetector::rearm`] (called by the healer after the
/// repaired program is live), which also restarts the grace window.
#[derive(Debug)]
pub struct DriftDetector {
    cfg: DriftConfig,
    probes_seen: usize,
    breaches: usize,
    tripped: bool,
}

impl DriftDetector {
    /// Panics if the config is incoherent (`clear_above < trigger_below`
    /// would invert the hysteresis band; zero breaches would trip on
    /// nothing).
    pub fn new(cfg: DriftConfig) -> DriftDetector {
        assert!(
            cfg.clear_above >= cfg.trigger_below,
            "clear_above ({}) must be >= trigger_below ({})",
            cfg.clear_above,
            cfg.trigger_below
        );
        assert!(cfg.breaches_to_trip >= 1, "breaches_to_trip must be >= 1");
        DriftDetector { cfg, probes_seen: 0, breaches: 0, tripped: false }
    }

    /// Feed one probe's agreement fraction (`[0, 1]`); returns the
    /// verdict for this observation.
    pub fn observe(&mut self, agreement: f64) -> DriftVerdict {
        self.probes_seen += 1;
        if self.tripped {
            return DriftVerdict::Tripped;
        }
        if self.probes_seen <= self.cfg.grace_probes {
            return DriftVerdict::Grace;
        }
        if agreement < self.cfg.trigger_below {
            self.breaches += 1;
            if self.breaches >= self.cfg.breaches_to_trip {
                self.tripped = true;
                return DriftVerdict::Drift;
            }
            return DriftVerdict::Suspect { breaches: self.breaches };
        }
        if agreement >= self.cfg.clear_above {
            self.breaches = 0;
            return DriftVerdict::Healthy;
        }
        // Hysteresis band: hold the streak where it is.
        if self.breaches > 0 {
            DriftVerdict::Suspect { breaches: self.breaches }
        } else {
            DriftVerdict::Healthy
        }
    }

    /// Whether the detector is currently tripped.
    pub fn is_tripped(&self) -> bool {
        self.tripped
    }

    /// Reset after a repair: clears the trip and the breach streak and
    /// restarts the cold-start grace window for the new deployment.
    pub fn rearm(&mut self) {
        self.tripped = false;
        self.breaches = 0;
        self.probes_seen = 0;
    }

    pub fn config(&self) -> DriftConfig {
        self.cfg
    }
}

/// Held-out canary rows with pinned reference predictions: the
/// shadow-scoring probe's ground truth. References are the *deployed
/// route's own* answers at pin time, so agreement measures "does the
/// silicon still compute what we verified it computing", independent of
/// labels.
pub struct CanarySet {
    rows: Vec<Vec<f32>>,
    reference: Vec<f32>,
}

impl CanarySet {
    /// Pin `rows` against the live route: each row is scored once
    /// through the fleet and its prediction frozen as the reference.
    /// Errors if any canary row fails to score (a canary that cannot be
    /// served is no baseline).
    pub fn pin(fleet: &Fleet, model: &str, rows: Vec<Vec<f32>>) -> Result<CanarySet, String> {
        if rows.is_empty() {
            return Err("canary set needs at least one row".to_string());
        }
        let mut set = CanarySet { rows, reference: Vec::new() };
        set.repin(fleet, model)?;
        Ok(set)
    }

    /// Re-freeze the references against the (possibly just-swapped)
    /// live route. The healer calls this after publishing a repaired
    /// program so subsequent probes compare against the new deployment.
    pub fn repin(&mut self, fleet: &Fleet, model: &str) -> Result<(), String> {
        let mut reference = Vec::with_capacity(self.rows.len());
        for (i, admission) in fleet.infer_batch(model, &self.rows)?.into_iter().enumerate() {
            let reply = admission.map_err(|e| format!("pinning canary row {i}: {e}"))?;
            reference.push(reply.prediction);
        }
        self.reference = reference;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Shadow-score the canaries through the live route and return the
    /// fraction agreeing with the pinned references. Shed or errored
    /// rows count as disagreement — a card that cannot answer its
    /// canaries is not healthy.
    pub fn agreement(&self, fleet: &Fleet, model: &str) -> Result<f64, String> {
        let replies = fleet.infer_batch(model, &self.rows)?;
        let agree = replies
            .into_iter()
            .zip(&self.reference)
            .filter(|(reply, want)| match reply {
                Ok(r) => r.prediction == **want,
                Err(_) => false,
            })
            .count();
        Ok(agree as f64 / self.rows.len() as f64)
    }
}

/// One probe's measurements plus the detector's verdict.
#[derive(Clone, Copy, Debug)]
pub struct HealthReading {
    /// Canary agreement fraction, before error dilution.
    pub agreement: f64,
    /// Effective agreement fed to the detector (canary agreement diluted
    /// by route errors accrued since the previous probe).
    pub effective_agreement: f64,
    /// Route error-reply delta since the previous probe
    /// ([`super::ModelStats::errors`]).
    pub error_delta: u64,
    pub verdict: DriftVerdict,
}

/// The complete sensor: canary shadow-scoring plus per-route error
/// counters, folded through a [`DriftDetector`].
///
/// Error folding: `n` error replies since the last probe are treated as
/// `n` extra failed canaries — `effective = agree / (canaries + n)` —
/// so a defect storm that surfaces as backend errors (not just wrong
/// predictions) accelerates the trip instead of hiding from the canary
/// sample.
pub struct HealthMonitor {
    canary: CanarySet,
    detector: DriftDetector,
    last_errors: u64,
}

impl HealthMonitor {
    pub fn new(canary: CanarySet, cfg: DriftConfig) -> HealthMonitor {
        HealthMonitor { canary, detector: DriftDetector::new(cfg), last_errors: 0 }
    }

    /// Run one probe against the live route.
    pub fn probe(&mut self, fleet: &Fleet, model: &str) -> Result<HealthReading, String> {
        let agreement = self.canary.agreement(fleet, model)?;
        let errors = fleet
            .model_stats(model)
            .map(|s| s.errors)
            .ok_or_else(|| format!("unknown model `{model}`"))?;
        // A swap resets the route's counters; saturating keeps the delta
        // sane across the reset (the fresh route starts at zero).
        let error_delta = errors.saturating_sub(self.last_errors);
        self.last_errors = errors;
        let n = self.canary.len() as f64;
        let effective_agreement = agreement * n / (n + error_delta as f64);
        let verdict = self.detector.observe(effective_agreement);
        Ok(HealthReading { agreement, effective_agreement, error_delta, verdict })
    }

    /// Whether the detector is tripped (repair needed / in flight).
    pub fn is_tripped(&self) -> bool {
        self.detector.is_tripped()
    }

    /// Post-repair reset: re-pin the canary references against the
    /// repaired live route, zero the error baseline, and rearm the
    /// detector (fresh grace window).
    pub fn rearm_with(&mut self, fleet: &Fleet, model: &str) -> Result<(), String> {
        self.canary.repin(fleet, model)?;
        self.last_errors = fleet.model_stats(model).map(|s| s.errors).unwrap_or(0);
        self.detector.rearm();
        Ok(())
    }

    pub fn detector(&self) -> &DriftDetector {
        &self.detector
    }

    pub fn canary(&self) -> &CanarySet {
        &self.canary
    }
}
