//! Request router: the multi-model front end.
//!
//! §III-D: "multiple unique models can be mapped to the accelerator, by
//! assigning a different batch to each model". The router owns the
//! quantizers (the host-side "DAC"), routes raw feature rows to the right
//! model's server, and exposes aggregate metrics.

use super::server::{BatchPolicy, Reply, Server};
use super::backend::Backend;
use crate::data::FeatureQuantizer;
use std::collections::BTreeMap;
use std::sync::mpsc::Receiver;

struct Route {
    server: Server,
    quantizer: FeatureQuantizer,
    n_features: usize,
}

/// Routes requests by model name.
#[derive(Default)]
pub struct Router {
    routes: BTreeMap<String, Route>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Register a model: its quantizer + a backend to serve it.
    pub fn register(
        &mut self,
        name: &str,
        quantizer: FeatureQuantizer,
        backend: Box<dyn Backend>,
        policy: BatchPolicy,
    ) {
        let n_features = quantizer.edges.len();
        let server = Server::start(backend, policy, n_features);
        self.routes.insert(name.to_string(), Route { server, quantizer, n_features });
    }

    pub fn models(&self) -> Vec<&str> {
        self.routes.keys().map(|s| s.as_str()).collect()
    }

    /// Async submit of a raw feature row.
    pub fn submit(&self, model: &str, row: &[f32]) -> Result<Receiver<Reply>, String> {
        let route = self.routes.get(model).ok_or_else(|| format!("unknown model `{model}`"))?;
        if row.len() != route.n_features {
            return Err(format!(
                "model `{model}` expects {} features, got {}",
                route.n_features,
                row.len()
            ));
        }
        Ok(route.server.submit(route.quantizer.bin_row(row)))
    }

    /// Blocking inference. Backend/shard failures surface in the `Err`
    /// arm (the server sends an error [`Reply`] rather than hanging up),
    /// so `Ok` always carries a served prediction.
    pub fn infer(&self, model: &str, row: &[f32]) -> Result<Reply, String> {
        let reply = self
            .submit(model, row)?
            .recv()
            .map_err(|_| format!("model `{model}` worker dropped the request"))?;
        match reply.error {
            Some(e) => Err(format!("model `{model}` inference failed: {e}")),
            None => Ok(reply),
        }
    }

    /// Per-model (requests, mean batch) metrics.
    pub fn stats(&self) -> Vec<(String, u64, f64)> {
        self.routes
            .iter()
            .map(|(name, r)| {
                let s = r.server.stats();
                (name.clone(), s.requests, s.mean_batch)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::coordinator::backend::FunctionalBackend;
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    fn add_model(
        router: &mut Router,
        dataset: &str,
    ) -> (crate::data::Dataset, crate::trees::Ensemble) {
        let d = by_name(dataset).unwrap().generate_n(600);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 5, max_leaves: 8, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        router.register(
            dataset,
            p.quantizer.clone(),
            Box::new(FunctionalBackend::new(&p)),
            BatchPolicy::default(),
        );
        (d, m)
    }

    #[test]
    fn routes_multiple_models() {
        let mut router = Router::new();
        let (d1, m1) = add_model(&mut router, "churn");
        let (d2, m2) = add_model(&mut router, "telco");
        assert_eq!(router.models(), vec!["churn", "telco"]);
        for i in 0..20 {
            let r1 = router.infer("churn", d1.row(i)).unwrap();
            assert_eq!(r1.prediction, m1.predict(d1.row(i)));
            let r2 = router.infer("telco", d2.row(i)).unwrap();
            assert_eq!(r2.prediction, m2.predict(d2.row(i)));
        }
        let stats = router.stats();
        assert_eq!(stats.len(), 2);
        assert!(stats.iter().all(|(_, reqs, _)| *reqs == 20));
    }

    #[test]
    fn rejects_unknown_model_and_bad_arity() {
        let mut router = Router::new();
        let (d, _) = add_model(&mut router, "churn");
        assert!(router.infer("nope", d.row(0)).is_err());
        assert!(router.infer("churn", &[1.0, 2.0]).is_err());
    }

    /// Regression: the server reports backend failures via an error
    /// `Reply` (it no longer hangs up), so `Router::infer` must fold
    /// that into its `Err` arm rather than returning an `Ok` carrying
    /// NaN/empty logits.
    #[test]
    fn backend_failure_surfaces_as_err() {
        struct FailingBackend;
        impl Backend for FailingBackend {
            fn name(&self) -> &'static str {
                "always-fails"
            }
            fn max_batch(&self) -> usize {
                8
            }
            fn task(&self) -> crate::data::Task {
                crate::data::Task::Binary
            }
            fn infer(&mut self, _batch: &[Vec<u16>]) -> anyhow::Result<Vec<Vec<f32>>> {
                Err(anyhow::anyhow!("injected fault"))
            }
        }
        let mut router = Router::new();
        router.register(
            "flaky",
            FeatureQuantizer { n_bits: 1, edges: vec![vec![0.5]] },
            Box::new(FailingBackend),
            BatchPolicy::default(),
        );
        let err = router.infer("flaky", &[0.3]).unwrap_err();
        assert!(err.contains("injected fault"), "got `{err}`");
    }
}
