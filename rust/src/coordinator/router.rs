//! Model fleet coordinator: the multi-tenant serving front end.
//!
//! §III-D: "multiple unique models can be mapped to the accelerator, by
//! assigning a different batch to each model". [`Fleet`] is that host:
//! it owns one sharded [`Server`] per registered model (the quantizer is
//! the host-side "DAC"), routes raw feature rows — single rows or whole
//! client batches — to the right model's pool, and degrades
//! deterministically under overload:
//!
//! * **sharded + planned registration** — [`Fleet::register_program`]
//!   partitions a compiled [`CamProgram`] across
//!   [`ModelConfig::shards`] cards and spins up
//!   [`Server::start_sharded`] over planned-execution functional
//!   backends; [`Fleet::register_backends`] accepts any backend pool
//!   (simulated PCIe cards, XLA) for the same route;
//! * **admission control** — each route holds a bounded queue
//!   ([`ModelConfig::queue_cap`]): [`Fleet::submit`] returns
//!   [`Admission::Accepted`] with the reply channel or
//!   [`Admission::Shed`] with the observed depth, and per-model +
//!   fleet-level shed/admitted counters account for every request
//!   exactly (an overloaded tenant sheds at its cap instead of growing
//!   an unbounded mpsc queue — the resource-contention regime RETENTION
//!   (Liao et al., 2025) studies for tree ensembles on CAMs);
//! * **hot swap / unload** — [`Fleet::swap_program`] atomically
//!   replaces a route while the old server drains under the
//!   [`Server::shutdown`] drain contract: every already-admitted
//!   request is answered by the server (and therefore the program) it
//!   was admitted to, bit-exactly, and only then do the old workers
//!   exit (DESIGN.md §5 contract 6). This is what lets the
//!   hardware-aware-training retrain → redeploy loop (PR 3) run against
//!   live traffic;
//! * **fleet observability** — [`Fleet::stats`] returns named
//!   [`FleetStats`]/[`ModelStats`] (admitted/shed/served, batching,
//!   queue depth, per-shard counters, latency summary from the
//!   bounded reservoir) consumed by `xtime serve --models …` and
//!   `examples/fleet_serving.rs`.
//!
//! [`Router`] remains as a thin alias for the single-model-era name;
//! duplicate registration is an error (replacement goes through
//! `swap_*` exclusively, so a live server can never be dropped without
//! its drain).

use super::backend::{Backend, FunctionalBackend};
use super::server::{BatchPolicy, QueueTicket, Reply, Server, ShardStats};
use crate::analysis::{self, AnalysisReport, VerifyPolicy};
use crate::artifact::{ArtifactStore, LoadedArtifact};
use crate::compiler::{partition, CamProgram, PartitionOptions};
use crate::data::FeatureQuantizer;
use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, RwLock};

/// Default bounded-queue capacity for [`ModelConfig`]: deep enough that
/// a healthy backend never sheds, small enough that a stalled one
/// back-pressures clients in milliseconds instead of hoarding requests.
pub const DEFAULT_QUEUE_CAP: usize = 1024;

/// Per-model serving configuration.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    /// Worker backends the route fans out to (≥ 1). For
    /// [`Fleet::register_program`] this is the number of shard programs
    /// the compiled model is partitioned into (one virtual PCIe card
    /// each, ADR-001); `1` serves the unpartitioned program.
    pub shards: usize,
    /// Dynamic-batching policy for the route's server, including the
    /// planned-execution `threads` knob pushed to every backend
    /// (ADR-002; bit-identical at every setting).
    pub batch_policy: BatchPolicy,
    /// Admission bound: at most this many requests may be in the server
    /// (admitted, reply not yet sent) before [`Fleet::submit`] sheds.
    /// `0` = unbounded (the pre-fleet behavior).
    pub queue_cap: usize,
    /// Host-side "DAC": raw f32 rows → quantized bins for this model.
    pub quantizer: FeatureQuantizer,
    /// Static-verifier gate run by [`Fleet::register_program`] /
    /// [`Fleet::swap_program`] before any backend is built (DESIGN.md §5
    /// contract 8). Default: refuse deny-level findings.
    pub verify: VerifyPolicy,
    /// Run the sparsity-aware capacity-compression pass
    /// ([`crate::compiler::compress_program`]) on registration/swap when
    /// the program is not already compressed. Bit-identical serving
    /// either way (DESIGN.md §5 contract 11); the compressed route
    /// occupies fewer physical CAM rows and is gated by verifier rule
    /// V7 like any other compressed deployment.
    pub compress: bool,
}

impl ModelConfig {
    /// Config serving `program` unsharded with the default batch policy
    /// and queue bound; chain [`ModelConfig::with_shards`] /
    /// [`ModelConfig::with_policy`] / [`ModelConfig::with_queue_cap`]
    /// to specialize.
    pub fn for_program(program: &CamProgram) -> ModelConfig {
        ModelConfig {
            shards: 1,
            batch_policy: BatchPolicy::default(),
            queue_cap: DEFAULT_QUEUE_CAP,
            quantizer: program.quantizer.clone(),
            verify: VerifyPolicy::default(),
            compress: false,
        }
    }

    pub fn with_shards(mut self, shards: usize) -> ModelConfig {
        self.shards = shards.max(1);
        self
    }

    pub fn with_policy(mut self, policy: BatchPolicy) -> ModelConfig {
        self.batch_policy = policy;
        self
    }

    pub fn with_queue_cap(mut self, cap: usize) -> ModelConfig {
        self.queue_cap = cap;
        self
    }

    /// Set the registration-gate policy ([`VerifyPolicy::Skip`] trusts
    /// the compiler; [`VerifyPolicy::DenyWarnings`] also refuses V5
    /// dead-leaf warnings, e.g. for defect-free golden deployments).
    pub fn with_verify(mut self, policy: VerifyPolicy) -> ModelConfig {
        self.verify = policy;
        self
    }

    /// Enable the capacity-compression pass at registration/swap time
    /// (no-op for programs that already carry compression layouts).
    pub fn with_compress(mut self, on: bool) -> ModelConfig {
        self.compress = on;
        self
    }
}

/// Outcome of submitting a request to a bounded route.
pub enum Admission {
    /// The request holds a queue slot; the reply arrives on the channel
    /// (successful or error — never silently dropped, even across a
    /// swap or unregister of the model).
    Accepted(Receiver<Reply>),
    /// The route's queue was at capacity; the request was **not**
    /// enqueued and is counted in the model's and the fleet's `shed`.
    Shed {
        /// The queue bound the refusal was made against
        /// ([`ModelConfig::queue_cap`]): the route held this many
        /// admitted-but-unanswered requests when the claim failed. (The
        /// live gauge may already be lower by the time the caller looks
        /// — workers drain concurrently — so the *configured* bound is
        /// reported, which is deterministic.)
        queue_depth: usize,
    },
}

impl Admission {
    pub fn is_accepted(&self) -> bool {
        matches!(self, Admission::Accepted(_))
    }

    /// Blocking convenience: wait for the reply, folding shedding and
    /// backend errors into `Err`.
    pub fn recv(self) -> Result<Reply, String> {
        match self {
            Admission::Shed { queue_depth } => {
                Err(format!("request shed (queue at {queue_depth})"))
            }
            Admission::Accepted(rx) => {
                let reply =
                    rx.recv().map_err(|_| "worker dropped the request".to_string())?;
                match reply.error {
                    Some(e) => Err(e),
                    None => Ok(reply),
                }
            }
        }
    }
}

/// One registered model: its server pool plus admission state.
struct Route {
    server: Server,
    cfg: ModelConfig,
    n_features: usize,
    /// Fleet-unique deployment generation: every publish (register or
    /// swap) of a name gets a fresh epoch, so a caller that observed one
    /// deployment can detect that a concurrent operator replaced it
    /// ([`Fleet::swap_backends_expecting`]).
    epoch: u64,
    /// Requests admitted whose reply has not been sent yet (the ticket
    /// gauge; see [`QueueTicket`]).
    depth: Arc<AtomicUsize>,
    admitted: AtomicU64,
    shed: AtomicU64,
}

impl Route {
    fn start(
        backends: Vec<Box<dyn Backend>>,
        base_score: Vec<f32>,
        cfg: ModelConfig,
        epoch: u64,
    ) -> Result<Route, String> {
        if backends.is_empty() {
            return Err("a route needs at least one backend".to_string());
        }
        let n_features = cfg.quantizer.edges.len();
        let server = Server::start_sharded(backends, base_score, cfg.batch_policy, n_features);
        Ok(Route {
            server,
            cfg,
            n_features,
            epoch,
            depth: Arc::new(AtomicUsize::new(0)),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        })
    }

    fn stats(&self, name: &str) -> ModelStats {
        let s = self.server.stats();
        ModelStats {
            name: name.to_string(),
            shards: s.shards.len(),
            epoch: self.epoch,
            degraded: self.server.is_degraded(),
            admitted: self.admitted.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            served: self.server.latency_samples_seen(),
            errors: s.errors,
            batches: s.batches,
            mean_batch: s.mean_batch,
            queue_depth: self.depth.load(Ordering::Acquire),
            queue_cap: self.cfg.queue_cap,
            latency: self.server.latency_summary(),
            shard_stats: s.shards,
        }
    }
}

/// Point-in-time statistics of one route (since its registration or
/// last swap — a swap starts a fresh server and fresh route counters;
/// fleet-level totals in [`FleetStats`] survive).
#[derive(Clone, Debug)]
pub struct ModelStats {
    pub name: String,
    /// Worker backends in the route's pool.
    pub shards: usize,
    /// Deployment generation of this route (see [`Fleet::route_epoch`]).
    pub epoch: u64,
    /// True while the route serves in degraded mode (a repair is in
    /// flight; replies carry `degraded = true`).
    pub degraded: bool,
    /// Requests that passed admission.
    pub admitted: u64,
    /// Requests refused at the queue bound (never enqueued).
    pub shed: u64,
    /// Rows whose successful reply has been sent.
    pub served: u64,
    /// Rows that received an error reply (backend/shard failures).
    pub errors: u64,
    pub batches: u64,
    pub mean_batch: f64,
    /// Admitted requests still owed a reply right now.
    pub queue_depth: usize,
    /// Admission bound (0 = unbounded).
    pub queue_cap: usize,
    /// Seconds; uniform reservoir sample over everything served
    /// ([`super::LATENCY_RESERVOIR_CAP`] retained samples).
    pub latency: Option<Summary>,
    /// Per-worker counters from the route's server.
    pub shard_stats: Vec<ShardStats>,
}

/// Fleet-wide snapshot: every live route plus lifetime totals.
#[derive(Clone, Debug)]
pub struct FleetStats {
    /// One entry per registered model, name-sorted.
    pub models: Vec<ModelStats>,
    /// Requests admitted across the fleet's lifetime — including routes
    /// since swapped or unregistered.
    pub admitted: u64,
    /// Requests shed across the fleet's lifetime.
    pub shed: u64,
}

/// Multi-model fleet coordinator. All methods take `&self` (routes live
/// behind an `RwLock`), so one `Arc<Fleet>` serves concurrent client
/// threads while another thread swaps or unloads models.
///
/// The lock guards only the name→route map; submissions clone the
/// route's `Arc` and quantize/admit **outside** the lock, so one
/// tenant's large client batch can never head-of-line-block other
/// tenants (or an operator's swap) behind the guard.
#[derive(Default)]
pub struct Fleet {
    routes: RwLock<Routes>,
    total_admitted: AtomicU64,
    total_shed: AtomicU64,
    /// Monotonic deployment-epoch allocator (first epoch is 1).
    epoch_counter: AtomicU64,
}

type Routes = BTreeMap<String, Arc<Route>>;

/// Routes-map access continuing through lock poisoning: the map is
/// structurally valid at every point a panicking holder could have
/// stopped (single insert/remove/lookup statements), so poison carries
/// no integrity signal here — and refusing all access would turn one
/// panicked request thread into a whole-fleet outage.
fn routes_read(lock: &RwLock<Routes>) -> std::sync::RwLockReadGuard<'_, Routes> {
    lock.read().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn routes_write(lock: &RwLock<Routes>) -> std::sync::RwLockWriteGuard<'_, Routes> {
    lock.write().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// The single-model-era name; the fleet is a drop-in superset.
pub type Router = Fleet;

impl Fleet {
    pub fn new() -> Fleet {
        Fleet::default()
    }

    /// Register a compiled program: runs the static verifier per
    /// [`ModelConfig::verify`] (a blocked program is refused with the
    /// worst finding — DESIGN.md §5 contract 8), partitions it into
    /// [`ModelConfig::shards`] shard programs (ADR-001) and serves each
    /// through a planned-execution [`FunctionalBackend`]
    /// ([`Server::start_sharded`] aggregation is bit-identical to the
    /// unsharded engine). Errors if `name` is already registered —
    /// replacement goes through [`Fleet::swap_program`].
    pub fn register_program(
        &self,
        name: &str,
        program: &CamProgram,
        cfg: ModelConfig,
    ) -> Result<(), String> {
        let (backends, base_score) = verified_shards(program, &cfg)?;
        self.register_backends(name, backends, base_score, cfg)
    }

    /// Register a model served by an explicit backend pool (simulated
    /// PCIe cards, XLA, test doubles). `base_score` is the source
    /// ensemble's additive prior for >1 backend
    /// ([`crate::compiler::ShardPlan::base_score`]); ignored for a pool
    /// of one.
    pub fn register_backends(
        &self,
        name: &str,
        backends: Vec<Box<dyn Backend>>,
        base_score: Vec<f32>,
        cfg: ModelConfig,
    ) -> Result<(), String> {
        let route = Route::start(backends, base_score, cfg, self.next_epoch())?;
        let mut routes = routes_write(&self.routes);
        if routes.contains_key(name) {
            // The fresh route has seen no traffic; dropping it just
            // joins idle workers. The live server is untouched.
            return Err(format!(
                "model `{name}` is already registered; replace it with `swap`, not `register`"
            ));
        }
        routes.insert(name.to_string(), Arc::new(route));
        Ok(())
    }

    /// Compatibility shim for the pre-fleet `Router::register`: one
    /// backend, unbounded queue. Now **errors on duplicate names**
    /// instead of silently dropping the old route's server mid-flight.
    pub fn register(
        &self,
        name: &str,
        quantizer: FeatureQuantizer,
        backend: Box<dyn Backend>,
        policy: BatchPolicy,
    ) -> Result<(), String> {
        let cfg = ModelConfig {
            shards: 1,
            batch_policy: policy,
            queue_cap: 0,
            quantizer,
            verify: VerifyPolicy::default(),
            compress: false,
        };
        self.register_backends(name, vec![backend], Vec::new(), cfg)
    }

    /// Hot-swap `name` to a newly compiled program (the HAT retrain →
    /// redeploy loop): the new sharded server goes live atomically, then
    /// this call blocks while the old server drains — every request
    /// admitted before the swap receives its reply *from the old
    /// program*, bit-exactly (contract 6). The replacement passes the
    /// same static-verifier gate as registration (contract 8): a
    /// refused program leaves the live route serving, untouched. Errors
    /// if `name` is unknown.
    pub fn swap_program(
        &self,
        name: &str,
        program: &CamProgram,
        cfg: ModelConfig,
    ) -> Result<(), String> {
        let (backends, base_score) = verified_shards(program, &cfg)?;
        self.swap_backends(name, backends, base_score, cfg)
    }

    /// [`Fleet::swap_program`] for an explicit backend pool. The
    /// deployment observed at entry is the one replaced: the current
    /// epoch is captured before the new pool spins up and rechecked
    /// under the write lock ([`Fleet::swap_backends_expecting`]), so a
    /// concurrent `unregister` + `register_from_artifact` of the same
    /// name surfaces as a structured error instead of being silently
    /// clobbered by this swap.
    pub fn swap_backends(
        &self,
        name: &str,
        backends: Vec<Box<dyn Backend>>,
        base_score: Vec<f32>,
        cfg: ModelConfig,
    ) -> Result<(), String> {
        let expected = self.route(name).map_err(|_| {
            format!("cannot swap unknown model `{name}`; register it first")
        })?;
        self.swap_backends_expecting(name, expected.epoch, backends, base_score, cfg)
    }

    /// Compare-and-swap variant of [`Fleet::swap_backends`]: replace the
    /// route only if it is still the deployment generation the caller
    /// observed (`expected_epoch`, from [`Fleet::route_epoch`] or
    /// [`ModelStats::epoch`]). If the name was concurrently unregistered
    /// or re-registered (a different epoch is live), the swap is refused
    /// with a structured error, the freshly built pool is torn down
    /// untraffic'd, and the live route keeps serving — no silent
    /// last-writer-wins. The self-healing repair driver publishes
    /// through this, pinning the deployment it diagnosed.
    pub fn swap_backends_expecting(
        &self,
        name: &str,
        expected_epoch: u64,
        backends: Vec<Box<dyn Backend>>,
        base_score: Vec<f32>,
        cfg: ModelConfig,
    ) -> Result<(), String> {
        let fresh = Route::start(backends, base_score, cfg, self.next_epoch())?;
        let old = {
            let mut routes = routes_write(&self.routes);
            match routes.get_mut(name) {
                Some(slot) if slot.epoch != expected_epoch => {
                    return Err(format!(
                        "cannot swap model `{name}`: deployment changed concurrently \
                         (expected epoch {expected_epoch}, live epoch {}); \
                         re-read the route and retry",
                        slot.epoch
                    ));
                }
                Some(slot) => std::mem::replace(slot, Arc::new(fresh)),
                None => {
                    return Err(format!(
                        "cannot swap model `{name}`: it was concurrently unregistered \
                         (expected epoch {expected_epoch})"
                    ))
                }
            }
        };
        // Write lock released: new submissions already land on the new
        // server. Old in-flight requests hold reply channels bound to
        // the old server; the drain blocks until each has its reply
        // (the drain contract), so no queued reply is ever dropped.
        drain_route(old);
        Ok(())
    }

    /// Register a model straight from a stored artifact (cold start
    /// without retraining). The store fully verifies the artifact on
    /// load — manifest bytes hash to `id`, every blob hashes to its
    /// digest, every decode succeeds — and then the decoded program
    /// passes through the same static-verifier gate as any other
    /// registration, which is what makes the artifact path satisfy
    /// contract 9 (DESIGN.md §5): an artifact-loaded program goes live
    /// only if it is verify-clean, and it then serves bit-identically
    /// to the in-memory original it was exported from. With `cfg:
    /// None`, the shard count recorded in the manifest is replayed
    /// (`1` for an unsharded artifact).
    pub fn register_from_artifact(
        &self,
        name: &str,
        store: &ArtifactStore,
        id: &str,
        cfg: Option<ModelConfig>,
    ) -> Result<(), String> {
        let (art, cfg) = load_for_serving(store, id, cfg)?;
        self.register_program(name, &art.program, cfg)
    }

    /// Hot-swap `name` to a stored artifact: [`Fleet::swap_program`]
    /// semantics (atomic cutover, old server drains under contract 6)
    /// with the program sourced from — and digest-verified against —
    /// the store instead of an in-memory compile.
    pub fn swap_to_digest(
        &self,
        name: &str,
        store: &ArtifactStore,
        id: &str,
        cfg: Option<ModelConfig>,
    ) -> Result<(), String> {
        let (art, cfg) = load_for_serving(store, id, cfg)?;
        self.swap_program(name, &art.program, cfg)
    }

    /// Unload a model. Blocks while the route's server drains: requests
    /// admitted before the unregister still receive their replies.
    pub fn unregister(&self, name: &str) -> Result<(), String> {
        let old = routes_write(&self.routes)
            .remove(name)
            .ok_or_else(|| format!("cannot unregister unknown model `{name}`"))?;
        drain_route(old);
        Ok(())
    }

    /// Compare-and-unregister: remove the route only if it is still the
    /// deployment the caller observed. A concurrent re-registration (new
    /// epoch) is refused with a structured error and keeps serving — the
    /// guard that stops an operator's stale unload from tearing down a
    /// model someone else just published under the same name.
    pub fn unregister_expecting(&self, name: &str, expected_epoch: u64) -> Result<(), String> {
        let old = {
            let mut routes = routes_write(&self.routes);
            match routes.get(name) {
                None => {
                    return Err(format!(
                        "cannot unregister model `{name}`: it was concurrently \
                         unregistered (expected epoch {expected_epoch})"
                    ))
                }
                Some(route) if route.epoch != expected_epoch => {
                    return Err(format!(
                        "cannot unregister model `{name}`: deployment changed \
                         concurrently (expected epoch {expected_epoch}, live epoch {})",
                        route.epoch
                    ));
                }
                // Invariant: checked present above; remove under the
                // same write guard cannot miss.
                #[allow(clippy::expect_used)]
                Some(_) => routes.remove(name).expect("checked present under write lock"),
            }
        };
        drain_route(old);
        Ok(())
    }

    /// Deployment generation currently live for `name` (`None` if
    /// unknown). Epochs are fleet-unique and monotonic: every register
    /// or swap publishes a fresh one, so two reads returning the same
    /// epoch bracket an interval with no replacement in between. Pin
    /// one, then publish with [`Fleet::swap_backends_expecting`] /
    /// [`Fleet::unregister_expecting`] to act only on the deployment
    /// you diagnosed.
    pub fn route_epoch(&self, name: &str) -> Option<u64> {
        routes_read(&self.routes).get(name).map(|r| r.epoch)
    }

    /// Flip degraded-serving mode on a live route: while set, every
    /// reply the route produces carries `degraded = true` (and its
    /// [`ModelStats::degraded`] reads true), telling callers to treat
    /// low-confidence answers with suspicion until the repair lands.
    pub fn set_degraded(&self, name: &str, on: bool) -> Result<(), String> {
        let route = self.route(name)?;
        route.server.set_degraded(on);
        Ok(())
    }

    fn next_epoch(&self) -> u64 {
        self.epoch_counter.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Registered model names (sorted).
    pub fn models(&self) -> Vec<String> {
        routes_read(&self.routes).keys().cloned().collect()
    }

    /// Admission-controlled async submit of a raw feature row.
    pub fn submit(&self, model: &str, row: &[f32]) -> Result<Admission, String> {
        let handle = self.handle(model)?; // routes lock released here
        handle.check_arity(row.len())?;
        Ok(handle.submit_row(row))
    }

    /// Admission-controlled submit of a whole client batch. Rows are
    /// enqueued back to back onto one route snapshot, so the server's
    /// dynamic batcher coalesces them into shared device batches — the
    /// PR 2/4 batched hot path — instead of row-at-a-time round trips.
    /// Quantization and admission run outside the routes lock, so a
    /// large batch never head-of-line-blocks other tenants. Input
    /// errors (unknown model, wrong arity anywhere in the batch) fail
    /// the whole call before anything is enqueued; per-row admission is
    /// reported in the returned vector.
    pub fn submit_batch(
        &self,
        model: &str,
        rows: &[Vec<f32>],
    ) -> Result<Vec<Admission>, String> {
        let handle = self.handle(model)?; // routes lock released here
        for row in rows {
            handle.check_arity(row.len())?;
        }
        Ok(rows.iter().map(|row| handle.submit_row(row)).collect())
    }

    /// Blocking single-row inference. Shedding, backend/shard failures
    /// and unknown models all surface in the `Err` arm, so `Ok` always
    /// carries a served prediction.
    pub fn infer(&self, model: &str, row: &[f32]) -> Result<Reply, String> {
        self.submit(model, row)?
            .recv()
            .map_err(|e| format!("model `{model}`: {e}"))
    }

    /// Blocking batch inference: submit the whole batch, then wait for
    /// every reply. Per-row outcomes (shed rows, failed batches) come
    /// back as `Err` entries; the outer `Err` is for input errors only.
    pub fn infer_batch(
        &self,
        model: &str,
        rows: &[Vec<f32>],
    ) -> Result<Vec<Result<Reply, String>>, String> {
        let admissions = self.submit_batch(model, rows)?;
        Ok(admissions.into_iter().map(Admission::recv).collect())
    }

    /// Stats for one model, `None` if unknown.
    pub fn model_stats(&self, name: &str) -> Option<ModelStats> {
        let route = routes_read(&self.routes).get(name).cloned()?;
        Some(route.stats(name))
    }

    /// Fleet-wide snapshot: per-model [`ModelStats`] plus lifetime
    /// admitted/shed totals. Counter snapshotting runs outside the
    /// routes lock.
    pub fn stats(&self) -> FleetStats {
        let routes: Vec<(String, Arc<Route>)> = routes_read(&self.routes)
            .iter()
            .map(|(name, r)| (name.clone(), r.clone()))
            .collect();
        FleetStats {
            models: routes.iter().map(|(name, r)| r.stats(name)).collect(),
            admitted: self.total_admitted.load(Ordering::Relaxed),
            shed: self.total_shed.load(Ordering::Relaxed),
        }
    }

    /// Drain every route and join all workers.
    pub fn shutdown(self) {
        let routes =
            self.routes.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        for (_, route) in routes {
            drain_route(route);
        }
    }

    /// Clone the named route's handle out of the map — the lock guard
    /// lives only for this statement, so quantization, admission and
    /// reply waits all run without it.
    fn route(&self, model: &str) -> Result<Arc<Route>, String> {
        routes_read(&self.routes)
            .get(model)
            .cloned()
            .ok_or_else(|| format!("unknown model `{model}`"))
    }

    /// Snapshot the named route into a [`RouteHandle`] that can make
    /// admission decisions **before** request payloads are decoded — the
    /// wire front end's shed-before-parse path. The routes lock is held
    /// only for the map lookup; everything done through the handle runs
    /// without it. The handle pins its route snapshot: a concurrent swap
    /// publishes the new route immediately to *new* lookups, while work
    /// submitted through this handle lands on (and is drained by) the
    /// server it was admitted to — exactly the contract-6 behavior of
    /// the in-process path.
    pub fn handle(&self, model: &str) -> Result<RouteHandle<'_>, String> {
        let route = self.route(model)?;
        Ok(RouteHandle { fleet: self, name: model.to_string(), route })
    }
}

/// A claimed admission slot: proof that one request passed a route's
/// queue bound. Produced by [`RouteHandle::try_admit`] *before* any
/// feature payload is deserialized and consumed by
/// [`RouteHandle::submit_admitted`]. The slot wraps the route's RAII
/// [`QueueTicket`], so dropping an unused slot releases the queue
/// position (the request still counts as admitted in the fleet's
/// accounting — claim-side counters are what make
/// `admitted + shed == offered` exact under races).
pub struct AdmitSlot {
    ticket: QueueTicket,
}

/// A pinned snapshot of one model's route, exposing the fleet's
/// admission machinery in two phases — claim ([`RouteHandle::try_admit`])
/// separated from payload decode + enqueue
/// ([`RouteHandle::submit_admitted`]) — so transport front ends can shed
/// at the queue bound without ever touching the bytes of a refused row.
pub struct RouteHandle<'f> {
    fleet: &'f Fleet,
    name: String,
    route: Arc<Route>,
}

impl RouteHandle<'_> {
    /// Feature arity this model expects.
    pub fn n_features(&self) -> usize {
        self.route.n_features
    }

    /// Configured admission bound (0 = unbounded).
    pub fn queue_cap(&self) -> usize {
        self.route.cfg.queue_cap
    }

    /// Live gauge: admitted requests not yet answered.
    pub fn queue_depth(&self) -> usize {
        self.route.depth.load(Ordering::Acquire)
    }

    /// Check a row's feature count against the model, with the same
    /// error text as [`Fleet::submit`].
    pub fn check_arity(&self, got: usize) -> Result<(), String> {
        check_arity(&self.route, &self.name, got)
    }

    /// Try to claim one queue slot. `Some` counts the request as
    /// admitted (route + fleet totals); `None` counts it as shed. This
    /// touches only atomics — no quantization, no payload access — so a
    /// wire listener can call it straight off the frame header.
    pub fn try_admit(&self) -> Option<AdmitSlot> {
        match QueueTicket::try_claim(&self.route.depth, self.route.cfg.queue_cap) {
            Some(ticket) => {
                self.route.admitted.fetch_add(1, Ordering::Relaxed);
                self.fleet.total_admitted.fetch_add(1, Ordering::Relaxed);
                Some(AdmitSlot { ticket })
            }
            None => {
                self.route.shed.fetch_add(1, Ordering::Relaxed);
                self.fleet.total_shed.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Quantize an already-admitted row and enqueue it, transferring
    /// the slot's ticket into the server (released when the reply is
    /// sent). Decode/quantization happens here — after admission — which
    /// is what keeps the refused path payload-free.
    pub fn submit_admitted(&self, slot: AdmitSlot, row: &[f32]) -> Receiver<Reply> {
        let bins = self.route.cfg.quantizer.bin_row(row);
        self.route.server.submit_ticketed(bins, Some(slot.ticket))
    }

    /// One-shot claim + enqueue: the in-process [`Fleet::submit`] path.
    pub fn submit_row(&self, row: &[f32]) -> Admission {
        match self.try_admit() {
            Some(slot) => Admission::Accepted(self.submit_admitted(slot, row)),
            None => Admission::Shed { queue_depth: self.route.cfg.queue_cap },
        }
    }
}

/// Block until no submitter still holds `route` (they hold it only for
/// the short lookup→enqueue window), then drain its server: every
/// request it admitted receives its reply before this returns —
/// `swap_*`/`unregister` ride this for contract 6's "returns only after
/// the drain completed".
fn drain_route(mut route: Arc<Route>) {
    let route = loop {
        match Arc::try_unwrap(route) {
            Ok(route) => break route,
            Err(still_shared) => {
                route = still_shared;
                std::thread::yield_now();
            }
        }
    };
    let Route { server, .. } = route;
    server.shutdown();
}

fn check_arity(route: &Route, model: &str, got: usize) -> Result<(), String> {
    if got != route.n_features {
        return Err(format!(
            "model `{model}` expects {} features, got {got}",
            route.n_features
        ));
    }
    Ok(())
}

/// Shared artifact-loading step for [`Fleet::register_from_artifact`] /
/// [`Fleet::swap_to_digest`]: digest-verified load, then a derived
/// [`ModelConfig`] when the caller passed none — the manifest's shard
/// count (min 1) with the loaded program's quantizer and default
/// policy/cap/verify.
fn load_for_serving(
    store: &ArtifactStore,
    id: &str,
    cfg: Option<ModelConfig>,
) -> Result<(LoadedArtifact, ModelConfig), String> {
    let art = store
        .load(id)
        .map_err(|e| format!("loading artifact {id}: {e}"))?;
    let cfg = cfg.unwrap_or_else(|| {
        ModelConfig::for_program(&art.program).with_shards(art.manifest.n_shards.max(1))
    });
    Ok((art, cfg))
}

/// Partition `program` into [`ModelConfig::shards`] planned-execution
/// functional backends (1 = serve unpartitioned; base score then stays
/// with the single backend's own `infer`), gated by the static verifier
/// per [`ModelConfig::verify`] (contract 8). The sharded path verifies
/// the *same* partition the backends are built from — one `partition`
/// call, no verify/serve divergence window. With
/// [`ModelConfig::compress`] set, the capacity-compression pass runs
/// first (contract 11: bit-identical serving), and the compressed
/// program is what gets verified (V7) and deployed.
fn verified_shards(
    program: &CamProgram,
    cfg: &ModelConfig,
) -> Result<(Vec<Box<dyn Backend>>, Vec<f32>), String> {
    let compressed;
    let program = if cfg.compress && program.layouts.is_none() {
        let mut p = program.clone();
        crate::compiler::compress_program(&mut p);
        compressed = p;
        &compressed
    } else {
        program
    };
    let gate = cfg.verify != VerifyPolicy::Skip;
    if cfg.shards <= 1 {
        if gate {
            refuse_blocked(program, cfg.verify, analysis::verify_program(program))?;
        }
        return Ok((vec![Box::new(FunctionalBackend::new(program))], Vec::new()));
    }
    let plan = partition(program, cfg.shards, &PartitionOptions::default()).map_err(|e| {
        format!("partitioning `{}` into {} shards: {e}", program.name, cfg.shards)
    })?;
    if gate {
        let mut report = analysis::verify_program(program);
        report.merge(analysis::verify_shard_plan(program, &plan));
        refuse_blocked(program, cfg.verify, report)?;
    }
    let backends = plan
        .shards
        .iter()
        .map(|s| Box::new(FunctionalBackend::new(s)) as Box<dyn Backend>)
        .collect();
    Ok((backends, plan.base_score))
}

/// Contract 8 refusal diagnostic: the worst blocking finding by rule,
/// location and message, plus the report's finding totals.
fn refuse_blocked(
    program: &CamProgram,
    policy: VerifyPolicy,
    report: AnalysisReport,
) -> Result<(), String> {
    match policy.blocks(&report) {
        Some(f) => Err(format!(
            "static verifier refused `{}` ({} deny, {} warn): {f}",
            program.name,
            report.deny_count(),
            report.warn_count()
        )),
        None => Ok(()),
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CamEngine, CompileOptions};
    use crate::coordinator::backend::FunctionalBackend;
    use crate::data::by_name;
    use crate::trees::{gbdt, GbdtParams};

    fn add_model(
        fleet: &Fleet,
        dataset: &str,
    ) -> (crate::data::Dataset, crate::trees::Ensemble) {
        let d = by_name(dataset).unwrap().generate_n(600);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 5, max_leaves: 8, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        fleet
            .register(
                dataset,
                p.quantizer.clone(),
                Box::new(FunctionalBackend::new(&p)),
                BatchPolicy::default(),
            )
            .unwrap();
        (d, m)
    }

    #[test]
    fn routes_multiple_models() {
        let fleet = Fleet::new();
        let (d1, m1) = add_model(&fleet, "churn");
        let (d2, m2) = add_model(&fleet, "telco");
        assert_eq!(fleet.models(), vec!["churn".to_string(), "telco".to_string()]);
        for i in 0..20 {
            let r1 = fleet.infer("churn", d1.row(i)).unwrap();
            assert_eq!(r1.prediction, m1.predict(d1.row(i)));
            let r2 = fleet.infer("telco", d2.row(i)).unwrap();
            assert_eq!(r2.prediction, m2.predict(d2.row(i)));
        }
        let stats = fleet.stats();
        assert_eq!(stats.models.len(), 2);
        assert!(stats.models.iter().all(|m| m.admitted == 20 && m.shed == 0));
        assert_eq!(stats.admitted, 40);
        assert_eq!(stats.shed, 0);
    }

    #[test]
    fn rejects_unknown_model_and_bad_arity() {
        let fleet = Fleet::new();
        let (d, _) = add_model(&fleet, "churn");
        assert!(fleet.infer("nope", d.row(0)).is_err());
        assert!(fleet.infer("churn", &[1.0, 2.0]).is_err());
        assert!(fleet.submit_batch("churn", &[d.row(0).to_vec(), vec![1.0]]).is_err());
        assert!(fleet.swap_program("nope", &dummy_program(), dummy_cfg()).is_err());
        assert!(fleet.unregister("nope").is_err());
    }

    fn dummy_program() -> crate::compiler::CamProgram {
        let d = by_name("churn").unwrap().generate_n(300);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 2, max_leaves: 4, ..Default::default() },
            None,
        );
        compile(&m, &CompileOptions::default()).unwrap()
    }

    fn dummy_cfg() -> ModelConfig {
        ModelConfig::for_program(&dummy_program())
    }

    /// Regression (ISSUE 5 satellite): `register` on an existing name
    /// used to `BTreeMap::insert`-overwrite the route, dropping the old
    /// `Server` without its drain. It must refuse instead, leave the old
    /// route serving, and point at `swap`.
    #[test]
    fn duplicate_register_is_an_error_and_old_route_survives() {
        let fleet = Fleet::new();
        let (d, m) = add_model(&fleet, "churn");
        let err = fleet
            .register(
                "churn",
                m.quantizer.clone(),
                Box::new(FunctionalBackend::new(
                    &compile(&m, &CompileOptions::default()).unwrap(),
                )),
                BatchPolicy::default(),
            )
            .unwrap_err();
        assert!(err.contains("swap"), "error should direct to swap: `{err}`");
        // The original route is untouched and still serves correctly.
        let r = fleet.infer("churn", d.row(0)).unwrap();
        assert_eq!(r.prediction, m.predict(d.row(0)));
    }

    /// Sharded registration through the fleet serves bit-identically to
    /// the unsharded engine, and the per-model stats expose the pool.
    #[test]
    fn register_program_sharded_matches_reference() {
        let d = by_name("telco").unwrap().generate_n(800);
        let m = gbdt::train(
            &d,
            &GbdtParams { n_rounds: 12, max_leaves: 16, ..Default::default() },
            None,
        );
        let p = compile(&m, &CompileOptions::default()).unwrap();
        let reference = CamEngine::new(&p);
        let fleet = Fleet::new();
        fleet
            .register_program("telco", &p, ModelConfig::for_program(&p).with_shards(3))
            .unwrap();
        let rows: Vec<Vec<f32>> = (0..24).map(|i| d.row(i).to_vec()).collect();
        for (i, reply) in fleet.infer_batch("telco", &rows).unwrap().into_iter().enumerate() {
            let reply = reply.unwrap();
            assert_eq!(
                reply.logits,
                reference.infer_bins(&p.quantizer.bin_row(&rows[i])),
                "row {i}"
            );
        }
        let s = fleet.model_stats("telco").unwrap();
        assert_eq!(s.shards, 3);
        assert_eq!(s.admitted, 24);
        assert_eq!(s.served, 24);
        assert_eq!(s.errors, 0);
        assert_eq!(s.queue_depth, 0, "all replies delivered → queue empty");
        assert_eq!(s.shard_stats.len(), 3);
        assert!(s.latency.is_some());
    }

    /// Regression: the server reports backend failures via an error
    /// `Reply` (it no longer hangs up), so `Fleet::infer` must fold
    /// that into its `Err` arm rather than returning an `Ok` carrying
    /// NaN/empty logits.
    #[test]
    fn backend_failure_surfaces_as_err() {
        struct FailingBackend;
        impl Backend for FailingBackend {
            fn name(&self) -> &'static str {
                "always-fails"
            }
            fn max_batch(&self) -> usize {
                8
            }
            fn task(&self) -> crate::data::Task {
                crate::data::Task::Binary
            }
            fn infer(&mut self, _batch: &[Vec<u16>]) -> anyhow::Result<Vec<Vec<f32>>> {
                Err(anyhow::anyhow!("injected fault"))
            }
        }
        let fleet = Fleet::new();
        fleet
            .register(
                "flaky",
                FeatureQuantizer { n_bits: 1, edges: vec![vec![0.5]] },
                Box::new(FailingBackend),
                BatchPolicy::default(),
            )
            .unwrap();
        let err = fleet.infer("flaky", &[0.3]).unwrap_err();
        assert!(err.contains("injected fault"), "got `{err}`");
        let s = fleet.model_stats("flaky").unwrap();
        assert_eq!(s.errors, 1);
        assert_eq!(s.served, 0);
    }
}
