//! X-TIME command-line interface.
//!
//! Subcommands:
//!   train     — train a Table II model on its synthetic dataset
//!   compile   — compile a trained model to a CAM program
//!   verify    — static verifier: lint a compiled program (rules V1–V6)
//!               without executing a query; `--json` for the report
//!   simulate  — run the cycle-detailed chip simulation
//!   serve     — demo serving loop (XLA artifact or functional backend),
//!               or a multi-tenant fleet with `--models a,b,c`; add
//!               `--listen ADDR` to expose the fleet on framed TCP
//!   loadgen   — open-loop wire load generator against a `serve --listen`
//!               endpoint; writes BENCH_serving.json
//!   export    — publish a compiled program (and optional shard plan)
//!               into a content-addressed artifact store
//!   import    — load + verify an artifact back out of the store,
//!               optionally proving bit-identity against the original
//!   store     — artifact store maintenance: `store ls`, `store gc`
//!   report    — print the Fig. 8 area/power breakdown
//!
//! Example:
//!   xtime train --dataset churn --trees 64 --out /tmp/churn.model.json
//!   xtime compile --model /tmp/churn.model.json --out /tmp/churn.cam.json
//!   xtime verify --program /tmp/churn.cam.json --shards 2 --json
//!   xtime export --program /tmp/churn.cam.json --shards 2 --store /tmp/store
//!   xtime import --name churn --store /tmp/store --check-against /tmp/churn.cam.json
//!   xtime store ls --store /tmp/store
//!   xtime serve --models churn --store /tmp/store --listen 127.0.0.1:7711
//!   xtime simulate --program /tmp/churn.cam.json --samples 100000
//!   xtime serve --program /tmp/churn.cam.json --requests 1000
//!   xtime serve --models churn,telco,gas --shards 2 --requests 6000
//!   xtime serve --models churn,telco --listen 127.0.0.1:7711 --duration-s 30
//!   xtime loadgen --addr 127.0.0.1:7711 --tenants churn,telco --requests 5000

use std::path::Path;
use std::sync::Arc;
use xtime::bench_support::{drive_skewed_mix, fleet_table, MixTenant};
use xtime::artifact::{export_program, ArtifactStore};
use xtime::cam::DefectSpec;
use xtime::compiler::{compile, partition, CamEngine, CamProgram, CompileOptions, PartitionOptions};
use xtime::coordinator::{BatchPolicy, Fleet, FunctionalBackend, ModelConfig, Server, XlaBackend};
use xtime::data::{by_name, catalog};
use xtime::runtime::XlaCamEngine;
use xtime::serve::loadgen::{self, LoadgenConfig, TenantSpec};
use xtime::serve::{WireServer, WIRE_VERSION};
use xtime::sim::{chip_area, chip_peak_power, simulate, ChipConfig, Workload};
use xtime::trees::{gbdt, paper_model, train_paper_model, Ensemble, GbdtParams};
use xtime::util::stats::{fmt_si_rate, fmt_si_time, percentile_sorted};
use xtime::util::Args;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!(
            "usage: xtime <train|compile|verify|simulate|serve|loadgen|export|import|store|report> [options]"
        );
        eprintln!("datasets: {}", catalog().iter().map(|s| s.name).collect::<Vec<_>>().join(", "));
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    match cmd.as_str() {
        "train" => cmd_train(&argv),
        "compile" => cmd_compile(&argv),
        "verify" => cmd_verify(&argv),
        "simulate" => cmd_simulate(&argv),
        "serve" => cmd_serve(&argv),
        "loadgen" => cmd_loadgen(&argv),
        "export" => cmd_export(&argv),
        "import" => cmd_import(&argv),
        "store" => cmd_store(&argv),
        "report" => cmd_report(),
        other => {
            eprintln!("unknown command `{other}`");
            std::process::exit(2);
        }
    }
}

fn parse(args: Args, argv: &[String]) -> Args {
    match args.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

fn cmd_train(argv: &[String]) {
    let a = parse(
        Args::new("xtime train", "train a Table II model on its synthetic dataset")
            .opt("dataset", Some("churn"), "dataset name (see Table II)")
            .opt("trees", Some("0"), "tree count override (0 = paper topology)")
            .opt("bits", Some("8"), "feature quantization bits (4/8)")
            .opt("samples", Some("0"), "training rows (0 = catalog default)")
            .opt("out", None, "output model JSON path"),
        argv,
    );
    let name = a.get("dataset");
    let spec = by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown dataset `{name}`");
        std::process::exit(2);
    });
    let n = a.get_usize("samples");
    let data = if n == 0 { spec.generate() } else { spec.generate_n(n) };
    let model_spec = paper_model(&name).unwrap();
    let trees = a.get_usize("trees");
    let model = train_paper_model(
        &data,
        &model_spec,
        a.get_usize("bits") as u8,
        model_spec.n_leaves_max,
        if trees == 0 { None } else { Some(trees) },
    );
    let out = a.get("out");
    model.save(Path::new(&out)).expect("writing model");
    println!(
        "trained {} ({}): {} trees, max {} leaves, depth {} → {out}",
        name,
        model_spec.kind.name(),
        model.n_trees(),
        model.max_leaves(),
        model.max_depth()
    );
}

fn load_model(path: &str) -> Ensemble {
    Ensemble::load(Path::new(path)).unwrap_or_else(|e| {
        eprintln!("loading model: {e}");
        std::process::exit(2);
    })
}

fn cmd_compile(argv: &[String]) {
    let a = parse(
        Args::new("xtime compile", "compile a trained model to a CAM program")
            .opt("model", None, "input model JSON")
            .opt("replicas", Some("1"), "batch replicas (0 = fill the chip)")
            .opt("out", None, "output program JSON")
            .flag(
                "compress",
                "run the sparsity-aware capacity-compression pass (bit-identical, contract 11)",
            ),
        argv,
    );
    let model = load_model(&a.get("model"));
    let opts = CompileOptions {
        replicas: a.get_usize("replicas"),
        compress: a.get_flag("compress"),
        ..Default::default()
    };
    let program = compile(&model, &opts).unwrap_or_else(|e| {
        eprintln!("compile error: {e}");
        std::process::exit(2);
    });
    let out = a.get("out");
    program.save(Path::new(&out)).expect("writing program");
    let rows = if program.layouts.is_some() {
        format!(
            "{} rows in {} physical words ({:.2}×)",
            program.total_rows(),
            program.total_phys_rows(),
            program.total_rows() as f64 / program.total_phys_rows().max(1) as f64
        )
    } else {
        format!("{} rows", program.total_rows())
    };
    println!(
        "compiled {}: {} cores/replica × {} replicas, {rows}, {} routers ({} accumulating) → {out}",
        program.name,
        program.cores_per_replica(),
        program.n_replicas,
        program.noc.n_routers(),
        program.noc.n_accumulating(),
    );
}

fn load_program(path: &str) -> CamProgram {
    CamProgram::load(Path::new(path)).unwrap_or_else(|e| {
        eprintln!("loading program: {e}");
        std::process::exit(2);
    })
}

fn cmd_verify(argv: &[String]) {
    let a = parse(
        Args::new("xtime verify", "static verifier: lint a compiled CAM program (rules V1-V7)")
            .opt("program", None, "compiled CAM program JSON")
            .opt("shards", Some("1"), "also verify an n-shard partition (rule V3)")
            .opt("defect-pct", Some("0"), "lint under a memristor defect draw (rule V5)")
            .opt("seed", Some("7"), "defect-draw seed")
            .opt("out", Some(""), "also write the JSON report to this path")
            .flag("json", "print the machine-readable report instead of the table"),
        argv,
    );
    let program = load_program(&a.get("program"));
    let defects = DefectSpec::memristor(a.get_f64("defect-pct"));
    let report =
        xtime::analysis::verify_deployment(&program, a.get_usize("shards"), defects, a.get_u64("seed"));
    let json = report.to_json().to_string();
    if a.get_flag("json") {
        println!("{json}");
    } else {
        print!("{}", report.render());
    }
    let out = a.get("out");
    if !out.is_empty() {
        std::fs::write(Path::new(&out), &json).expect("writing report");
    }
    // Exit contract mirrors the fleet gate (contract 8): deny findings
    // fail the invocation so CI can gate on the exit code alone.
    if !report.is_clean() {
        std::process::exit(1);
    }
}

fn cmd_simulate(argv: &[String]) {
    let a = parse(
        Args::new("xtime simulate", "cycle-detailed chip simulation")
            .opt("program", None, "compiled CAM program JSON")
            .opt("samples", Some("100000"), "samples to stream")
            .opt("interval", Some("0"), "inject interval in cycles (0 = saturate)"),
        argv,
    );
    let program = load_program(&a.get("program"));
    let cfg = ChipConfig::default();
    let wl = Workload { n_samples: a.get_usize("samples"), inject_interval: a.get_u64("interval") };
    let rep = simulate(&program, &cfg, &wl, 0.05);
    println!("samples           : {}", rep.n_samples);
    println!("makespan          : {} cycles", rep.makespan_cycles);
    println!("latency (unloaded): {}", fmt_si_time(rep.latency_ns.min * 1e-9));
    println!("latency (mean)    : {}", fmt_si_time(rep.latency_ns.mean * 1e-9));
    println!("throughput        : {}", fmt_si_rate(rep.throughput_msps * 1e6, "Samples"));
    println!("energy/decision   : {:.3} nJ", rep.energy_nj_per_decision);
    println!("bottleneck        : {}", rep.bottleneck);
    println!(
        "utilization       : input {:.2} core {:.2} output {:.2} cp {:.2}",
        rep.util_input, rep.util_core, rep.util_output, rep.util_cp
    );
}

fn cmd_serve(argv: &[String]) {
    let a = parse(
        Args::new("xtime serve", "demo serving loop over synthetic requests")
            .opt("program", Some(""), "compiled CAM program JSON (single-model mode)")
            .opt("models", Some(""), "comma-separated dataset names → multi-tenant fleet mode")
            .opt(
                "store",
                Some(""),
                "fleet mode: cold-start each model from this artifact store \
                 (latest published artifact per name) instead of training in-process",
            )
            .opt("requests", Some("1000"), "number of requests")
            .opt("backend", Some("auto"), "auto | xla | functional")
            .opt("artifacts", Some("artifacts"), "AOT artifact directory")
            .opt("shards", Some("1"), "fleet mode: shard programs (virtual cards) per model")
            .opt("queue-cap", Some("1024"), "fleet mode: per-model admission bound (0 = unbounded)")
            .opt(
                "threads",
                Some("1"),
                "fleet mode: planned-execution workers per backend (0 = auto)",
            )
            .opt(
                "listen",
                Some(""),
                "fleet mode: expose the fleet on framed TCP at this address \
                 (e.g. 127.0.0.1:7711) instead of driving a local mix",
            )
            .opt(
                "duration-s",
                Some("30"),
                "with --listen: seconds to serve before draining (0 = forever)",
            )
            .flag(
                "compress",
                "fleet mode: capacity-compress each model at registration (bit-identical)",
            ),
        argv,
    );
    if !a.get("models").is_empty() {
        return cmd_serve_fleet(&a);
    }
    if a.get("program").is_empty() {
        eprintln!("serve needs --program <file> (single-model) or --models <a,b,c> (fleet)");
        std::process::exit(2);
    }
    let program = load_program(&a.get("program"));
    let n = a.get_usize("requests");
    let Some(spec) = by_name(&program.name) else {
        eprintln!("program's dataset `{}` not in catalog", program.name);
        std::process::exit(2);
    };
    let data = spec.generate_n(n.clamp(256, 10_000));

    let backend_kind = a.get("backend");
    let artifacts = a.get("artifacts");
    let backend: Box<dyn xtime::coordinator::Backend> = match backend_kind.as_str() {
        "functional" => {
            println!("backend: cam-functional");
            Box::new(FunctionalBackend::new(&program))
        }
        _ => match XlaCamEngine::new(&program, Path::new(&artifacts), 64) {
            Ok(engine) => {
                println!("backend: xla-aot (bucket {})", engine.bucket().file);
                Box::new(XlaBackend { engine })
            }
            Err(e) if backend_kind == "auto" => {
                println!("backend: cam-functional (XLA unavailable: {e})");
                Box::new(FunctionalBackend::new(&program))
            }
            Err(e) => {
                eprintln!("XLA backend: {e:#}");
                std::process::exit(2);
            }
        },
    };

    let server = Server::start(backend, BatchPolicy::default(), program.n_features);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..n {
        pending.push(server.submit(program.quantizer.bin_row(data.row(i % data.n_rows()))));
    }
    let mut preds = 0usize;
    for rx in pending {
        let _ = rx.recv().expect("reply");
        preds += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    let lat = server.latency_summary().unwrap();
    println!("served {preds} requests in {}", fmt_si_time(dt));
    println!("throughput : {}", fmt_si_rate(preds as f64 / dt, "req"));
    println!(
        "latency    : p50 {} p95 {} max {}",
        fmt_si_time(lat.median),
        fmt_si_time(lat.p95),
        fmt_si_time(lat.max)
    );
    println!("batching   : {} batches, mean size {:.1}", stats.batches, stats.mean_batch);
}

/// Multi-tenant fleet mode (`xtime serve --models churn,telco,gas`):
/// trains one small model per named catalog dataset in-process — or,
/// with `--store DIR`, cold-starts each from its latest published
/// artifact via [`Fleet::register_from_artifact`] (digest-verified,
/// verifier-gated; contract 9) — registers each as a sharded route with
/// a bounded admission queue, drives a skewed load mix across the
/// tenants, and prints the per-model fleet table (§III-D "a different
/// batch to each model").
fn cmd_serve_fleet(a: &Args) {
    let names: Vec<String> = a
        .get("models")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        eprintln!("--models needs at least one dataset name");
        std::process::exit(2);
    }
    let shards = a.get_usize("shards").max(1);
    let queue_cap = a.get_usize("queue-cap");
    let threads = a.get_usize("threads");
    let n_requests = a.get_usize("requests");

    let store_dir = a.get("store");
    let store = if store_dir.is_empty() { None } else { Some(open_store(&store_dir)) };

    let fleet = Fleet::new();
    let mut datasets = Vec::new();
    println!(
        "building fleet: {} model(s) × {shards} shard(s) each, queue cap {}{}",
        names.len(),
        if queue_cap == 0 { "∞".to_string() } else { queue_cap.to_string() },
        if store.is_some() { format!(", cold-start from {store_dir}") } else { String::new() }
    );
    for name in &names {
        let Some(spec) = by_name(name) else {
            eprintln!(
                "unknown dataset `{name}`; catalog: {}",
                catalog().iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
            );
            std::process::exit(2);
        };
        let data = spec.generate_n(2_000);
        let policy = BatchPolicy { max_wait_us: 200, max_batch: 0, threads: Some(threads) };
        if let Some(store) = &store {
            // Cold start: digest-verified load + the same verifier gate
            // as a fresh registration (contract 9).
            let id = store.resolve(name).unwrap_or_else(|e| {
                eprintln!("resolving `{name}` in {store_dir}: {e}");
                std::process::exit(2);
            });
            let art = store.load(&id).unwrap_or_else(|e| {
                eprintln!("loading `{name}` ({id}): {e}");
                std::process::exit(2);
            });
            // --shards 1 (the default) replays the shard count recorded
            // in the artifact; an explicit larger value overrides it.
            let eff_shards = if shards > 1 { shards } else { art.manifest.n_shards.max(1) };
            let cfg = ModelConfig::for_program(&art.program)
                .with_shards(eff_shards)
                .with_policy(policy)
                .with_queue_cap(queue_cap)
                .with_compress(a.get_flag("compress"));
            fleet.register_from_artifact(name, store, &id, Some(cfg)).unwrap_or_else(|e| {
                eprintln!("registering `{name}`: {e}");
                std::process::exit(2);
            });
            println!(
                "  {name}: artifact {} — {} trees, {} CAM rows → {eff_shards} shard(s)",
                &id[..12.min(id.len())],
                art.program.n_trees,
                art.program.total_rows(),
            );
        } else {
            let model = gbdt::train(
                &data,
                &GbdtParams { n_rounds: 16, max_leaves: 32, ..Default::default() },
                None,
            );
            let program = compile(&model, &CompileOptions::default()).unwrap_or_else(|e| {
                eprintln!("compiling `{name}`: {e}");
                std::process::exit(2);
            });
            let cfg = ModelConfig::for_program(&program)
                .with_shards(shards)
                .with_policy(policy)
                .with_queue_cap(queue_cap)
                .with_compress(a.get_flag("compress"));
            fleet.register_program(name, &program, cfg).unwrap_or_else(|e| {
                eprintln!("registering `{name}`: {e}");
                std::process::exit(2);
            });
            println!(
                "  {name}: {} trees, {} CAM rows → {shards} shard(s)",
                program.n_trees,
                program.total_rows(),
            );
        }
        datasets.push(data);
    }

    let listen = a.get("listen");
    if !listen.is_empty() {
        return serve_wire(fleet, &listen, a.get_u64("duration-s"));
    }

    // Skewed tenant mix (weights 2^(k-1) … 1): the first model is the
    // hot tenant, the last the cold one.
    let tenants: Vec<MixTenant> = names
        .iter()
        .zip(&datasets)
        .enumerate()
        .map(|(i, (name, data))| MixTenant {
            name: name.as_str(),
            data,
            weight: 1usize << (names.len() - 1 - i),
        })
        .collect();
    let mix = drive_skewed_mix(&fleet, &tenants, n_requests, 7).unwrap_or_else(|e| {
        eprintln!("submit failed: {e}");
        std::process::exit(2);
    });

    fleet_table(&fleet.stats()).print(&format!(
        "fleet serving — {n_requests} requests in {} (mix {})",
        fmt_si_time(mix.wall_s),
        tenants.iter().map(|t| t.weight.to_string()).collect::<Vec<_>>().join(":")
    ));
    println!("throughput : {}", fmt_si_rate(mix.served as f64 / mix.wall_s, "req"));
    println!(
        "admission  : {} served, {} shed, {} errored (every request accounted)",
        mix.served, mix.shed, mix.errors
    );
    fleet.shutdown();
}

/// `xtime serve --models … --listen ADDR`: expose the built fleet on
/// framed TCP for `--duration-s` seconds (0 = until killed), then drain
/// cleanly — wire handlers first, then every route's server.
fn serve_wire(fleet: Fleet, addr: &str, duration_s: u64) {
    let fleet = Arc::new(fleet);
    let server = WireServer::start(fleet.clone(), addr).unwrap_or_else(|e| {
        eprintln!("binding {addr}: {e}");
        std::process::exit(2);
    });
    println!(
        "listening on {} (wire protocol v{WIRE_VERSION}, {})",
        server.local_addr(),
        if duration_s == 0 {
            "until killed".to_string()
        } else {
            format!("{duration_s}s")
        }
    );
    if duration_s == 0 {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(duration_s));
    let ws = server.stats();
    server.shutdown(); // joins accept loop + all connection handlers
    println!(
        "wire: {} connection(s), {} frame(s), rows offered {} = admitted {} + shed {} \
         (decoded {}), {} rejected frame(s), {} protocol error(s)",
        ws.connections,
        ws.frames,
        ws.rows_offered,
        ws.rows_admitted,
        ws.rows_shed,
        ws.rows_decoded,
        ws.rejected_frames,
        ws.protocol_errors,
    );
    fleet_table(&fleet.stats()).print("fleet after wire serving");
    match Arc::try_unwrap(fleet) {
        Ok(fleet) => fleet.shutdown(), // drain: every admitted row answered
        Err(_) => eprintln!("warning: fleet still shared at exit; skipping drain"),
    }
}

/// `xtime loadgen`: open-loop Poisson load against a `serve --listen`
/// endpoint; prints per-tenant accounting and writes BENCH_serving.json.
fn cmd_loadgen(argv: &[String]) {
    let a = parse(
        Args::new("xtime loadgen", "open-loop wire load generator (writes BENCH_serving.json)")
            .opt("addr", Some("127.0.0.1:7711"), "serve --listen address")
            .opt(
                "tenants",
                Some("churn,telco"),
                "comma-separated tenant names; must match the server's --models",
            )
            .opt("requests", Some("5000"), "total requests across all connections")
            .opt("rate", Some("2000"), "aggregate arrival rate, req/s (0 = unpaced)")
            .opt("conns", Some("8"), "concurrent worker connections")
            .opt("batch", Some("4"), "rows per request frame")
            .opt("churn", Some("200"), "reconnect each worker every N requests (0 = never)")
            .opt("rows", Some("256"), "distinct synthetic rows per tenant")
            .opt("seed", Some("7"), "RNG seed (arrivals + tenant mix)"),
        argv,
    );
    let names: Vec<String> = a
        .get("tenants")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        eprintln!("--tenants needs at least one dataset name");
        std::process::exit(2);
    }
    // Same skewed weights (2^(k-1) … 1) as `serve --models`, so the
    // hot/cold tenant split matches what the server prints.
    let n_rows = a.get_usize("rows").max(1);
    let tenants: Vec<TenantSpec> = names
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let Some(spec) = by_name(name) else {
                eprintln!(
                    "unknown dataset `{name}`; catalog: {}",
                    catalog().iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
                );
                std::process::exit(2);
            };
            let data = spec.generate_n(n_rows);
            TenantSpec {
                name: name.clone(),
                rows: (0..data.n_rows()).map(|r| data.row(r).to_vec()).collect(),
                weight: 1usize << (names.len() - 1 - i),
            }
        })
        .collect();
    let cfg = LoadgenConfig {
        addr: a.get("addr"),
        tenants,
        requests: a.get_usize("requests"),
        rate_rps: a.get_f64("rate"),
        conns: a.get_usize("conns").max(1),
        batch: a.get_usize("batch").max(1),
        churn_every: a.get_usize("churn"),
        seed: a.get_u64("seed"),
    };
    println!(
        "loadgen → {}: {} requests × {} row(s), {} conn(s), rate {} req/s, churn every {}",
        cfg.addr, cfg.requests, cfg.batch, cfg.conns, cfg.rate_rps, cfg.churn_every
    );
    let report = loadgen::run(&cfg).unwrap_or_else(|e| {
        eprintln!("loadgen: {e}");
        std::process::exit(2);
    });
    for (name, o) in &report.tenants {
        let mut lat = o.latencies.clone();
        lat.sort_by(f64::total_cmp);
        let q = |p: f64| {
            if lat.is_empty() {
                "-".to_string()
            } else {
                fmt_si_time(percentile_sorted(&lat, p))
            }
        };
        println!(
            "  {name:<12} offered {:>8} served {:>8} shed {:>8} ({:>5.1}%) failed {:>6} | \
             p50 {} p99 {} p999 {}",
            o.offered_rows,
            o.served_rows,
            o.shed_rows,
            100.0 * o.shed_rate(),
            o.failed_rows,
            q(50.0),
            q(99.0),
            q(99.9),
        );
    }
    let totals = report.totals();
    println!(
        "total: {} rows in {} → {}, shed rate {:.1}%, {} transport error(s)",
        totals.offered_rows,
        fmt_si_time(report.wall_s),
        fmt_si_rate(totals.offered_rows as f64 / report.wall_s.max(1e-9), "rows"),
        100.0 * totals.shed_rate(),
        report.request_errors,
    );
    xtime::bench_support::write_bench_json("serving", &loadgen::report_json(&cfg, &report));
}

fn open_store(dir: &str) -> ArtifactStore {
    ArtifactStore::open(Path::new(dir)).unwrap_or_else(|e| {
        eprintln!("opening store {dir}: {e}");
        std::process::exit(2);
    })
}

/// `xtime export`: publish a compiled program into the content-addressed
/// store. With `--shards N` the artifact also carries the N-way shard
/// plan, so an importer can replay the exact partition.
fn cmd_export(argv: &[String]) {
    let a = parse(
        Args::new("xtime export", "publish a compiled CAM program into an artifact store")
            .opt("program", None, "compiled CAM program JSON")
            .opt("shards", Some("0"), "also embed an n-shard plan (0 = program only)")
            .opt("store", Some(".xtime-store"), "artifact store directory"),
        argv,
    );
    let program = load_program(&a.get("program"));
    let shards = a.get_usize("shards");
    let plan = if shards > 1 {
        Some(partition(&program, shards, &PartitionOptions::default()).unwrap_or_else(|e| {
            eprintln!("partitioning into {shards} shards: {e}");
            std::process::exit(2);
        }))
    } else {
        None
    };
    let mut store = open_store(&a.get("store"));
    let id = export_program(&mut store, &program, plan.as_ref()).unwrap_or_else(|e| {
        eprintln!("export: {e}");
        std::process::exit(2);
    });
    println!(
        "exported {} ({} trees, {} rows{}) → {}",
        program.name,
        program.n_trees,
        program.total_rows(),
        if shards > 1 { format!(", {shards}-shard plan") } else { String::new() },
        id
    );
}

/// `xtime import`: digest-verified load of an artifact, gated by the
/// static verifier (nonzero exit on deny findings, mirroring `xtime
/// verify` and the fleet gate). `--check-against` additionally proves
/// the loaded program serves bit-identically to an original program
/// file — the contract 9 demonstration on the command line.
fn cmd_import(argv: &[String]) {
    let a = parse(
        Args::new("xtime import", "load + verify an artifact from a store")
            .opt("store", Some(".xtime-store"), "artifact store directory")
            .opt("digest", Some(""), "artifact id (sha256 hex)")
            .opt("name", Some(""), "model name → latest published artifact")
            .opt("out", Some(""), "write the imported program JSON here")
            .opt("check-against", Some(""), "original program JSON to prove bit-identity against")
            .opt("queries", Some("256"), "random queries for the bit-identity check")
            .opt("seed", Some("7"), "query-draw seed"),
        argv,
    );
    let store = open_store(&a.get("store"));
    let digest = a.get("digest");
    let id = if !digest.is_empty() {
        digest
    } else {
        let name = a.get("name");
        if name.is_empty() {
            eprintln!("import needs --digest <id> or --name <model>");
            std::process::exit(2);
        }
        store.resolve(&name).unwrap_or_else(|e| {
            eprintln!("resolve: {e}");
            std::process::exit(2);
        })
    };
    let art = store.load(&id).unwrap_or_else(|e| {
        eprintln!("load: {e}");
        std::process::exit(2);
    });
    let mut report = xtime::analysis::verify_program(&art.program);
    if let Some(plan) = &art.plan {
        report.merge(xtime::analysis::verify_shard_plan(&art.program, plan));
    }
    println!(
        "loaded {} from {} ({} trees, {} rows, {} shard(s)) — verifier: {} deny, {} warn",
        art.program.name,
        &id[..12.min(id.len())],
        art.program.n_trees,
        art.program.total_rows(),
        art.manifest.n_shards.max(1),
        report.deny_count(),
        report.warn_count(),
    );
    let out = a.get("out");
    if !out.is_empty() {
        art.program.save(Path::new(&out)).expect("writing program");
        println!("wrote {out}");
    }
    let original = a.get("check-against");
    if !original.is_empty() {
        let orig = load_program(&original);
        let queries = xtime::bench_support::random_query_bins(
            &orig,
            a.get_usize("queries").max(1),
            a.get_u64("seed"),
        );
        let a_logits = CamEngine::new(&orig).infer_batch(&queries);
        let b_logits = CamEngine::new(&art.program).infer_batch(&queries);
        let identical = a_logits.len() == b_logits.len()
            && a_logits.iter().zip(&b_logits).all(|(x, y)| {
                x.len() == y.len()
                    && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
            });
        if identical {
            println!("bit-identity: OK ({} queries, every logit bit-equal)", queries.len());
        } else {
            eprintln!("bit-identity: FAILED — imported program diverges from {original}");
            std::process::exit(1);
        }
    }
    if !report.is_clean() {
        std::process::exit(1);
    }
}

/// `xtime store ls|gc`: artifact store maintenance.
fn cmd_store(argv: &[String]) {
    let Some(sub) = argv.first().map(String::as_str) else {
        eprintln!("usage: xtime store <ls|gc> --store <dir>");
        std::process::exit(2);
    };
    let rest = &argv[1..];
    let a = parse(
        Args::new("xtime store", "artifact store maintenance (ls, gc)")
            .opt("store", Some(".xtime-store"), "artifact store directory"),
        rest,
    );
    let mut store = open_store(&a.get("store"));
    match sub {
        "ls" => {
            let entries = store.ls();
            if entries.is_empty() {
                println!("store {} is empty", store.root().display());
                return;
            }
            println!("{:<12} {:<16} {:>6} {:>6} {:>5} {:>4}", "ID", "NAME", "SEQ", "SHARDS", "TREES", "BITS");
            for e in entries {
                println!(
                    "{:<12} {:<16} {:>6} {:>6} {:>5} {:>4}",
                    &e.id[..12.min(e.id.len())],
                    e.name,
                    e.seq,
                    e.n_shards,
                    e.n_trees,
                    e.n_bits
                );
            }
        }
        "gc" => {
            let r = store.gc().unwrap_or_else(|e| {
                eprintln!("gc: {e}");
                std::process::exit(2);
            });
            println!(
                "gc: kept {} blob(s), removed {} blob(s) + {} manifest(s), freed {} byte(s)",
                r.kept_blobs, r.removed_blobs, r.removed_manifests, r.bytes_freed
            );
        }
        other => {
            eprintln!("unknown store subcommand `{other}` (expected ls or gc)");
            std::process::exit(2);
        }
    }
}

fn cmd_report() {
    let cfg = ChipConfig::default();
    let area = chip_area(&cfg);
    let power = chip_peak_power(&cfg);
    println!("X-TIME chip @16nm, {} cores, {:.1} GHz", cfg.n_cores, cfg.clock_ghz);
    println!("\nArea breakdown (Fig. 8a):");
    for (name, v) in area.rows("mm²") {
        println!("  {name:<24} {v:>8.2}");
    }
    println!("  {:<24} {:>8.2}", "TOTAL (mm²)", area.total());
    println!("\nPeak power breakdown (Fig. 8b):");
    for (name, v) in power.rows("W") {
        println!("  {name:<24} {v:>8.2}");
    }
    println!("  {:<24} {:>8.2}", "TOTAL (W)", power.total());
}
