//! X-TIME command-line interface.
//!
//! Subcommands:
//!   train     — train a Table II model on its synthetic dataset
//!   compile   — compile a trained model to a CAM program
//!   simulate  — run the cycle-detailed chip simulation
//!   serve     — demo serving loop (XLA artifact or functional backend),
//!               or a multi-tenant fleet with `--models a,b,c`
//!   report    — print the Fig. 8 area/power breakdown
//!
//! Example:
//!   xtime train --dataset churn --trees 64 --out /tmp/churn.model.json
//!   xtime compile --model /tmp/churn.model.json --out /tmp/churn.cam.json
//!   xtime simulate --program /tmp/churn.cam.json --samples 100000
//!   xtime serve --program /tmp/churn.cam.json --requests 1000
//!   xtime serve --models churn,telco,gas --shards 2 --requests 6000

use std::path::Path;
use xtime::bench_support::{drive_skewed_mix, fleet_table, MixTenant};
use xtime::compiler::{compile, CamProgram, CompileOptions};
use xtime::coordinator::{BatchPolicy, Fleet, FunctionalBackend, ModelConfig, Server, XlaBackend};
use xtime::data::{by_name, catalog};
use xtime::runtime::XlaCamEngine;
use xtime::sim::{chip_area, chip_peak_power, simulate, ChipConfig, Workload};
use xtime::trees::{gbdt, paper_model, train_paper_model, Ensemble, GbdtParams};
use xtime::util::stats::{fmt_si_rate, fmt_si_time};
use xtime::util::Args;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("usage: xtime <train|compile|simulate|serve|report> [options]");
        eprintln!("datasets: {}", catalog().iter().map(|s| s.name).collect::<Vec<_>>().join(", "));
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    match cmd.as_str() {
        "train" => cmd_train(&argv),
        "compile" => cmd_compile(&argv),
        "simulate" => cmd_simulate(&argv),
        "serve" => cmd_serve(&argv),
        "report" => cmd_report(),
        other => {
            eprintln!("unknown command `{other}`");
            std::process::exit(2);
        }
    }
}

fn parse(args: Args, argv: &[String]) -> Args {
    match args.parse(argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

fn cmd_train(argv: &[String]) {
    let a = parse(
        Args::new("xtime train", "train a Table II model on its synthetic dataset")
            .opt("dataset", Some("churn"), "dataset name (see Table II)")
            .opt("trees", Some("0"), "tree count override (0 = paper topology)")
            .opt("bits", Some("8"), "feature quantization bits (4/8)")
            .opt("samples", Some("0"), "training rows (0 = catalog default)")
            .opt("out", None, "output model JSON path"),
        argv,
    );
    let name = a.get("dataset");
    let spec = by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown dataset `{name}`");
        std::process::exit(2);
    });
    let n = a.get_usize("samples");
    let data = if n == 0 { spec.generate() } else { spec.generate_n(n) };
    let model_spec = paper_model(&name).unwrap();
    let trees = a.get_usize("trees");
    let model = train_paper_model(
        &data,
        &model_spec,
        a.get_usize("bits") as u8,
        model_spec.n_leaves_max,
        if trees == 0 { None } else { Some(trees) },
    );
    let out = a.get("out");
    model.save(Path::new(&out)).expect("writing model");
    println!(
        "trained {} ({}): {} trees, max {} leaves, depth {} → {out}",
        name,
        model_spec.kind.name(),
        model.n_trees(),
        model.max_leaves(),
        model.max_depth()
    );
}

fn load_model(path: &str) -> Ensemble {
    Ensemble::load(Path::new(path)).unwrap_or_else(|e| {
        eprintln!("loading model: {e}");
        std::process::exit(2);
    })
}

fn cmd_compile(argv: &[String]) {
    let a = parse(
        Args::new("xtime compile", "compile a trained model to a CAM program")
            .opt("model", None, "input model JSON")
            .opt("replicas", Some("1"), "batch replicas (0 = fill the chip)")
            .opt("out", None, "output program JSON"),
        argv,
    );
    let model = load_model(&a.get("model"));
    let opts = CompileOptions { replicas: a.get_usize("replicas"), ..Default::default() };
    let program = compile(&model, &opts).unwrap_or_else(|e| {
        eprintln!("compile error: {e}");
        std::process::exit(2);
    });
    let out = a.get("out");
    program.save(Path::new(&out)).expect("writing program");
    println!(
        "compiled {}: {} cores/replica × {} replicas, {} rows, {} routers ({} accumulating) → {out}",
        program.name,
        program.cores_per_replica(),
        program.n_replicas,
        program.total_rows(),
        program.noc.n_routers(),
        program.noc.n_accumulating(),
    );
}

fn load_program(path: &str) -> CamProgram {
    CamProgram::load(Path::new(path)).unwrap_or_else(|e| {
        eprintln!("loading program: {e}");
        std::process::exit(2);
    })
}

fn cmd_simulate(argv: &[String]) {
    let a = parse(
        Args::new("xtime simulate", "cycle-detailed chip simulation")
            .opt("program", None, "compiled CAM program JSON")
            .opt("samples", Some("100000"), "samples to stream")
            .opt("interval", Some("0"), "inject interval in cycles (0 = saturate)"),
        argv,
    );
    let program = load_program(&a.get("program"));
    let cfg = ChipConfig::default();
    let wl = Workload { n_samples: a.get_usize("samples"), inject_interval: a.get_u64("interval") };
    let rep = simulate(&program, &cfg, &wl, 0.05);
    println!("samples           : {}", rep.n_samples);
    println!("makespan          : {} cycles", rep.makespan_cycles);
    println!("latency (unloaded): {}", fmt_si_time(rep.latency_ns.min * 1e-9));
    println!("latency (mean)    : {}", fmt_si_time(rep.latency_ns.mean * 1e-9));
    println!("throughput        : {}", fmt_si_rate(rep.throughput_msps * 1e6, "Samples"));
    println!("energy/decision   : {:.3} nJ", rep.energy_nj_per_decision);
    println!("bottleneck        : {}", rep.bottleneck);
    println!(
        "utilization       : input {:.2} core {:.2} output {:.2} cp {:.2}",
        rep.util_input, rep.util_core, rep.util_output, rep.util_cp
    );
}

fn cmd_serve(argv: &[String]) {
    let a = parse(
        Args::new("xtime serve", "demo serving loop over synthetic requests")
            .opt("program", Some(""), "compiled CAM program JSON (single-model mode)")
            .opt("models", Some(""), "comma-separated dataset names → multi-tenant fleet mode")
            .opt("requests", Some("1000"), "number of requests")
            .opt("backend", Some("auto"), "auto | xla | functional")
            .opt("artifacts", Some("artifacts"), "AOT artifact directory")
            .opt("shards", Some("1"), "fleet mode: shard programs (virtual cards) per model")
            .opt("queue-cap", Some("1024"), "fleet mode: per-model admission bound (0 = unbounded)")
            .opt(
                "threads",
                Some("1"),
                "fleet mode: planned-execution workers per backend (0 = auto)",
            ),
        argv,
    );
    if !a.get("models").is_empty() {
        return cmd_serve_fleet(&a);
    }
    if a.get("program").is_empty() {
        eprintln!("serve needs --program <file> (single-model) or --models <a,b,c> (fleet)");
        std::process::exit(2);
    }
    let program = load_program(&a.get("program"));
    let n = a.get_usize("requests");
    let Some(spec) = by_name(&program.name) else {
        eprintln!("program's dataset `{}` not in catalog", program.name);
        std::process::exit(2);
    };
    let data = spec.generate_n(n.clamp(256, 10_000));

    let backend_kind = a.get("backend");
    let artifacts = a.get("artifacts");
    let backend: Box<dyn xtime::coordinator::Backend> = match backend_kind.as_str() {
        "functional" => {
            println!("backend: cam-functional");
            Box::new(FunctionalBackend::new(&program))
        }
        _ => match XlaCamEngine::new(&program, Path::new(&artifacts), 64) {
            Ok(engine) => {
                println!("backend: xla-aot (bucket {})", engine.bucket().file);
                Box::new(XlaBackend { engine })
            }
            Err(e) if backend_kind == "auto" => {
                println!("backend: cam-functional (XLA unavailable: {e})");
                Box::new(FunctionalBackend::new(&program))
            }
            Err(e) => {
                eprintln!("XLA backend: {e:#}");
                std::process::exit(2);
            }
        },
    };

    let server = Server::start(backend, BatchPolicy::default(), program.n_features);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..n {
        pending.push(server.submit(program.quantizer.bin_row(data.row(i % data.n_rows()))));
    }
    let mut preds = 0usize;
    for rx in pending {
        let _ = rx.recv().expect("reply");
        preds += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    let lat = server.latency_summary().unwrap();
    println!("served {preds} requests in {}", fmt_si_time(dt));
    println!("throughput : {}", fmt_si_rate(preds as f64 / dt, "req"));
    println!(
        "latency    : p50 {} p95 {} max {}",
        fmt_si_time(lat.median),
        fmt_si_time(lat.p95),
        fmt_si_time(lat.max)
    );
    println!("batching   : {} batches, mean size {:.1}", stats.batches, stats.mean_batch);
}

/// Multi-tenant fleet mode (`xtime serve --models churn,telco,gas`):
/// trains one small model per named catalog dataset in-process, registers
/// each as a sharded route with a bounded admission queue, drives a
/// skewed load mix across the tenants, and prints the per-model fleet
/// table (§III-D "a different batch to each model").
fn cmd_serve_fleet(a: &Args) {
    let names: Vec<String> = a
        .get("models")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        eprintln!("--models needs at least one dataset name");
        std::process::exit(2);
    }
    let shards = a.get_usize("shards").max(1);
    let queue_cap = a.get_usize("queue-cap");
    let threads = a.get_usize("threads");
    let n_requests = a.get_usize("requests");

    let fleet = Fleet::new();
    let mut datasets = Vec::new();
    println!(
        "building fleet: {} model(s) × {shards} shard(s) each, queue cap {}",
        names.len(),
        if queue_cap == 0 { "∞".to_string() } else { queue_cap.to_string() }
    );
    for name in &names {
        let Some(spec) = by_name(name) else {
            eprintln!(
                "unknown dataset `{name}`; catalog: {}",
                catalog().iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
            );
            std::process::exit(2);
        };
        let data = spec.generate_n(2_000);
        let model = gbdt::train(
            &data,
            &GbdtParams { n_rounds: 16, max_leaves: 32, ..Default::default() },
            None,
        );
        let program = compile(&model, &CompileOptions::default()).unwrap_or_else(|e| {
            eprintln!("compiling `{name}`: {e}");
            std::process::exit(2);
        });
        let policy = BatchPolicy { max_wait_us: 200, max_batch: 0, threads: Some(threads) };
        let cfg = ModelConfig::for_program(&program)
            .with_shards(shards)
            .with_policy(policy)
            .with_queue_cap(queue_cap);
        fleet.register_program(name, &program, cfg).unwrap_or_else(|e| {
            eprintln!("registering `{name}`: {e}");
            std::process::exit(2);
        });
        println!(
            "  {name}: {} trees, {} CAM rows → {shards} shard(s)",
            program.n_trees,
            program.total_rows(),
        );
        datasets.push(data);
    }

    // Skewed tenant mix (weights 2^(k-1) … 1): the first model is the
    // hot tenant, the last the cold one.
    let tenants: Vec<MixTenant> = names
        .iter()
        .zip(&datasets)
        .enumerate()
        .map(|(i, (name, data))| MixTenant {
            name: name.as_str(),
            data,
            weight: 1usize << (names.len() - 1 - i),
        })
        .collect();
    let mix = drive_skewed_mix(&fleet, &tenants, n_requests, 7).unwrap_or_else(|e| {
        eprintln!("submit failed: {e}");
        std::process::exit(2);
    });

    fleet_table(&fleet.stats()).print(&format!(
        "fleet serving — {n_requests} requests in {} (mix {})",
        fmt_si_time(mix.wall_s),
        tenants.iter().map(|t| t.weight.to_string()).collect::<Vec<_>>().join(":")
    ));
    println!("throughput : {}", fmt_si_rate(mix.served as f64 / mix.wall_s, "req"));
    println!(
        "admission  : {} served, {} shed, {} errored (every request accounted)",
        mix.served, mix.shed, mix.errors
    );
    fleet.shutdown();
}

fn cmd_report() {
    let cfg = ChipConfig::default();
    let area = chip_area(&cfg);
    let power = chip_peak_power(&cfg);
    println!("X-TIME chip @16nm, {} cores, {:.1} GHz", cfg.n_cores, cfg.clock_ghz);
    println!("\nArea breakdown (Fig. 8a):");
    for (name, v) in area.rows("mm²") {
        println!("  {name:<24} {v:>8.2}");
    }
    println!("  {:<24} {:>8.2}", "TOTAL (mm²)", area.total());
    println!("\nPeak power breakdown (Fig. 8b):");
    for (name, v) in power.rows("W") {
        println!("  {name:<24} {v:>8.2}");
    }
    println!("  {:<24} {:>8.2}", "TOTAL (W)", power.total());
}
