//! Analog CAM cell models (paper §II-C, §III-B).
//!
//! The base analog CAM sub-cell stores a range with two memristor
//! conductances at `M = 4` bits (16 levels) and matches when the applied
//! analog query voltage falls inside the range. The paper's novel
//! contribution is the *macro-cell*: two sub-cells + a two-cycle search
//! that evaluates an `N = 8`-bit comparison on 4-bit devices — Eq. (3):
//!
//! ```text
//! MAL = [(q_MSB ≥ T_LMSB + 1) ∨ (q_LSB ≥ T_LLSB)]   (cycle 1, lower)
//!     ∧ (q_MSB ≥ T_LMSB)                             (cycle 2, lower)
//!     ∧ [(q_MSB < T_HMSB) ∨ (q_LSB < T_HLSB)]        (cycle 1, upper)
//!     ∧ (q_MSB < T_HMSB + 1)                         (cycle 2, upper)
//! ```
//!
//! This module implements both the ideal 8-bit comparison and the
//! two-cycle macro-cell evaluation, and [`tests::macro_cell_equals_ideal`]
//! proves them equivalent over the whole (q, T_L, T_H) space — the
//! correctness claim behind Table I.

/// Number of levels per memristor device (M = 4 bits).
pub const SUB_LEVELS: u16 = 16;
/// Full-precision bin count reachable with a macro-cell (N = 8 bits).
pub const MACRO_BINS: u16 = 256;

/// One 4-bit analog sub-cell: a `[lo, hi)` window in device levels.
/// `lo ∈ 0..=16`, `hi ∈ 0..=16`; `lo = 0, hi = 16` is "don't care".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SubCell {
    pub lo: u8,
    pub hi: u8,
}

impl SubCell {
    pub const DONT_CARE: SubCell = SubCell { lo: 0, hi: SUB_LEVELS as u8 };

    /// Single-cycle analog match: `lo ≤ q < hi`.
    #[inline]
    pub fn matches(&self, q: u8) -> bool {
        self.lo <= q && q < self.hi
    }
}

/// An 8-bit macro-cell built from two sub-cells per bound (MSB + LSB).
///
/// Thresholds live in *bin* space: `lo ∈ 0..=256`, `hi ∈ 0..=256`, row
/// matches iff `lo ≤ q < hi`. `hi = 256` (and `lo = 0`) encode the
/// "don't care" (full-range) programming of a missing feature (§II-D).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MacroCell {
    pub lo: u16,
    pub hi: u16,
}

impl MacroCell {
    pub const DONT_CARE: MacroCell = MacroCell { lo: 0, hi: MACRO_BINS };

    pub fn new(lo: u16, hi: u16) -> MacroCell {
        debug_assert!(lo <= MACRO_BINS && hi <= MACRO_BINS);
        MacroCell { lo, hi }
    }

    pub fn is_dont_care(&self) -> bool {
        self.lo == 0 && self.hi >= MACRO_BINS
    }

    /// Ideal 8-bit comparison (the functional spec).
    #[inline]
    pub fn matches_ideal(&self, q: u16) -> bool {
        self.lo <= q && q < self.hi
    }

    /// MSB/LSB decomposition of a bound: `T = 16·T_MSB + T_LSB`.
    /// `T = 256` decomposes to `(16, 0)` — the MSB device programmed past
    /// its last comparison level, i.e. "always below" for the upper bound.
    #[inline]
    pub fn split_bound(t: u16) -> (u16, u16) {
        (t / SUB_LEVELS, t % SUB_LEVELS)
    }

    /// Two-cycle macro-cell evaluation, Eq. (3). `q` must be an 8-bit bin.
    /// Returns the final MAL state after both cycles; the per-cycle parts
    /// are exposed by [`MacroCell::search_cycles`] for the pipeline model.
    #[inline]
    pub fn matches_two_cycle(&self, q: u8) -> bool {
        let (c1, c2) = self.search_cycles(q);
        c1 && c2
    }

    /// The two search cycles of Table I.
    ///
    /// Cycle 1 evaluates the OR brackets (both bounds); cycle 2 evaluates
    /// the second, MSB-only terms. The physical MAL is precharged before
    /// cycle 1 and only stays high if *both* cycles match (charge is not
    /// restored between cycles), implementing the AND.
    #[inline]
    pub fn search_cycles(&self, q: u8) -> (bool, bool) {
        let (q_msb, q_lsb) = (u16::from(q) / SUB_LEVELS, u16::from(q) % SUB_LEVELS);
        let (tl_msb, tl_lsb) = Self::split_bound(self.lo);
        let (th_msb, th_lsb) = Self::split_bound(self.hi);

        // Cycle 1: [(q_MSB ≥ T_LMSB+1) ∨ (q_LSB ≥ T_LLSB)]
        //        ∧ [(q_MSB < T_HMSB) ∨ (q_LSB < T_HLSB)]
        let c1_lower = q_msb >= tl_msb + 1 || q_lsb >= tl_lsb;
        let c1_upper = q_msb < th_msb || q_lsb < th_lsb;

        // Cycle 2: (q_MSB ≥ T_LMSB) ∧ (q_MSB < T_HMSB+1); the LSB
        // sub-cells are driven with always-match inputs (VDD/GND wires in
        // Table I) so only the MSB terms constrain the MAL.
        let c2_lower = q_msb >= tl_msb;
        let c2_upper = q_msb < th_msb + 1;

        (c1_lower && c1_upper, c2_lower && c2_upper)
    }

    /// The four physical sub-cells (lower-MSB, lower-LSB, upper-MSB,
    /// upper-LSB) as programmed device windows — used by the defect model,
    /// which perturbs *device levels*, not logical bins.
    pub fn sub_cells(&self) -> [(u16, u16); 2] {
        [Self::split_bound(self.lo), Self::split_bound(self.hi)]
    }

    /// Rebuild from (possibly defect-perturbed) sub-cell levels.
    pub fn from_levels(lo_msb: u16, lo_lsb: u16, hi_msb: u16, hi_lsb: u16) -> MacroCell {
        MacroCell {
            lo: (lo_msb * SUB_LEVELS + lo_lsb).min(MACRO_BINS),
            hi: (hi_msb * SUB_LEVELS + hi_lsb).min(MACRO_BINS),
        }
    }
}

/// A 4-bit-only cell operating directly on 4-bit bins (the prior-work
/// baseline [51] and the "X-TIME 4bit" ablation of Fig. 9a). One cycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cell4 {
    pub lo: u16,
    pub hi: u16,
}

impl Cell4 {
    pub const DONT_CARE: Cell4 = Cell4 { lo: 0, hi: SUB_LEVELS };

    #[inline]
    pub fn matches(&self, q: u16) -> bool {
        self.lo <= q && q < self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn macro_cell_equals_ideal_exhaustive_band() {
        // Exhaustive over q and a dense grid of (lo, hi) pairs including
        // every boundary-adjacent configuration — this is the Table I
        // correctness claim.
        for lo in (0..=MACRO_BINS).step_by(7) {
            for hi in (0..=MACRO_BINS).step_by(5) {
                let c = MacroCell::new(lo, hi);
                for q in 0u16..MACRO_BINS {
                    assert_eq!(
                        c.matches_two_cycle(q as u8),
                        c.matches_ideal(q),
                        "q={q} lo={lo} hi={hi}"
                    );
                }
            }
        }
    }

    #[test]
    fn macro_cell_equals_ideal_random() {
        prop::check(20_000, 0xEC3, |g| {
            let lo = g.usize_in(0, 257) as u16;
            let hi = g.usize_in(0, 257) as u16;
            let q = g.u8();
            let c = MacroCell::new(lo, hi);
            prop::require(
                c.matches_two_cycle(q) == c.matches_ideal(q as u16),
                format!("q={q} lo={lo} hi={hi}"),
            )
        });
    }

    #[test]
    fn boundary_cases() {
        // Half-open semantics: lo inclusive, hi exclusive.
        let c = MacroCell::new(16, 32);
        assert!(!c.matches_two_cycle(15));
        assert!(c.matches_two_cycle(16));
        assert!(c.matches_two_cycle(31));
        assert!(!c.matches_two_cycle(32));
        // Empty range never matches.
        let never = MacroCell::new(8, 8);
        for q in 0..=255u8 {
            assert!(!never.matches_two_cycle(q));
        }
        // Inverted range (used as padding rows) never matches.
        let inv = MacroCell::new(200, 10);
        for q in 0..=255u8 {
            assert!(!inv.matches_two_cycle(q));
        }
    }

    #[test]
    fn dont_care_matches_everything() {
        for q in 0..=255u8 {
            assert!(MacroCell::DONT_CARE.matches_two_cycle(q));
        }
        assert!(MacroCell::DONT_CARE.is_dont_care());
    }

    #[test]
    fn cycle1_alone_is_not_sufficient() {
        // The two-cycle scheme is genuinely needed: there must exist cases
        // where cycle 1 matches but cycle 2 rejects (otherwise one search
        // would do and the paper's Table I scheme would be vacuous).
        let mut found = false;
        for lo in 0..=MACRO_BINS {
            let c = MacroCell::new(lo, MACRO_BINS);
            for q in 0..MACRO_BINS {
                let (c1, c2) = c.search_cycles(q as u8);
                if c1 && !c2 {
                    found = true;
                    break;
                }
            }
            if found {
                break;
            }
        }
        assert!(found, "cycle 2 never constrained the result");
    }

    #[test]
    fn split_bound_roundtrip() {
        for t in 0..=MACRO_BINS {
            let (m, l) = MacroCell::split_bound(t);
            assert_eq!(m * SUB_LEVELS + l, t);
            assert!(l < SUB_LEVELS);
        }
    }

    #[test]
    fn sub_cell_matches() {
        let s = SubCell { lo: 3, hi: 10 };
        assert!(!s.matches(2));
        assert!(s.matches(3));
        assert!(s.matches(9));
        assert!(!s.matches(10));
        assert!(SubCell::DONT_CARE.matches(0) && SubCell::DONT_CARE.matches(15));
    }

    #[test]
    fn cell4_semantics() {
        let c = Cell4 { lo: 2, hi: 9 };
        assert!(!c.matches(1));
        assert!(c.matches(2) && c.matches(8));
        assert!(!c.matches(9));
        assert!(Cell4::DONT_CARE.matches(15));
    }

    #[test]
    fn from_levels_roundtrip() {
        prop::check(2000, 0x1E7E15, |g| {
            let lo = g.usize_in(0, 257) as u16;
            let hi = g.usize_in(0, 257) as u16;
            let c = MacroCell::new(lo, hi);
            let [(lm, ll), (hm, hl)] = c.sub_cells();
            let back = MacroCell::from_levels(lm, ll, hm, hl);
            prop::require(back == c, format!("lo={lo} hi={hi}"))
        });
    }
}
