//! Functional analog-CAM model: cells (including the paper's two-cycle
//! 8-bit macro-cell, §III-B), arrays with stacked/queued core organization
//! (§III-C) and analog defect injection (§V-A).

pub mod analog;
pub mod array;
pub mod cell;
pub mod defects;

pub use array::{
    dac_level, CamArray, CoreCam, CoreSearch, ARRAY_COLS, ARRAY_ROWS, CORE_COLS, CORE_ROWS,
};
pub use cell::{Cell4, MacroCell, SubCell, MACRO_BINS, SUB_LEVELS};
pub use defects::{
    inject_memristor_defects, inject_memristor_defects_tracked, DacErrors, DefectSpec,
};
